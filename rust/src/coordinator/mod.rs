//! L3 coordinator — the serving layer for real-time MRI uncertainty
//! estimation (the paper's adaptive-radiotherapy use case: voxel batches
//! arrive from the MR-Linac pipeline and must return calibrated
//! predictions within the 0.8 ms/batch real-time budget, §VI-C).
//!
//! Architecture (std threads + channels; tokio unavailable offline):
//!
//! ```text
//! clients ──► RequestQueue ──► Batcher ──► worker thread (owns Engine)
//!                 ▲  backpressure  │             │
//!                 └────────────────┘             ▼
//!                              UncertaintyAggregator ──► responses
//! ```
//!
//! * [`batcher`] — groups requests into engine-sized batches under a
//!   deadline (size-or-timeout policy), padding tail batches.
//! * [`server`] — worker thread construction (engines are not `Send`;
//!   the worker builds its engine from a factory inside the thread),
//!   request/response plumbing, graceful shutdown.
//! * [`uncertainty`] — per-voxel aggregation of the N mask samples into
//!   prediction + relative uncertainty + confidence flag.
//! * [`metrics`] — latency histogram, throughput, queue depth gauges.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod uncertainty;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use server::{Coordinator, CoordinatorConfig, VoxelRequest, VoxelResponse};
pub use uncertainty::{UncertaintyReport, VoxelEstimate};
