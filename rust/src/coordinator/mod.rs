//! L3 coordinator — the serving layer for real-time MRI uncertainty
//! estimation (the paper's adaptive-radiotherapy use case: voxel batches
//! arrive from the MR-Linac pipeline and must return calibrated
//! predictions within the 0.8 ms/batch real-time budget, §VI-C).
//!
//! Architecture (std threads + channels; tokio unavailable offline):
//!
//! ```text
//! clients ──► lease()/submit() ──► dispatcher (owns the Batcher)
//!                 ▲  backpressure   │ pushes batches (p2c on depth)
//!                 │      ┌──────────┼──────────┐
//!                 │      ▼          ▼          ▼
//!                 │  [deque 0]  [deque 1] … [deque K-1]  LIFO local pop,
//!                 │      ▼          ▼          ▼         FIFO steal-on-idle
//!                 │   shard 0    shard 1 …  shard K-1    (one Engine each,
//!                 │      │          │          │          built in-thread)
//!                 └──────┴────── responses ───┘
//! ```
//!
//! * [`batcher`] — groups requests into engine-sized batches under a
//!   deadline (size-or-timeout policy), zero-padding tail batches.
//! * [`deque`] — the per-shard bounded work deques: power-of-two-choices
//!   placement, LIFO local pops, FIFO steal-on-idle from a seeded-random
//!   victim; every step is a non-blocking atomic op so `testing::sched`
//!   can replay interleavings deterministically.
//! * [`server`] — the sharded worker pool (engines are not `Send`; each
//!   shard builds its engine from a shared factory inside its thread).
//!   Shards claim batches from their own deque and steal from stalled
//!   siblings (a slow shard never strands batches behind it), then run
//!   the two-phase `execute_into` hot path into output buffers recycled
//!   through a shared `infer::OutputPool`.  `Coordinator::lease` hands
//!   out pooled per-request signal buffers that the dispatcher reclaims
//!   at batch-cut time.  Graceful shutdown drains every shard.
//! * [`net`] — the TCP front door: hardened length-prefixed framing
//!   (`util::frame`), zero-copy ingest into `lease()` buffers, and
//!   deadline-aware admission control that sheds with an explicit
//!   `OVERLOADED` reply when the estimated queue delay (deque backlog ×
//!   EWMA batch latency) exceeds the request deadline.
//! * [`uncertainty`] — per-voxel aggregation of the N mask samples into
//!   prediction + relative uncertainty + confidence flag.
//! * [`metrics`] — latency histogram, throughput, queue/deque gauges and
//!   per-shard batch/response/steal/busy counters.
//!
//! See rust/DESIGN.md for the layer map and the shard architecture notes.

pub mod batcher;
pub mod deque;
pub mod metrics;
pub mod net;
pub mod server;
pub mod uncertainty;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use deque::{Claim, ShardDeques};
pub use metrics::{MetricsSnapshot, ServingMetrics, ShardSnapshot};
pub use net::{NetClient, NetConfig, NetReply, NetServer};
pub use server::{
    Coordinator, CoordinatorConfig, DispatchMode, SignalLease, StreamDriverGuard, VoxelRequest,
    VoxelResponse,
};
pub use uncertainty::{UncertaintyReport, VoxelEstimate};
