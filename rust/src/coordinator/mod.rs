//! L3 coordinator — the serving layer for real-time MRI uncertainty
//! estimation (the paper's adaptive-radiotherapy use case: voxel batches
//! arrive from the MR-Linac pipeline and must return calibrated
//! predictions within the 0.8 ms/batch real-time budget, §VI-C).
//!
//! Architecture (std threads + channels; tokio unavailable offline):
//!
//! ```text
//! clients ──► submit() ──► dispatcher (owns the Batcher)
//!                 ▲  backpressure  │ round-robin batches
//!                 │        ┌───────┼────────┐
//!                 │        ▼       ▼        ▼
//!                 │    shard 0  shard 1 … shard K-1   (one Engine each,
//!                 │        │       │        │          built in-thread)
//!                 └────────┴── responses ───┘
//! ```
//!
//! * [`batcher`] — groups requests into engine-sized batches under a
//!   deadline (size-or-timeout policy), padding tail batches.
//! * [`server`] — the sharded worker pool (engines are not `Send`; each
//!   shard builds its engine from a shared factory inside its thread),
//!   round-robin batch dispatch, request/response plumbing, graceful
//!   shutdown draining every shard.
//! * [`uncertainty`] — per-voxel aggregation of the N mask samples into
//!   prediction + relative uncertainty + confidence flag.
//! * [`metrics`] — latency histogram, throughput, queue depth gauges and
//!   per-shard batch/response/busy counters.
//!
//! See rust/DESIGN.md for the layer map and the shard architecture notes.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod uncertainty;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::{MetricsSnapshot, ServingMetrics, ShardSnapshot};
pub use server::{Coordinator, CoordinatorConfig, VoxelRequest, VoxelResponse};
pub use uncertainty::{UncertaintyReport, VoxelEstimate};
