//! L3 coordinator — the serving layer for real-time MRI uncertainty
//! estimation (the paper's adaptive-radiotherapy use case: voxel batches
//! arrive from the MR-Linac pipeline and must return calibrated
//! predictions within the 0.8 ms/batch real-time budget, §VI-C).
//!
//! Architecture (std threads + channels; tokio unavailable offline):
//!
//! ```text
//! clients ──► submit() ──► dispatcher (owns the Batcher)
//!                 ▲  backpressure  │ pushes full batches
//!                 │                ▼
//!                 │        ┌─ shared queue ─┐
//!                 │        ▼       ▼        ▼   shards PULL when idle
//!                 │    shard 0  shard 1 … shard K-1   (one Engine each,
//!                 │        │       │        │          built in-thread)
//!                 └────────┴── responses ───┘
//! ```
//!
//! * [`batcher`] — groups requests into engine-sized batches under a
//!   deadline (size-or-timeout policy), zero-padding tail batches.
//! * [`server`] — the sharded worker pool (engines are not `Send`; each
//!   shard builds its engine from a shared factory inside its thread).
//!   Shards *pull* formed batches from a shared queue (work-stealing: a
//!   slow shard never strands batches behind it) and run the two-phase
//!   `execute_into` hot path into output buffers recycled through a
//!   shared `infer::OutputPool`.  Graceful shutdown drains every shard.
//! * [`uncertainty`] — per-voxel aggregation of the N mask samples into
//!   prediction + relative uncertainty + confidence flag.
//! * [`metrics`] — latency histogram, throughput, queue depth gauges and
//!   per-shard batch/response/busy counters.
//!
//! See rust/DESIGN.md for the layer map and the shard architecture notes.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod uncertainty;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::{MetricsSnapshot, ServingMetrics, ShardSnapshot};
pub use server::{Coordinator, CoordinatorConfig, VoxelRequest, VoxelResponse};
pub use uncertainty::{UncertaintyReport, VoxelEstimate};
