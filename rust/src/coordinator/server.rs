//! The coordinator proper: a sharded pool of worker threads, each owning
//! its own inference engine, claiming formed batches from per-shard
//! work-stealing deques and recycling every serving-path buffer through
//! shared pools.
//!
//! ```text
//! clients ──► lease()/submit() ──► dispatcher thread (owns the Batcher)
//!                               │ pushes batches (p2c on deque depth)
//!                   ┌───────────┼───────────┐
//!                   ▼           ▼           ▼
//!               [deque 0]   [deque 1] … [deque K-1]   local pop = LIFO
//!                   ▼           ▼           ▼         steal-on-idle = FIFO
//!                shard 0     shard 1 ... shard K-1    from a random victim
//!                   │           │           │     (one Engine each,
//!                   └────── responses ──────┘      built in-thread)
//! ```
//!
//! Stealing is what keeps the datapath saturated under skewed load: a
//! stalled shard delays at most the single batch it already holds — an
//! idle sibling steals the rest of its backlog in arrival (FIFO) order.
//! Unlike the previous single shared MPMC queue (one `Mutex`+`Condvar`
//! all K shards convoyed on), contention is per-deque: the dispatcher
//! and at most one thief touch any given lock.  The legacy shared queue
//! survives behind [`DispatchMode::SharedQueue`] as the contention
//! baseline the `coordinator_throughput` bench compares against.
//!
//! Engines are not `Send` (PJRT handles are `Rc`-based), so the
//! coordinator takes an engine *factory* and each shard constructs its
//! engine inside its own thread.  Shards run the two-phase hot path:
//! `execute_into` writes into an `InferOutput` recycled through a shared
//! [`OutputPool`], batch signal buffers recycle through one [`VecPool`]
//! and per-request signal buffers through another (the
//! [`Coordinator::lease`] slab) — steady-state serving performs no
//! allocation on any side of the path.  Each request carries its own
//! response channel (one-shot style), so cross-shard completion order
//! never scrambles routing.
//!
//! Graceful shutdown drains everything: the dispatcher flushes the
//! batcher into the deques, closes them, and the coordinator joins all
//! threads — shards keep claiming (local pops *and* steals) until the
//! closed deques are empty, so no request admitted before `shutdown()`
//! is dropped.  If every shard dies (engine panics), the last exit
//! closes and drains the deques so stranded callers fail fast instead of
//! hanging.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher, BatcherConfig, Pending};
use super::deque::{Claim, ShardDeques};
use super::metrics::{MetricsSnapshot, ServingMetrics};
use super::uncertainty::{aggregate_voxel, Thresholds};
use crate::infer::{Engine, OutputPool};
use crate::util::pool::VecPool;
use crate::util::rng::Pcg32;

pub use super::uncertainty::UncertaintyReport;

/// Seed for the dispatcher's power-of-two-choices placement stream.
const DISPATCH_SEED: u64 = 0x00D1_5BA1;
/// Stream family for per-shard steal-victim selection (stream = shard).
const STEAL_SEED: u64 = 0x0005_7EA1;

/// A request: one voxel's normalised signals.
#[derive(Debug, Clone)]
pub struct VoxelRequest {
    pub id: u64,
    pub signals: Vec<f32>,
}

/// The response: aggregated prediction + uncertainty.
#[derive(Debug, Clone)]
pub struct VoxelResponse {
    pub id: u64,
    pub report: UncertaintyReport,
}

struct Envelope {
    req: VoxelRequest,
    resp_tx: Sender<VoxelResponse>,
    enqueued: Instant,
}

enum Msg {
    Request(Envelope),
    Shutdown,
}

/// Tag carried through the batcher for each real row.
type RowTag = (u64, Sender<VoxelResponse>, Instant);

/// The shared batch queue the shards pull from.  Closing it wakes every
/// puller; pullers drain remaining batches before observing the close.
struct WorkQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    batches: VecDeque<Batch<RowTag>>,
    closed: bool,
}

impl WorkQueue {
    fn new() -> Self {
        WorkQueue {
            state: Mutex::new(QueueState {
                batches: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a batch.  `Err` hands the batch back when the queue is
    /// already closed — that only happens when every shard is gone, and
    /// the caller must fail the batch's requests instead of stranding
    /// them (during normal shutdown the dispatcher itself closes the
    /// queue, and only after its final flush).
    fn push(&self, batch: Batch<RowTag>) -> Result<(), Batch<RowTag>> {
        let mut s = self.state.lock().expect("queue lock");
        if s.closed {
            return Err(batch);
        }
        s.batches.push_back(batch);
        drop(s);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking pull.  `None` only once the queue is closed *and* fully
    /// drained, so shutdown never drops an admitted batch.
    fn pull(&self) -> Option<Batch<RowTag>> {
        let mut s = self.state.lock().expect("queue lock");
        loop {
            if let Some(b) = s.batches.pop_front() {
                return Some(b);
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).expect("queue lock");
        }
    }

    fn close(&self) {
        self.state.lock().expect("queue lock").closed = true;
        self.ready.notify_all();
    }

    /// Non-blocking pop, ignoring the closed flag (last-shard-exit drain).
    fn try_pull(&self) -> Option<Batch<RowTag>> {
        self.state.lock().expect("queue lock").batches.pop_front()
    }
}

/// How formed batches travel from the dispatcher to the shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Per-shard bounded deques: p2c placement, LIFO local pop, FIFO
    /// steal-on-idle (the default — contention is per-deque).
    #[default]
    Deques,
    /// The legacy single shared MPMC queue (one `Mutex`+`Condvar` every
    /// shard convoys on).  Kept as the contention baseline for the
    /// `coordinator_throughput` bench and as a fallback.
    SharedQueue,
}

/// The dispatcher→shard hand-off structure, unified over both dispatch
/// modes so the dispatcher/shard/failsafe loops are written once.
enum WorkSource {
    Shared(WorkQueue),
    Deques(ShardDeques<Batch<RowTag>>),
}

impl WorkSource {
    fn new(mode: DispatchMode, shards: usize, cfg: &BatcherConfig) -> Self {
        match mode {
            DispatchMode::SharedQueue => WorkSource::Shared(WorkQueue::new()),
            DispatchMode::Deques => {
                // Soft per-deque balance bound: the admitted backlog
                // (queue_capacity requests) split across shards, in
                // batches.  Admission control stays at `submit()`.
                let cap = super::deque::cap_for(cfg.queue_capacity, cfg.batch_size, shards);
                WorkSource::Deques(ShardDeques::new(shards, cap))
            }
        }
    }

    /// Hand a formed batch to the shards.  `Err` returns the batch once
    /// the source is closed (every shard dead): the caller must fail its
    /// requests fast rather than strand them.
    fn push(&self, batch: Batch<RowTag>, rng: &mut Pcg32) -> Result<(), Batch<RowTag>> {
        match self {
            WorkSource::Shared(q) => q.push(batch),
            WorkSource::Deques(d) => d.push_balanced(batch, rng).map(|_| ()),
        }
    }

    /// Blocking claim for shard `k`.  `None` only once closed **and**
    /// drained.  Shared-queue claims count as local.
    fn pop(&self, k: usize, rng: &mut Pcg32) -> Option<(Batch<RowTag>, Claim)> {
        match self {
            WorkSource::Shared(q) => q.pull().map(|b| (b, Claim::Local)),
            WorkSource::Deques(d) => d.pop(k, rng),
        }
    }

    fn close(&self) {
        match self {
            WorkSource::Shared(q) => q.close(),
            WorkSource::Deques(d) => d.close(),
        }
    }

    /// Empty every queue/deque, handing the batches back (dead-pool
    /// failsafe; call after `close`).
    fn drain(&self) -> Vec<Batch<RowTag>> {
        match self {
            WorkSource::Shared(q) => {
                let mut out = Vec::new();
                while let Some(b) = q.try_pull() {
                    out.push(b);
                }
                out
            }
            WorkSource::Deques(d) => d.drain(),
        }
    }

    /// Shard `k`'s deque depth gauge (0 under the shared queue, which
    /// has no per-shard backlog).
    fn deque_depth(&self, k: usize) -> usize {
        match self {
            WorkSource::Shared(_) => 0,
            WorkSource::Deques(d) => d.depth(k),
        }
    }
}

/// A pooled per-request signal buffer handed out by
/// [`Coordinator::lease`]: fill it (it is pre-sized to `nb`, zeroed) and
/// pass it to [`Coordinator::submit_leased`].  The buffer's `Vec` is
/// reclaimed into the lease slab when the dispatcher copies it into a
/// batch — and an **unused** lease returns its buffer on drop, so
/// abandoning one leaks nothing.
pub struct SignalLease {
    buf: Option<Vec<f32>>,
    pool: Arc<VecPool>,
}

impl SignalLease {
    /// The signal slots, in b-value order (length = the coordinator's
    /// `nb`).
    pub fn signals_mut(&mut self) -> &mut [f32] {
        self.buf.as_mut().expect("lease holds its buffer")
    }

    pub fn signals(&self) -> &[f32] {
        self.buf.as_ref().expect("lease holds its buffer")
    }

    /// Copy a voxel's signals in (`src.len()` must equal `nb`).
    pub fn copy_from(&mut self, src: &[f32]) {
        self.signals_mut().copy_from_slice(src);
    }

    fn into_vec(mut self) -> Vec<f32> {
        self.buf.take().expect("lease holds its buffer")
    }
}

impl Drop for SignalLease {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.pool.put(buf);
        }
    }
}

impl std::ops::Deref for SignalLease {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.signals()
    }
}

impl std::ops::DerefMut for SignalLease {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.signals_mut()
    }
}

/// Runs when a shard thread exits for any reason — normal shutdown,
/// factory failure, or an engine panic unwinding the thread.  When the
/// *last* shard goes away, close and drain the work source so stranded
/// batches drop their responders (callers see an error instead of
/// hanging forever) and release their queue-depth slots.
struct ShardExitGuard {
    source: Arc<WorkSource>,
    depth: Arc<AtomicUsize>,
    alive: Arc<AtomicUsize>,
}

impl Drop for ShardExitGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.source.close();
            for batch in self.source.drain() {
                for _ in batch.tags {
                    self.depth.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub thresholds: Thresholds,
    /// Voxel width (number of b-values) — validated on submit.
    pub nb: usize,
    /// Worker shards, each owning one engine (min 1).
    pub shards: usize,
    /// Dispatcher→shard hand-off structure (default: per-shard deques).
    pub dispatch: DispatchMode,
}

impl CoordinatorConfig {
    pub fn for_batch(nb: usize, batch_size: usize) -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig {
                batch_size,
                ..Default::default()
            },
            thresholds: Thresholds::default(),
            nb,
            shards: 1,
            dispatch: DispatchMode::default(),
        }
    }

    /// `for_batch` with a K-shard worker pool.
    pub fn sharded(nb: usize, batch_size: usize, shards: usize) -> Self {
        CoordinatorConfig {
            shards: shards.max(1),
            ..Self::for_batch(nb, batch_size)
        }
    }
}

/// Handle to a running coordinator.  Dropping shuts the pool down.
pub struct Coordinator {
    tx: Sender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
    shard_workers: Vec<JoinHandle<()>>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    source: Arc<WorkSource>,
    pool: Arc<OutputPool>,
    signal_pool: Arc<VecPool>,
    request_pool: Arc<VecPool>,
    capacity: usize,
    nb: usize,
    shards: usize,
    batch_size: usize,
    /// Set while a streaming-volume driver owns the slice admission
    /// gate (see [`Coordinator::stream_driver_guard`]).
    stream_driver: Arc<AtomicBool>,
}

/// Exclusive claim on the streaming-volume admission gate.
///
/// `volume::stream::stream_volume`'s no-rejection proof assumes a
/// **single producer**: the driver reads `queue_depth` and then submits
/// a whole slice on the strength of that read, which only holds when no
/// other driver is admitting concurrently.  The guard turns that
/// implicit invariant into a checked one — a second concurrent driver
/// gets an error instead of silently racing the gate.  Dropping the
/// guard releases the claim.
pub struct StreamDriverGuard {
    flag: Arc<AtomicBool>,
}

impl Drop for StreamDriverGuard {
    fn drop(&mut self) {
        self.flag.store(false, Ordering::Release);
    }
}

/// Everything one shard worker needs, bundled so the spawn loop stays
/// readable.
struct ShardCtx {
    index: usize,
    source: Arc<WorkSource>,
    pool: Arc<OutputPool>,
    signal_pool: Arc<VecPool>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    thresholds: Thresholds,
    batch_size: usize,
}

impl Coordinator {
    /// Start the pool.  `engine_factory` runs once per shard, on that
    /// shard's thread, and must produce engines whose `batch_size()`
    /// equals the batcher's.
    pub fn start<F>(cfg: CoordinatorConfig, engine_factory: F) -> anyhow::Result<Coordinator>
    where
        F: Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let metrics = Arc::new(ServingMetrics::with_shards(shards));
        let depth = Arc::new(AtomicUsize::new(0));
        let capacity = cfg.batcher.queue_capacity;
        let nb = cfg.nb;
        let factory = Arc::new(engine_factory);
        let source = Arc::new(WorkSource::new(cfg.dispatch, shards, &cfg.batcher));
        // Enough pooled buffers for every shard to hold one in flight
        // plus one ready for hand-off.
        let pool = Arc::new(OutputPool::new(2 * shards));
        // Same bound for the recycled batch *signal* buffers (one being
        // filled by the dispatcher + one in flight per shard).
        let signal_pool = Arc::new(VecPool::new(2 * shards));
        // The lease slab: per-request signal buffers.  Bounded by the
        // admission gate — there can never be more than `queue_capacity`
        // leased-and-admitted requests in flight, so at that cap the
        // steady state allocates nothing and a burst cannot hoard more
        // than the backlog it was admitted for.
        let request_pool = Arc::new(VecPool::new(capacity.max(1)));

        // Spawn the shard workers first; each builds its engine in-thread
        // and reports readiness (engine batch size) or the build error.
        let (ready_tx, ready_rx) = channel::<(usize, anyhow::Result<usize>)>();
        let alive = Arc::new(AtomicUsize::new(shards));
        let mut shard_workers = Vec::with_capacity(shards);
        for k in 0..shards {
            let ctx = ShardCtx {
                index: k,
                source: Arc::clone(&source),
                pool: Arc::clone(&pool),
                signal_pool: Arc::clone(&signal_pool),
                metrics: Arc::clone(&metrics),
                depth: Arc::clone(&depth),
                thresholds: cfg.thresholds,
                batch_size: cfg.batcher.batch_size,
            };
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let guard = ShardExitGuard {
                source: Arc::clone(&source),
                depth: Arc::clone(&depth),
                alive: Arc::clone(&alive),
            };
            let spawned = std::thread::Builder::new()
                .name(format!("uivim-shard-{k}"))
                .spawn(move || {
                    // dropped on every exit path, including panics
                    let _guard = guard;
                    let mut engine = match (*factory)() {
                        Ok(e) => {
                            let _ = ready.send((k, Ok(e.batch_size())));
                            e
                        }
                        Err(e) => {
                            let _ = ready.send((k, Err(e)));
                            return;
                        }
                    };
                    shard_loop(ctx, engine.as_mut());
                });
            match spawned {
                Ok(h) => shard_workers.push(h),
                Err(e) => {
                    // don't leave already-spawned shards parked on the
                    // work source forever
                    source.close();
                    for w in shard_workers {
                        let _ = w.join();
                    }
                    return Err(e.into());
                }
            }
        }
        drop(ready_tx);

        // Wait for every shard to build (or fail fast, draining the rest).
        let mut build_err = None;
        for _ in 0..shards {
            match ready_rx.recv() {
                Ok((_, Ok(engine_batch))) => {
                    if engine_batch != cfg.batcher.batch_size {
                        build_err = Some(anyhow::anyhow!(
                            "engine batch size {engine_batch} != batcher {}",
                            cfg.batcher.batch_size
                        ));
                    }
                }
                Ok((k, Err(e))) => {
                    build_err = Some(e.context(format!("shard {k} engine construction")));
                }
                Err(_) => {
                    build_err =
                        Some(anyhow::anyhow!("a shard died during engine construction"));
                    break;
                }
            }
        }
        if let Some(e) = build_err {
            source.close();
            for w in shard_workers {
                let _ = w.join();
            }
            return Err(e);
        }

        // Dispatcher thread: owns the batcher, feeds the work source.
        let (tx, rx) = channel::<Msg>();
        let d_metrics = Arc::clone(&metrics);
        let d_depth = Arc::clone(&depth);
        let d_source = Arc::clone(&source);
        let d_signal_pool = Arc::clone(&signal_pool);
        let d_request_pool = Arc::clone(&request_pool);
        let d_cfg = cfg.clone();
        let dispatcher = match std::thread::Builder::new()
            .name("uivim-dispatcher".into())
            .spawn(move || {
                dispatcher_loop(
                    d_cfg,
                    rx,
                    &d_source,
                    &d_metrics,
                    &d_depth,
                    d_signal_pool,
                    d_request_pool,
                )
            }) {
            Ok(h) => h,
            Err(e) => {
                // shards are parked on the work source: release and join
                source.close();
                for w in shard_workers {
                    let _ = w.join();
                }
                return Err(e.into());
            }
        };

        Ok(Coordinator {
            tx,
            dispatcher: Some(dispatcher),
            shard_workers,
            metrics,
            depth,
            source,
            pool,
            signal_pool,
            request_pool,
            capacity,
            nb,
            shards,
            batch_size: cfg.batcher.batch_size,
            stream_driver: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Submit a voxel; returns a receiver for the response, or an error
    /// immediately under backpressure.
    pub fn submit(&self, req: VoxelRequest) -> anyhow::Result<Receiver<VoxelResponse>> {
        self.submit_inner(req).map_err(|(e, _)| e)
    }

    /// `submit` that hands the request back on failure, so pooled
    /// buffers can be reclaimed instead of dropped.
    fn submit_inner(
        &self,
        req: VoxelRequest,
    ) -> Result<Receiver<VoxelResponse>, (anyhow::Error, VoxelRequest)> {
        if req.signals.len() != self.nb {
            return Err((
                anyhow::anyhow!(
                    "voxel has {} values, expected {}",
                    req.signals.len(),
                    self.nb
                ),
                req,
            ));
        }
        // relaxed: request/rejected are monotonic telemetry counters —
        // readers only ever snapshot totals, no ordering is needed.
        if self.depth.load(Ordering::Acquire) >= self.capacity {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err((
                anyhow::anyhow!("queue full ({} requests)", self.capacity),
                req,
            ));
        }
        let (resp_tx, resp_rx) = channel();
        self.depth.fetch_add(1, Ordering::AcqRel);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match self.tx.send(Msg::Request(Envelope {
            req,
            resp_tx,
            enqueued: Instant::now(),
        })) {
            Ok(()) => Ok(resp_rx),
            Err(send_err) => {
                self.depth.fetch_sub(1, Ordering::AcqRel);
                let Msg::Request(env) = send_err.0 else {
                    unreachable!("submit only sends requests")
                };
                Err((anyhow::anyhow!("coordinator stopped"), env.req))
            }
        }
    }

    /// Take a pooled per-request signal buffer (pre-sized to `nb`,
    /// zeroed).  Fill it and pass it to [`Coordinator::submit_leased`]:
    /// together they close the last caller-side allocation on the
    /// serving path — the buffer cycles lease → batcher → back to the
    /// slab, and dropping an unfilled lease returns it too.
    pub fn lease(&self) -> SignalLease {
        let mut buf = self.request_pool.take(self.nb);
        buf.resize(self.nb, 0.0);
        SignalLease {
            buf: Some(buf),
            pool: Arc::clone(&self.request_pool),
        }
    }

    /// Submit a leased buffer as voxel `id`.  On rejection
    /// (backpressure / shutdown) the buffer goes straight back to the
    /// slab — a failed submit leaks nothing.
    pub fn submit_leased(
        &self,
        id: u64,
        lease: SignalLease,
    ) -> anyhow::Result<Receiver<VoxelResponse>> {
        let req = VoxelRequest {
            id,
            signals: lease.into_vec(),
        };
        match self.submit_inner(req) {
            Ok(rx) => Ok(rx),
            Err((e, req)) => {
                self.request_pool.put(req.signals);
                Err(e)
            }
        }
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, req: VoxelRequest) -> anyhow::Result<VoxelResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped the request"))
    }

    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Number of worker shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Voxel width (signal values per request) — what `lease()` sizes
    /// its buffers to and what the net layer validates frames against.
    pub fn nb(&self) -> usize {
        self.nb
    }

    /// Current queue depth (requests admitted but not yet answered).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// The admission-gate capacity: `submit` rejects once `queue_depth`
    /// reaches this. Streaming drivers use it to size backpressure
    /// (admit a slice only when `capacity - depth` can absorb it).
    pub fn queue_capacity(&self) -> usize {
        self.capacity
    }

    /// Idle recycled output buffers (observability for the pool).
    pub fn pooled_outputs(&self) -> usize {
        self.pool.idle()
    }

    /// Idle recycled batch signal buffers.
    pub fn pooled_signals(&self) -> usize {
        self.signal_pool.idle()
    }

    /// Idle per-request signal buffers in the lease slab.
    pub fn pooled_requests(&self) -> usize {
        self.request_pool.idle()
    }

    /// Fresh allocations the lease slab has made so far — the
    /// capacity-stability signature (stable once leases recycle in
    /// steady state).
    pub fn lease_high_water(&self) -> usize {
        self.request_pool.created()
    }

    /// Point-in-time metrics **including the live gauges** (pool sizes,
    /// per-shard deque depths, pending queue depth) that the raw counter
    /// block cannot see.  Prefer this over `metrics().snapshot()` for
    /// dashboards.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        s.pooled_outputs = self.pooled_outputs();
        s.pooled_signals = self.pooled_signals();
        s.pooled_requests = self.pooled_requests();
        s.queue_depth = self.queue_depth();
        for (k, shard) in s.per_shard.iter_mut().enumerate() {
            shard.deque_depth = self.source.deque_depth(k);
        }
        s
    }

    /// Estimated wait for a request admitted right now, in µs: the
    /// formed-batch backlog across every shard deque plus the unformed
    /// remainder of `queue_depth`, priced at the EWMA batch service
    /// time and divided across the shards.  Zero on a cold coordinator
    /// (no batch has run yet) — deadline shedding only ever engages
    /// once there is measured service time to reason with.
    pub fn estimated_queue_delay_us(&self) -> u64 {
        let queued_batches: usize = (0..self.shards)
            .map(|k| self.source.deque_depth(k))
            .sum();
        let in_deques = queued_batches * self.batch_size;
        let pending = self.queue_depth().saturating_sub(in_deques);
        super::net::admission::estimate_delay_us(
            queued_batches,
            pending,
            self.batch_size,
            self.shards,
            self.metrics.ewma_batch_us() as u64,
        )
    }

    /// Claim the streaming-volume admission gate for one driver (see
    /// [`StreamDriverGuard`]).  Errors when another driver already
    /// holds it: running two `stream_volume` calls concurrently against
    /// one coordinator would break the gate's single-producer
    /// no-rejection invariant.
    pub fn stream_driver_guard(&self) -> anyhow::Result<StreamDriverGuard> {
        if self
            .stream_driver
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            anyhow::bail!(
                "a streaming-volume driver already owns this coordinator's slice \
                 admission gate (single-producer invariant); run the volumes \
                 sequentially or use separate coordinators"
            );
        }
        Ok(StreamDriverGuard {
            flag: Arc::clone(&self.stream_driver),
        })
    }

    fn stop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.shard_workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Graceful shutdown: flush pending work through the queue, join the
    /// dispatcher and all workers.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Dispatcher: batch formation + work-source hand-off (p2c placement
/// under deque dispatch).
fn dispatcher_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<Msg>,
    source: &WorkSource,
    metrics: &ServingMetrics,
    depth: &AtomicUsize,
    signal_pool: Arc<VecPool>,
    request_pool: Arc<VecPool>,
) {
    let mut batcher: Batcher<RowTag> = Batcher::with_pools(
        cfg.batcher.clone(),
        cfg.nb,
        signal_pool,
        Arc::clone(&request_pool),
    );
    let mut rng = Pcg32::new(DISPATCH_SEED);
    let mut shutting_down = false;

    loop {
        // Wait for work, bounded by the oldest request's deadline.
        let timeout = match batcher.oldest_wait(Instant::now()) {
            Some(w) => cfg.batcher.max_wait.saturating_sub(w),
            None => {
                if shutting_down {
                    break;
                }
                Duration::from_millis(50)
            }
        };
        let handle = |msg: Msg, batcher: &mut Batcher<RowTag>, shutting_down: &mut bool| {
            match msg {
                Msg::Request(env) => {
                    let pend = Pending {
                        signals: env.req.signals,
                        tag: (env.req.id, env.resp_tx, env.enqueued),
                        enqueued: env.enqueued,
                    };
                    // capacity is enforced on submit; push cannot fail
                    // here unless capacity raced — shed in that case,
                    // reclaiming the request's buffer into the slab.
                    // relaxed: monotonic telemetry counter (snapshot-only
                    // readers), no ordering needed.
                    if let Err(p) = batcher.push(pend) {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        depth.fetch_sub(1, Ordering::AcqRel);
                        request_pool.put(p.signals);
                    }
                }
                Msg::Shutdown => *shutting_down = true,
            }
        };
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                handle(msg, &mut batcher, &mut shutting_down);
                // Greedily drain whatever else is already queued on the
                // channel: requests age in the channel too, and cutting
                // before draining would degrade into 1-row batches under
                // bursty load.
                while !batcher.is_full() {
                    match rx.try_recv() {
                        Ok(msg) => handle(msg, &mut batcher, &mut shutting_down),
                        Err(_) => break,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                shutting_down = true;
            }
        }

        // Cut every ready batch (all pending on shutdown) into the work
        // source; under deque dispatch p2c picks the shallowest of two
        // random deques, and an idle shard steals whatever lands badly.
        // Batch/padding counters are recorded by the shard that actually
        // serves the batch, so dropped batches never inflate the
        // aggregate metrics.
        while (shutting_down && !batcher.is_empty()) || batcher.ready(Instant::now()) {
            let Some(batch) = batcher.cut() else { break };
            if let Err(batch) = source.push(batch, &mut rng) {
                // every shard is dead: fail these requests fast by
                // dropping their responders and releasing their slots
                for _ in batch.tags {
                    depth.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }

        if shutting_down && batcher.is_empty() {
            break;
        }
    }

    // Close the source: shards drain whatever is left, then exit.
    source.close();
}

/// One shard: claim batches (local LIFO pop, FIFO steal when idle), run
/// the engine into a recycled output buffer, answer requests.
fn shard_loop(ctx: ShardCtx, engine: &mut dyn Engine) {
    // Hard assert: a mis-sized engine would slice `signals` wrong on
    // every batch, and a `debug_assert` would wave it through in release.
    assert_eq!(engine.batch_size(), ctx.batch_size);
    // relaxed: every Relaxed below is a monotonic telemetry counter
    // (batches, responses, busy time); readers snapshot totals only, so
    // no cross-counter ordering is needed.  Queue-depth accounting, the
    // one atomic with ordering semantics, stays AcqRel.
    let shard = ctx.metrics.shard(ctx.index);
    let n_samples = engine.n_samples();
    let mut rng = Pcg32::with_stream(STEAL_SEED, ctx.index as u64);
    while let Some((batch, claim)) = ctx.source.pop(ctx.index, &mut rng) {
        match claim {
            Claim::Local => {
                shard.local_batches.fetch_add(1, Ordering::Relaxed);
            }
            Claim::Stolen { .. } => {
                shard.stolen_batches.fetch_add(1, Ordering::Relaxed);
            }
        }
        let Batch { signals, tags, real } = batch;
        let mut out = ctx.pool.take(n_samples, ctx.batch_size);
        let t0 = Instant::now();
        // A panicking engine must not leak this batch's queue-depth
        // slots: release them, then let the unwind continue so the
        // thread's ShardExitGuard handles the rest of the queue.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.execute_into(&signals, &mut out)
        }));
        let run = match run {
            Ok(r) => r,
            Err(payload) => {
                for _ in &tags {
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                }
                std::panic::resume_unwind(payload);
            }
        };
        match run {
            Ok(()) => {
                let batch_us = t0.elapsed().as_micros() as u64;
                ctx.metrics.batch_latency.record_us(batch_us);
                ctx.metrics.record_batch_ewma(batch_us);
                ctx.metrics.batches.fetch_add(1, Ordering::Relaxed);
                ctx.metrics.padded_rows.fetch_add(
                    (ctx.batch_size - real) as u64,
                    Ordering::Relaxed,
                );
                shard.busy_us.fetch_add(batch_us, Ordering::Relaxed);
                shard.batches.fetch_add(1, Ordering::Relaxed);
                for (row, (id, resp_tx, enq)) in tags.into_iter().enumerate() {
                    let report = aggregate_voxel(&out, row, &ctx.thresholds);
                    ctx.metrics
                        .request_latency
                        .record_us(enq.elapsed().as_micros() as u64);
                    ctx.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    shard.responses.fetch_add(1, Ordering::Relaxed);
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                    let _ = resp_tx.send(VoxelResponse { id, report });
                }
            }
            Err(e) => {
                eprintln!("uivim-shard-{}: engine failure: {e:#}", ctx.index);
                shard.engine_errors.fetch_add(1, Ordering::Relaxed);
                for (_, _resp_tx, _) in tags.into_iter() {
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                    // dropping resp_tx signals the error to the caller
                }
            }
        }
        ctx.pool.put(out);
        // hand the batch's signal buffer back for the dispatcher's next cut
        ctx.signal_pool.put(signals);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::registry::{factory, EngineOpts};
    use crate::infer::InferOutput;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::Manifest;
    use crate::testing::fixture;

    fn start_native(
        batch: usize,
        queue_capacity: usize,
        shards: usize,
    ) -> (Coordinator, Manifest) {
        let (man, w) = fixture::tiny_fixture();
        let man2 = man.clone();
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
        cfg.batcher.queue_capacity = queue_capacity;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let opts = EngineOpts {
            batch: Some(batch),
            ..Default::default()
        };
        let coord = Coordinator::start(cfg, factory("native", man2, w, opts).unwrap()).unwrap();
        (coord, man)
    }

    #[test]
    fn serves_requests_end_to_end() {
        let (coord, man) = start_native(8, 1000, 1);
        let ds = synth_dataset(20, &man.bvalues, 20.0, 1);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap(),
            );
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
            let d = resp.report.get(crate::ivim::Param::D);
            assert!(d.mean >= 0.0 && d.mean <= 0.005);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, 20);
        assert!(snap.batches >= 3); // 20 voxels / batch 8
        coord.shutdown();
    }

    #[test]
    fn sharded_pool_partitions_every_response() {
        let (coord, man) = start_native(4, 100_000, 3);
        assert_eq!(coord.shards(), 3);
        let n = 120;
        let ds = synth_dataset(n, &man.bvalues, 20.0, 4);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, n as u64);
        assert_eq!(snap.per_shard.len(), 3);
        let shard_responses: u64 = snap.per_shard.iter().map(|s| s.responses).sum();
        assert_eq!(shard_responses, n as u64, "every response owned by a shard");
        // Pull scheduling: batch ownership is demand-driven, so only the
        // totals are deterministic — every batch was claimed by exactly
        // one shard.
        let shard_batches: u64 = snap.per_shard.iter().map(|s| s.batches).sum();
        assert_eq!(shard_batches, snap.batches);
        coord.shutdown();
    }

    #[test]
    fn sharded_results_match_single_worker() {
        let (c1, man) = start_native(8, 10_000, 1);
        let (c4, _) = start_native(8, 10_000, 4);
        let ds = synth_dataset(64, &man.bvalues, 20.0, 5);
        let collect = |coord: &Coordinator| -> Vec<f64> {
            let rxs: Vec<_> = (0..64)
                .map(|i| {
                    coord
                        .submit(VoxelRequest {
                            id: i as u64,
                            signals: ds.voxel(i).to_vec(),
                        })
                        .unwrap()
                })
                .collect();
            rxs.into_iter()
                .map(|rx| {
                    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    r.report.get(crate::ivim::Param::D).mean
                })
                .collect()
        };
        let a = collect(&c1);
        let b = collect(&c4);
        // Per-voxel results are unchanged by sharding: identical engines,
        // identical per-voxel math, batch membership does not leak.
        // (Batch *padding* rows never land on real voxels' outputs.)
        assert_eq!(a, b);
        c1.shutdown();
        c4.shutdown();
    }

    /// The point of the pull model: a stalled shard must not strand
    /// batches behind it.  One shard sleeps 25 ms per batch; under
    /// round-robin half the batches would queue behind it, under pull the
    /// fast shard drains nearly everything.
    #[test]
    fn slow_shard_does_not_strand_batches() {
        struct SlowEngine {
            inner: Box<dyn Engine>,
            delay: Duration,
        }
        impl Engine for SlowEngine {
            fn name(&self) -> &str {
                "slow-wrapper"
            }
            fn batch_size(&self) -> usize {
                self.inner.batch_size()
            }
            fn n_samples(&self) -> usize {
                self.inner.n_samples()
            }
            fn execute_into(
                &mut self,
                signals: &[f32],
                out: &mut InferOutput,
            ) -> anyhow::Result<()> {
                std::thread::sleep(self.delay);
                self.inner.execute_into(signals, out)
            }
        }

        let (man, w) = fixture::tiny_fixture();
        let batch = 4usize;
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, 2);
        cfg.batcher.queue_capacity = 100_000;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let built = Arc::new(AtomicUsize::new(0));
        let inner = factory(
            "native",
            man.clone(),
            w,
            EngineOpts {
                batch: Some(batch),
                ..Default::default()
            },
        )
        .unwrap();
        let coord = Coordinator::start(cfg, move || {
            // the first engine constructed is the slow one
            let delay = if built.fetch_add(1, Ordering::SeqCst) == 0 {
                Duration::from_millis(25)
            } else {
                Duration::ZERO
            };
            Ok(Box::new(SlowEngine {
                inner: inner()?,
                delay,
            }) as Box<dyn Engine>)
        })
        .unwrap();

        let n = 80; // 20 batches of 4
        let ds = synth_dataset(n, &man.bvalues, 20.0, 6);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let snap = coord.metrics().snapshot();
        let batches: Vec<u64> = snap.per_shard.iter().map(|s| s.batches).collect();
        let fast = *batches.iter().max().unwrap();
        let total: u64 = batches.iter().sum();
        assert_eq!(snap.responses, n as u64);
        // Round-robin would split exactly 50/50; pull lets the fast
        // shard take the majority (in practice nearly everything — the
        // slow shard serves a handful at 25 ms each while the fast one
        // clears microsecond batches).  Strictly-more-than-half is the
        // scheduling-noise-proof bound.
        assert!(
            fast > total / 2,
            "fast shard should dominate under pull dispatch: {batches:?}"
        );
        coord.shutdown();
    }

    /// If every shard dies (engine panic), pending and future batches
    /// must fail fast — responders dropped so callers see an error —
    /// instead of hanging forever on a queue nobody will ever drain.
    #[test]
    fn dead_pool_fails_requests_instead_of_hanging() {
        struct PanicEngine {
            inner: Box<dyn Engine>,
        }
        impl Engine for PanicEngine {
            fn name(&self) -> &str {
                "panic-wrapper"
            }
            fn batch_size(&self) -> usize {
                self.inner.batch_size()
            }
            fn n_samples(&self) -> usize {
                self.inner.n_samples()
            }
            fn execute_into(
                &mut self,
                _signals: &[f32],
                _out: &mut InferOutput,
            ) -> anyhow::Result<()> {
                panic!("injected engine failure");
            }
        }

        let (man, w) = fixture::tiny_fixture();
        let batch = 4usize;
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, 1);
        cfg.batcher.queue_capacity = 10_000;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let inner = factory(
            "native",
            man.clone(),
            w,
            EngineOpts {
                batch: Some(batch),
                ..Default::default()
            },
        )
        .unwrap();
        let coord = Coordinator::start(cfg, move || {
            Ok(Box::new(PanicEngine { inner: inner()? }) as Box<dyn Engine>)
        })
        .unwrap();
        let ds = synth_dataset(16, &man.bvalues, 20.0, 8);
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            // must be a dropped responder (Disconnected), not a 10 s hang
            let got = rx.recv_timeout(Duration::from_secs(10));
            assert!(
                matches!(got, Err(RecvTimeoutError::Disconnected)),
                "request {i} should fail fast once the pool is dead, got {got:?}"
            );
        }
        coord.shutdown();
    }

    #[test]
    fn output_buffers_are_recycled() {
        let (coord, man) = start_native(8, 10_000, 2);
        let ds = synth_dataset(64, &man.bvalues, 20.0, 7);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        // Responses are sent before the shard returns its buffer, so
        // poll briefly instead of racing that hand-back.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let pooled = coord.pooled_outputs();
            let signals = coord.pooled_signals();
            assert!(pooled <= 4, "output pool exceeded its bound: {pooled}");
            assert!(signals <= 4, "signal pool exceeded its bound: {signals}");
            if pooled >= 1 && signals >= 1 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "shards never returned buffers to the pools \
                 (outputs {pooled}, signals {signals})"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // the gauge-bearing snapshot sees what the raw counters cannot
        let snap = coord.snapshot();
        assert!(snap.pooled_outputs >= 1);
        assert!(snap.pooled_signals >= 1);
        assert_eq!(snap.queue_depth, 0, "all requests answered");
        let bare = coord.metrics().snapshot();
        assert_eq!(bare.pooled_outputs, 0, "bare counters cannot see the pools");
        coord.shutdown();
    }

    /// The lease slab's capacity-stability signature (the PR-3
    /// `McDropout` zero-alloc test style): once warm, >= 100 further
    /// leased submits must not allocate a single new request buffer.
    #[test]
    fn lease_lifecycle_reuses_buffers_with_stable_high_water() {
        let (coord, man) = start_native(8, 10_000, 2);
        let ds = synth_dataset(1, &man.bvalues, 20.0, 11);
        for i in 0..20u64 {
            let mut lease = coord.lease();
            lease.copy_from(ds.voxel(0));
            let rx = coord.submit_leased(i, lease).unwrap();
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let hw = coord.lease_high_water();
        assert!(hw >= 1, "warm-up must have populated the slab");
        for i in 0..120u64 {
            let mut lease = coord.lease();
            lease.copy_from(ds.voxel(0));
            let rx = coord.submit_leased(100 + i, lease).unwrap();
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert_eq!(
            coord.lease_high_water(),
            hw,
            "lease slab grew in steady state (allocation on the hot path)"
        );
        let snap = coord.snapshot();
        assert!(snap.pooled_requests >= 1, "reclaimed buffers are visible");
        coord.shutdown();
    }

    /// Dropping a lease without submitting returns the buffer to the
    /// slab instead of leaking it.
    #[test]
    fn dropping_an_unused_lease_returns_the_buffer() {
        let (coord, _man) = start_native(8, 1000, 1);
        assert_eq!(coord.pooled_requests(), 0);
        let lease = coord.lease();
        assert_eq!(coord.lease_high_water(), 1);
        assert_eq!(lease.signals().len(), coord.nb);
        drop(lease);
        assert_eq!(coord.pooled_requests(), 1, "abandoned lease came back");
        // and it is reused, not re-allocated
        let lease2 = coord.lease();
        assert_eq!(coord.lease_high_water(), 1);
        drop(lease2);
        coord.shutdown();
    }

    /// A leased submit that is rejected (wrong width is impossible by
    /// construction, so force backpressure) reclaims its buffer.
    #[test]
    fn rejected_leased_submit_reclaims_the_buffer() {
        let (coord, man) = start_native(64, 1, 1);
        let ds = synth_dataset(3, &man.bvalues, 20.0, 13);
        // first fills the only capacity slot...
        let mut l0 = coord.lease();
        l0.copy_from(ds.voxel(0));
        let _rx = coord.submit_leased(0, l0).unwrap();
        // ...hammer until one is rejected by the depth gate (the first
        // request may complete quickly, so loop until a rejection)
        let mut rejected = false;
        for i in 0..50u64 {
            let mut l = coord.lease();
            l.copy_from(ds.voxel(1));
            if coord.submit_leased(1 + i, l).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "capacity 1 must reject a same-instant burst");
        // the rejected buffer goes straight back to the slab (the
        // dispatcher's cut-time reclaim cannot have run for it)
        assert!(
            coord.pooled_requests() >= 1,
            "rejected lease must return its buffer"
        );
        coord.shutdown();
    }

    /// Every served batch was claimed exactly once, locally or by
    /// stealing — the new counters partition the batch total.
    #[test]
    fn claim_counters_partition_served_batches() {
        let (coord, man) = start_native(4, 100_000, 3);
        let n = 120;
        let ds = synth_dataset(n, &man.bvalues, 20.0, 14);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let snap = coord.snapshot();
        assert_eq!(snap.responses, n as u64);
        assert_eq!(
            snap.local_batches() + snap.stolen_batches(),
            snap.batches,
            "claims must partition batches: {:?}",
            snap.per_shard
        );
        // all answered -> every deque is empty
        assert!(snap.per_shard.iter().all(|s| s.deque_depth == 0));
        coord.shutdown();
    }

    /// The legacy shared queue survives behind `DispatchMode::SharedQueue`
    /// and produces identical per-voxel results (dispatch is a
    /// scheduling choice, not a numeric one).
    #[test]
    fn shared_queue_mode_serves_identically() {
        let (man, w) = fixture::tiny_fixture();
        let run = |mode: DispatchMode| -> Vec<f64> {
            let mut cfg = CoordinatorConfig::sharded(man.nb, 8, 3);
            cfg.batcher.queue_capacity = 100_000;
            cfg.batcher.max_wait = Duration::from_millis(1);
            cfg.dispatch = mode;
            let opts = EngineOpts {
                batch: Some(8),
                ..Default::default()
            };
            let coord = Coordinator::start(
                cfg,
                factory("native", man.clone(), w.clone(), opts).unwrap(),
            )
            .unwrap();
            let ds = synth_dataset(48, &man.bvalues, 20.0, 12);
            let rxs: Vec<_> = (0..48)
                .map(|i| {
                    coord
                        .submit(VoxelRequest {
                            id: i as u64,
                            signals: ds.voxel(i).to_vec(),
                        })
                        .unwrap()
                })
                .collect();
            let out: Vec<f64> = rxs
                .into_iter()
                .map(|rx| {
                    rx.recv_timeout(Duration::from_secs(10))
                        .unwrap()
                        .report
                        .get(crate::ivim::Param::D)
                        .mean
                })
                .collect();
            let snap = coord.snapshot();
            assert_eq!(snap.responses, 48);
            if mode == DispatchMode::SharedQueue {
                assert_eq!(snap.stolen_batches(), 0, "shared queue cannot steal");
                assert_eq!(snap.local_batches(), snap.batches);
            }
            coord.shutdown();
            out
        };
        assert_eq!(run(DispatchMode::Deques), run(DispatchMode::SharedQueue));
    }

    #[test]
    fn rejects_wrong_width() {
        let (coord, _) = start_native(8, 1000, 1);
        assert!(coord
            .submit(VoxelRequest {
                id: 0,
                signals: vec![0.0; 3],
            })
            .is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (coord, man) = start_native(64, 2, 2);
        let ds = synth_dataset(10, &man.bvalues, 20.0, 2);
        let mut accepted = 0;
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..10 {
            match coord.submit(VoxelRequest {
                id: i as u64,
                signals: ds.voxel(i).to_vec(),
            }) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure with capacity 2");
        // accepted requests still complete (deadline flush)
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert_eq!(
            coord.metrics().snapshot().rejected as usize
                + coord.metrics().snapshot().responses as usize,
            accepted + rejected
        );
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (coord, man) = start_native(64, 1000, 2);
        let ds = synth_dataset(5, &man.bvalues, 20.0, 3);
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        coord.shutdown(); // must flush the partial batch through a shard
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        }
    }

    #[test]
    fn factory_failure_propagates() {
        let cfg = CoordinatorConfig::for_batch(4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn factory_failure_propagates_sharded() {
        // One factory that fails for every shard: start() must join all
        // workers and surface the error instead of hanging.
        let cfg = CoordinatorConfig::sharded(4, 4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn stream_driver_guard_is_exclusive_and_releases_on_drop() {
        let (coord, _) = start_native(8, 1000, 1);
        let g = coord.stream_driver_guard().unwrap();
        assert!(
            coord.stream_driver_guard().is_err(),
            "a second concurrent driver must be rejected"
        );
        drop(g);
        // sequential drivers are fine
        let g2 = coord.stream_driver_guard().unwrap();
        drop(g2);
        coord.shutdown();
    }

    #[test]
    fn delay_estimate_cold_then_tracks_service_time() {
        let (coord, man) = start_native(8, 1000, 1);
        assert_eq!(
            coord.estimated_queue_delay_us(),
            0,
            "cold coordinator must estimate zero wait (never sheds)"
        );
        let ds = synth_dataset(8, &man.bvalues, 20.0, 21);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert!(
            coord.metrics().ewma_batch_us() > 0.0,
            "a served batch must seed the EWMA"
        );
        // queue drained -> no backlog -> estimate back to zero
        assert_eq!(coord.estimated_queue_delay_us(), 0);
        coord.shutdown();
    }

    #[test]
    fn batch_size_mismatch_rejected() {
        let (man, w) = fixture::tiny_fixture();
        let cfg = CoordinatorConfig::for_batch(man.nb, 8);
        // engine batch 16 != batcher batch 8
        let opts = EngineOpts {
            batch: Some(16),
            ..Default::default()
        };
        let r = Coordinator::start(cfg, factory("native", man, w, opts).unwrap());
        assert!(r.is_err());
    }
}
