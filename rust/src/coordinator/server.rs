//! The coordinator proper: a sharded pool of worker threads, each owning
//! its own inference engine, pulling formed batches from a shared queue
//! (work-stealing pull model) and recycling output buffers through a
//! shared pool.
//!
//! ```text
//! clients ──► submit() ──► dispatcher thread (owns the Batcher)
//!                               │ pushes full batches
//!                               ▼
//!                       ┌─ shared batch queue ─┐
//!                       ▼          ▼           ▼   each shard PULLS its
//!                   shard 0    shard 1 ... shard K-1  next batch when idle
//!                       │          │           │   (one Engine each,
//!                       └───── responses ──────┘    built in-thread)
//! ```
//!
//! The pull model is what keeps the datapath saturated under skewed load:
//! with dispatcher-push round-robin, one slow shard strands every batch
//! queued behind it while its siblings idle — exactly the imbalance
//! multi-sample inference amplifies, since all N mask samples ride on one
//! batch.  Here a batch is only ever claimed by a shard that is ready to
//! run it, so a stalled shard delays at most the single batch it already
//! holds.
//!
//! Engines are not `Send` (PJRT handles are `Rc`-based), so the
//! coordinator takes an engine *factory* and each shard constructs its
//! engine inside its own thread.  Shards run the two-phase hot path:
//! `execute_into` writes into an `InferOutput` recycled through a shared
//! [`OutputPool`], so steady-state serving performs no output allocation.
//! Each request carries its own response channel (one-shot style), so
//! cross-shard completion order never scrambles routing.
//!
//! Graceful shutdown drains everything: the dispatcher flushes the
//! batcher into the queue, closes the queue, and the coordinator joins
//! all threads — shards keep pulling until the closed queue is empty, so
//! no request admitted before `shutdown()` is dropped.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher, BatcherConfig, Pending};
use super::metrics::{MetricsSnapshot, ServingMetrics};
use super::uncertainty::{aggregate_voxel, Thresholds};
use crate::infer::{Engine, OutputPool};
use crate::util::pool::VecPool;

pub use super::uncertainty::UncertaintyReport;

/// A request: one voxel's normalised signals.
#[derive(Debug, Clone)]
pub struct VoxelRequest {
    pub id: u64,
    pub signals: Vec<f32>,
}

/// The response: aggregated prediction + uncertainty.
#[derive(Debug, Clone)]
pub struct VoxelResponse {
    pub id: u64,
    pub report: UncertaintyReport,
}

struct Envelope {
    req: VoxelRequest,
    resp_tx: Sender<VoxelResponse>,
    enqueued: Instant,
}

enum Msg {
    Request(Envelope),
    Shutdown,
}

/// Tag carried through the batcher for each real row.
type RowTag = (u64, Sender<VoxelResponse>, Instant);

/// The shared batch queue the shards pull from.  Closing it wakes every
/// puller; pullers drain remaining batches before observing the close.
struct WorkQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    batches: VecDeque<Batch<RowTag>>,
    closed: bool,
}

impl WorkQueue {
    fn new() -> Self {
        WorkQueue {
            state: Mutex::new(QueueState {
                batches: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a batch.  `Err` hands the batch back when the queue is
    /// already closed — that only happens when every shard is gone, and
    /// the caller must fail the batch's requests instead of stranding
    /// them (during normal shutdown the dispatcher itself closes the
    /// queue, and only after its final flush).
    fn push(&self, batch: Batch<RowTag>) -> Result<(), Batch<RowTag>> {
        let mut s = self.state.lock().expect("queue lock");
        if s.closed {
            return Err(batch);
        }
        s.batches.push_back(batch);
        drop(s);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking pull.  `None` only once the queue is closed *and* fully
    /// drained, so shutdown never drops an admitted batch.
    fn pull(&self) -> Option<Batch<RowTag>> {
        let mut s = self.state.lock().expect("queue lock");
        loop {
            if let Some(b) = s.batches.pop_front() {
                return Some(b);
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).expect("queue lock");
        }
    }

    fn close(&self) {
        self.state.lock().expect("queue lock").closed = true;
        self.ready.notify_all();
    }

    /// Non-blocking pop, ignoring the closed flag (last-shard-exit drain).
    fn try_pull(&self) -> Option<Batch<RowTag>> {
        self.state.lock().expect("queue lock").batches.pop_front()
    }
}

/// Runs when a shard thread exits for any reason — normal shutdown,
/// factory failure, or an engine panic unwinding the thread.  When the
/// *last* shard goes away, close and drain the queue so stranded batches
/// drop their responders (callers see an error instead of hanging
/// forever) and release their queue-depth slots.
struct ShardExitGuard {
    queue: Arc<WorkQueue>,
    depth: Arc<AtomicUsize>,
    alive: Arc<AtomicUsize>,
}

impl Drop for ShardExitGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queue.close();
            while let Some(batch) = self.queue.try_pull() {
                for _ in batch.tags {
                    self.depth.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub thresholds: Thresholds,
    /// Voxel width (number of b-values) — validated on submit.
    pub nb: usize,
    /// Worker shards, each owning one engine (min 1).
    pub shards: usize,
}

impl CoordinatorConfig {
    pub fn for_batch(nb: usize, batch_size: usize) -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig {
                batch_size,
                ..Default::default()
            },
            thresholds: Thresholds::default(),
            nb,
            shards: 1,
        }
    }

    /// `for_batch` with a K-shard worker pool.
    pub fn sharded(nb: usize, batch_size: usize, shards: usize) -> Self {
        CoordinatorConfig {
            shards: shards.max(1),
            ..Self::for_batch(nb, batch_size)
        }
    }
}

/// Handle to a running coordinator.  Dropping shuts the pool down.
pub struct Coordinator {
    tx: Sender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
    shard_workers: Vec<JoinHandle<()>>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    pool: Arc<OutputPool>,
    signal_pool: Arc<VecPool>,
    capacity: usize,
    nb: usize,
    shards: usize,
}

/// Everything one shard worker needs, bundled so the spawn loop stays
/// readable.
struct ShardCtx {
    index: usize,
    queue: Arc<WorkQueue>,
    pool: Arc<OutputPool>,
    signal_pool: Arc<VecPool>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    thresholds: Thresholds,
    batch_size: usize,
}

impl Coordinator {
    /// Start the pool.  `engine_factory` runs once per shard, on that
    /// shard's thread, and must produce engines whose `batch_size()`
    /// equals the batcher's.
    pub fn start<F>(cfg: CoordinatorConfig, engine_factory: F) -> anyhow::Result<Coordinator>
    where
        F: Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let metrics = Arc::new(ServingMetrics::with_shards(shards));
        let depth = Arc::new(AtomicUsize::new(0));
        let capacity = cfg.batcher.queue_capacity;
        let nb = cfg.nb;
        let factory = Arc::new(engine_factory);
        let queue = Arc::new(WorkQueue::new());
        // Enough pooled buffers for every shard to hold one in flight
        // plus one ready for hand-off.
        let pool = Arc::new(OutputPool::new(2 * shards));
        // Same bound for the recycled batch *signal* buffers (one being
        // filled by the dispatcher + one in flight per shard).
        let signal_pool = Arc::new(VecPool::new(2 * shards));

        // Spawn the shard workers first; each builds its engine in-thread
        // and reports readiness (engine batch size) or the build error.
        let (ready_tx, ready_rx) = channel::<(usize, anyhow::Result<usize>)>();
        let alive = Arc::new(AtomicUsize::new(shards));
        let mut shard_workers = Vec::with_capacity(shards);
        for k in 0..shards {
            let ctx = ShardCtx {
                index: k,
                queue: Arc::clone(&queue),
                pool: Arc::clone(&pool),
                signal_pool: Arc::clone(&signal_pool),
                metrics: Arc::clone(&metrics),
                depth: Arc::clone(&depth),
                thresholds: cfg.thresholds,
                batch_size: cfg.batcher.batch_size,
            };
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let guard = ShardExitGuard {
                queue: Arc::clone(&queue),
                depth: Arc::clone(&depth),
                alive: Arc::clone(&alive),
            };
            let spawned = std::thread::Builder::new()
                .name(format!("uivim-shard-{k}"))
                .spawn(move || {
                    // dropped on every exit path, including panics
                    let _guard = guard;
                    let mut engine = match (*factory)() {
                        Ok(e) => {
                            let _ = ready.send((k, Ok(e.batch_size())));
                            e
                        }
                        Err(e) => {
                            let _ = ready.send((k, Err(e)));
                            return;
                        }
                    };
                    shard_loop(ctx, engine.as_mut());
                });
            match spawned {
                Ok(h) => shard_workers.push(h),
                Err(e) => {
                    // don't leave already-spawned shards parked on the
                    // queue forever
                    queue.close();
                    for w in shard_workers {
                        let _ = w.join();
                    }
                    return Err(e.into());
                }
            }
        }
        drop(ready_tx);

        // Wait for every shard to build (or fail fast, draining the rest).
        let mut build_err = None;
        for _ in 0..shards {
            match ready_rx.recv() {
                Ok((_, Ok(engine_batch))) => {
                    if engine_batch != cfg.batcher.batch_size {
                        build_err = Some(anyhow::anyhow!(
                            "engine batch size {engine_batch} != batcher {}",
                            cfg.batcher.batch_size
                        ));
                    }
                }
                Ok((k, Err(e))) => {
                    build_err = Some(e.context(format!("shard {k} engine construction")));
                }
                Err(_) => {
                    build_err =
                        Some(anyhow::anyhow!("a shard died during engine construction"));
                    break;
                }
            }
        }
        if let Some(e) = build_err {
            queue.close();
            for w in shard_workers {
                let _ = w.join();
            }
            return Err(e);
        }

        // Dispatcher thread: owns the batcher, feeds the shared queue.
        let (tx, rx) = channel::<Msg>();
        let d_metrics = Arc::clone(&metrics);
        let d_depth = Arc::clone(&depth);
        let d_queue = Arc::clone(&queue);
        let d_signal_pool = Arc::clone(&signal_pool);
        let d_cfg = cfg.clone();
        let dispatcher = match std::thread::Builder::new()
            .name("uivim-dispatcher".into())
            .spawn(move || {
                dispatcher_loop(d_cfg, rx, &d_queue, &d_metrics, &d_depth, d_signal_pool)
            }) {
            Ok(h) => h,
            Err(e) => {
                // shards are parked on the queue: release and join them
                queue.close();
                for w in shard_workers {
                    let _ = w.join();
                }
                return Err(e.into());
            }
        };

        Ok(Coordinator {
            tx,
            dispatcher: Some(dispatcher),
            shard_workers,
            metrics,
            depth,
            pool,
            signal_pool,
            capacity,
            nb,
            shards,
        })
    }

    /// Submit a voxel; returns a receiver for the response, or an error
    /// immediately under backpressure.
    pub fn submit(&self, req: VoxelRequest) -> anyhow::Result<Receiver<VoxelResponse>> {
        anyhow::ensure!(
            req.signals.len() == self.nb,
            "voxel has {} values, expected {}",
            req.signals.len(),
            self.nb
        );
        if self.depth.load(Ordering::Acquire) >= self.capacity {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("queue full ({} requests)", self.capacity);
        }
        let (resp_tx, resp_rx) = channel();
        self.depth.fetch_add(1, Ordering::AcqRel);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Request(Envelope {
                req,
                resp_tx,
                enqueued: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(resp_rx)
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, req: VoxelRequest) -> anyhow::Result<VoxelResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped the request"))
    }

    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Number of worker shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Current queue depth (requests admitted but not yet answered).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Idle recycled output buffers (observability for the pool).
    pub fn pooled_outputs(&self) -> usize {
        self.pool.idle()
    }

    /// Idle recycled batch signal buffers.
    pub fn pooled_signals(&self) -> usize {
        self.signal_pool.idle()
    }

    /// Point-in-time metrics **including the live gauges** (pool sizes,
    /// pending queue depth) that the raw counter block cannot see.
    /// Prefer this over `metrics().snapshot()` for dashboards.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        s.pooled_outputs = self.pooled_outputs();
        s.pooled_signals = self.pooled_signals();
        s.queue_depth = self.queue_depth();
        s
    }

    fn stop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.shard_workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Graceful shutdown: flush pending work through the queue, join the
    /// dispatcher and all workers.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Dispatcher: batch formation + shared-queue hand-off.
fn dispatcher_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<Msg>,
    queue: &WorkQueue,
    metrics: &ServingMetrics,
    depth: &AtomicUsize,
    signal_pool: Arc<VecPool>,
) {
    let mut batcher: Batcher<RowTag> =
        Batcher::with_pool(cfg.batcher.clone(), cfg.nb, signal_pool);
    let mut shutting_down = false;

    loop {
        // Wait for work, bounded by the oldest request's deadline.
        let timeout = match batcher.oldest_wait(Instant::now()) {
            Some(w) => cfg.batcher.max_wait.saturating_sub(w),
            None => {
                if shutting_down {
                    break;
                }
                Duration::from_millis(50)
            }
        };
        let handle = |msg: Msg, batcher: &mut Batcher<RowTag>, shutting_down: &mut bool| {
            match msg {
                Msg::Request(env) => {
                    let pend = Pending {
                        signals: env.req.signals,
                        tag: (env.req.id, env.resp_tx, env.enqueued),
                        enqueued: env.enqueued,
                    };
                    // capacity is enforced on submit; push cannot fail
                    // here unless capacity raced — drop in that case.
                    if batcher.push(pend).is_err() {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        depth.fetch_sub(1, Ordering::AcqRel);
                    }
                }
                Msg::Shutdown => *shutting_down = true,
            }
        };
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                handle(msg, &mut batcher, &mut shutting_down);
                // Greedily drain whatever else is already queued on the
                // channel: requests age in the channel too, and cutting
                // before draining would degrade into 1-row batches under
                // bursty load.
                while !batcher.is_full() {
                    match rx.try_recv() {
                        Ok(msg) => handle(msg, &mut batcher, &mut shutting_down),
                        Err(_) => break,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                shutting_down = true;
            }
        }

        // Cut every ready batch (all pending on shutdown) into the shared
        // queue; whichever shard is free next claims it.  Batch/padding
        // counters are recorded by the shard that actually serves the
        // batch, so dropped batches never inflate the aggregate metrics.
        while (shutting_down && !batcher.is_empty()) || batcher.ready(Instant::now()) {
            let Some(batch) = batcher.cut() else { break };
            if let Err(batch) = queue.push(batch) {
                // every shard is dead: fail these requests fast by
                // dropping their responders and releasing their slots
                for _ in batch.tags {
                    depth.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }

        if shutting_down && batcher.is_empty() {
            break;
        }
    }

    // Close the queue: shards drain whatever is left, then exit.
    queue.close();
}

/// One shard: pull batches from the shared queue, run the engine into a
/// recycled output buffer, answer requests.
fn shard_loop(ctx: ShardCtx, engine: &mut dyn Engine) {
    debug_assert_eq!(engine.batch_size(), ctx.batch_size);
    let shard = ctx.metrics.shard(ctx.index);
    let n_samples = engine.n_samples();
    while let Some(batch) = ctx.queue.pull() {
        let Batch { signals, tags, real } = batch;
        let mut out = ctx.pool.take(n_samples, ctx.batch_size);
        let t0 = Instant::now();
        // A panicking engine must not leak this batch's queue-depth
        // slots: release them, then let the unwind continue so the
        // thread's ShardExitGuard handles the rest of the queue.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.execute_into(&signals, &mut out)
        }));
        let run = match run {
            Ok(r) => r,
            Err(payload) => {
                for _ in &tags {
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                }
                std::panic::resume_unwind(payload);
            }
        };
        match run {
            Ok(()) => {
                let batch_us = t0.elapsed().as_micros() as u64;
                ctx.metrics.batch_latency.record_us(batch_us);
                ctx.metrics.batches.fetch_add(1, Ordering::Relaxed);
                ctx.metrics.padded_rows.fetch_add(
                    (ctx.batch_size - real) as u64,
                    Ordering::Relaxed,
                );
                shard.busy_us.fetch_add(batch_us, Ordering::Relaxed);
                shard.batches.fetch_add(1, Ordering::Relaxed);
                for (row, (id, resp_tx, enq)) in tags.into_iter().enumerate() {
                    let report = aggregate_voxel(&out, row, &ctx.thresholds);
                    ctx.metrics
                        .request_latency
                        .record_us(enq.elapsed().as_micros() as u64);
                    ctx.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    shard.responses.fetch_add(1, Ordering::Relaxed);
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                    let _ = resp_tx.send(VoxelResponse { id, report });
                }
            }
            Err(e) => {
                eprintln!("uivim-shard-{}: engine failure: {e:#}", ctx.index);
                shard.engine_errors.fetch_add(1, Ordering::Relaxed);
                for (_, _resp_tx, _) in tags.into_iter() {
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                    // dropping resp_tx signals the error to the caller
                }
            }
        }
        ctx.pool.put(out);
        // hand the batch's signal buffer back for the dispatcher's next cut
        ctx.signal_pool.put(signals);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::registry::{factory, EngineOpts};
    use crate::infer::InferOutput;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::Manifest;
    use crate::testing::fixture;

    fn start_native(
        batch: usize,
        queue_capacity: usize,
        shards: usize,
    ) -> (Coordinator, Manifest) {
        let (man, w) = fixture::tiny_fixture();
        let man2 = man.clone();
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
        cfg.batcher.queue_capacity = queue_capacity;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let opts = EngineOpts {
            batch: Some(batch),
            ..Default::default()
        };
        let coord = Coordinator::start(cfg, factory("native", man2, w, opts).unwrap()).unwrap();
        (coord, man)
    }

    #[test]
    fn serves_requests_end_to_end() {
        let (coord, man) = start_native(8, 1000, 1);
        let ds = synth_dataset(20, &man.bvalues, 20.0, 1);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap(),
            );
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
            let d = resp.report.get(crate::ivim::Param::D);
            assert!(d.mean >= 0.0 && d.mean <= 0.005);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, 20);
        assert!(snap.batches >= 3); // 20 voxels / batch 8
        coord.shutdown();
    }

    #[test]
    fn sharded_pool_partitions_every_response() {
        let (coord, man) = start_native(4, 100_000, 3);
        assert_eq!(coord.shards(), 3);
        let n = 120;
        let ds = synth_dataset(n, &man.bvalues, 20.0, 4);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, n as u64);
        assert_eq!(snap.per_shard.len(), 3);
        let shard_responses: u64 = snap.per_shard.iter().map(|s| s.responses).sum();
        assert_eq!(shard_responses, n as u64, "every response owned by a shard");
        // Pull scheduling: batch ownership is demand-driven, so only the
        // totals are deterministic — every batch was claimed by exactly
        // one shard.
        let shard_batches: u64 = snap.per_shard.iter().map(|s| s.batches).sum();
        assert_eq!(shard_batches, snap.batches);
        coord.shutdown();
    }

    #[test]
    fn sharded_results_match_single_worker() {
        let (c1, man) = start_native(8, 10_000, 1);
        let (c4, _) = start_native(8, 10_000, 4);
        let ds = synth_dataset(64, &man.bvalues, 20.0, 5);
        let collect = |coord: &Coordinator| -> Vec<f64> {
            let rxs: Vec<_> = (0..64)
                .map(|i| {
                    coord
                        .submit(VoxelRequest {
                            id: i as u64,
                            signals: ds.voxel(i).to_vec(),
                        })
                        .unwrap()
                })
                .collect();
            rxs.into_iter()
                .map(|rx| {
                    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    r.report.get(crate::ivim::Param::D).mean
                })
                .collect()
        };
        let a = collect(&c1);
        let b = collect(&c4);
        // Per-voxel results are unchanged by sharding: identical engines,
        // identical per-voxel math, batch membership does not leak.
        // (Batch *padding* rows never land on real voxels' outputs.)
        assert_eq!(a, b);
        c1.shutdown();
        c4.shutdown();
    }

    /// The point of the pull model: a stalled shard must not strand
    /// batches behind it.  One shard sleeps 25 ms per batch; under
    /// round-robin half the batches would queue behind it, under pull the
    /// fast shard drains nearly everything.
    #[test]
    fn slow_shard_does_not_strand_batches() {
        struct SlowEngine {
            inner: Box<dyn Engine>,
            delay: Duration,
        }
        impl Engine for SlowEngine {
            fn name(&self) -> &str {
                "slow-wrapper"
            }
            fn batch_size(&self) -> usize {
                self.inner.batch_size()
            }
            fn n_samples(&self) -> usize {
                self.inner.n_samples()
            }
            fn execute_into(
                &mut self,
                signals: &[f32],
                out: &mut InferOutput,
            ) -> anyhow::Result<()> {
                std::thread::sleep(self.delay);
                self.inner.execute_into(signals, out)
            }
        }

        let (man, w) = fixture::tiny_fixture();
        let batch = 4usize;
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, 2);
        cfg.batcher.queue_capacity = 100_000;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let built = Arc::new(AtomicUsize::new(0));
        let inner = factory(
            "native",
            man.clone(),
            w,
            EngineOpts {
                batch: Some(batch),
                ..Default::default()
            },
        )
        .unwrap();
        let coord = Coordinator::start(cfg, move || {
            // the first engine constructed is the slow one
            let delay = if built.fetch_add(1, Ordering::SeqCst) == 0 {
                Duration::from_millis(25)
            } else {
                Duration::ZERO
            };
            Ok(Box::new(SlowEngine {
                inner: inner()?,
                delay,
            }) as Box<dyn Engine>)
        })
        .unwrap();

        let n = 80; // 20 batches of 4
        let ds = synth_dataset(n, &man.bvalues, 20.0, 6);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        let snap = coord.metrics().snapshot();
        let batches: Vec<u64> = snap.per_shard.iter().map(|s| s.batches).collect();
        let fast = *batches.iter().max().unwrap();
        let total: u64 = batches.iter().sum();
        assert_eq!(snap.responses, n as u64);
        // Round-robin would split exactly 50/50; pull lets the fast
        // shard take the majority (in practice nearly everything — the
        // slow shard serves a handful at 25 ms each while the fast one
        // clears microsecond batches).  Strictly-more-than-half is the
        // scheduling-noise-proof bound.
        assert!(
            fast > total / 2,
            "fast shard should dominate under pull dispatch: {batches:?}"
        );
        coord.shutdown();
    }

    /// If every shard dies (engine panic), pending and future batches
    /// must fail fast — responders dropped so callers see an error —
    /// instead of hanging forever on a queue nobody will ever drain.
    #[test]
    fn dead_pool_fails_requests_instead_of_hanging() {
        struct PanicEngine {
            inner: Box<dyn Engine>,
        }
        impl Engine for PanicEngine {
            fn name(&self) -> &str {
                "panic-wrapper"
            }
            fn batch_size(&self) -> usize {
                self.inner.batch_size()
            }
            fn n_samples(&self) -> usize {
                self.inner.n_samples()
            }
            fn execute_into(
                &mut self,
                _signals: &[f32],
                _out: &mut InferOutput,
            ) -> anyhow::Result<()> {
                panic!("injected engine failure");
            }
        }

        let (man, w) = fixture::tiny_fixture();
        let batch = 4usize;
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, 1);
        cfg.batcher.queue_capacity = 10_000;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let inner = factory(
            "native",
            man.clone(),
            w,
            EngineOpts {
                batch: Some(batch),
                ..Default::default()
            },
        )
        .unwrap();
        let coord = Coordinator::start(cfg, move || {
            Ok(Box::new(PanicEngine { inner: inner()? }) as Box<dyn Engine>)
        })
        .unwrap();
        let ds = synth_dataset(16, &man.bvalues, 20.0, 8);
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            // must be a dropped responder (Disconnected), not a 10 s hang
            let got = rx.recv_timeout(Duration::from_secs(10));
            assert!(
                matches!(got, Err(RecvTimeoutError::Disconnected)),
                "request {i} should fail fast once the pool is dead, got {got:?}"
            );
        }
        coord.shutdown();
    }

    #[test]
    fn output_buffers_are_recycled() {
        let (coord, man) = start_native(8, 10_000, 2);
        let ds = synth_dataset(64, &man.bvalues, 20.0, 7);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        // Responses are sent before the shard returns its buffer, so
        // poll briefly instead of racing that hand-back.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let pooled = coord.pooled_outputs();
            let signals = coord.pooled_signals();
            assert!(pooled <= 4, "output pool exceeded its bound: {pooled}");
            assert!(signals <= 4, "signal pool exceeded its bound: {signals}");
            if pooled >= 1 && signals >= 1 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "shards never returned buffers to the pools \
                 (outputs {pooled}, signals {signals})"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // the gauge-bearing snapshot sees what the raw counters cannot
        let snap = coord.snapshot();
        assert!(snap.pooled_outputs >= 1);
        assert!(snap.pooled_signals >= 1);
        assert_eq!(snap.queue_depth, 0, "all requests answered");
        let bare = coord.metrics().snapshot();
        assert_eq!(bare.pooled_outputs, 0, "bare counters cannot see the pools");
        coord.shutdown();
    }

    #[test]
    fn rejects_wrong_width() {
        let (coord, _) = start_native(8, 1000, 1);
        assert!(coord
            .submit(VoxelRequest {
                id: 0,
                signals: vec![0.0; 3],
            })
            .is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (coord, man) = start_native(64, 2, 2);
        let ds = synth_dataset(10, &man.bvalues, 20.0, 2);
        let mut accepted = 0;
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..10 {
            match coord.submit(VoxelRequest {
                id: i as u64,
                signals: ds.voxel(i).to_vec(),
            }) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure with capacity 2");
        // accepted requests still complete (deadline flush)
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert_eq!(
            coord.metrics().snapshot().rejected as usize
                + coord.metrics().snapshot().responses as usize,
            accepted + rejected
        );
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (coord, man) = start_native(64, 1000, 2);
        let ds = synth_dataset(5, &man.bvalues, 20.0, 3);
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        coord.shutdown(); // must flush the partial batch through a shard
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        }
    }

    #[test]
    fn factory_failure_propagates() {
        let cfg = CoordinatorConfig::for_batch(4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn factory_failure_propagates_sharded() {
        // One factory that fails for every shard: start() must join all
        // workers and surface the error instead of hanging.
        let cfg = CoordinatorConfig::sharded(4, 4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn batch_size_mismatch_rejected() {
        let (man, w) = fixture::tiny_fixture();
        let cfg = CoordinatorConfig::for_batch(man.nb, 8);
        // engine batch 16 != batcher batch 8
        let opts = EngineOpts {
            batch: Some(16),
            ..Default::default()
        };
        let r = Coordinator::start(cfg, factory("native", man, w, opts).unwrap());
        assert!(r.is_err());
    }
}
