//! The coordinator proper: a worker thread that owns the inference
//! engine, fed by a dynamic batcher, with backpressure and metrics.
//!
//! Engines are not `Send` (PJRT handles are `Rc`-based), so the
//! coordinator takes an engine *factory* and constructs the engine inside
//! the worker thread.  Requests travel over an mpsc channel; each request
//! carries its own response channel (one-shot style).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batcher, BatcherConfig, Pending};
use super::metrics::ServingMetrics;
use super::uncertainty::{aggregate_voxel, Thresholds, UncertaintyReport};
use crate::infer::Engine;

/// A request: one voxel's normalised signals.
#[derive(Debug, Clone)]
pub struct VoxelRequest {
    pub id: u64,
    pub signals: Vec<f32>,
}

/// The response: aggregated prediction + uncertainty.
#[derive(Debug, Clone)]
pub struct VoxelResponse {
    pub id: u64,
    pub report: UncertaintyReport,
}

struct Envelope {
    req: VoxelRequest,
    resp_tx: Sender<VoxelResponse>,
    enqueued: Instant,
}

enum Msg {
    Request(Envelope),
    Shutdown,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub thresholds: Thresholds,
    /// Voxel width (number of b-values) — validated on submit.
    pub nb: usize,
}

impl CoordinatorConfig {
    pub fn for_batch(nb: usize, batch_size: usize) -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig {
                batch_size,
                ..Default::default()
            },
            thresholds: Thresholds::default(),
            nb,
        }
    }
}

/// Handle to a running coordinator.  Dropping shuts the worker down.
pub struct Coordinator {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    capacity: usize,
    nb: usize,
}

impl Coordinator {
    /// Start the worker.  `engine_factory` runs on the worker thread and
    /// must produce an engine whose `batch_size()` equals the batcher's.
    pub fn start<F>(cfg: CoordinatorConfig, engine_factory: F) -> anyhow::Result<Coordinator>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn Engine>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(ServingMetrics::new());
        let depth = Arc::new(AtomicUsize::new(0));
        let capacity = cfg.batcher.queue_capacity;
        let nb = cfg.nb;
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();

        let m2 = Arc::clone(&metrics);
        let d2 = Arc::clone(&depth);
        let worker = std::thread::Builder::new()
            .name("uivim-coordinator".into())
            .spawn(move || {
                let mut engine = match engine_factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(cfg, rx, engine.as_mut(), &m2, &d2);
            })?;

        // Wait for the engine to build (or fail fast).
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during engine construction"))??;

        Ok(Coordinator {
            tx,
            worker: Some(worker),
            metrics,
            depth,
            capacity,
            nb,
        })
    }

    /// Submit a voxel; returns a receiver for the response, or an error
    /// immediately under backpressure.
    pub fn submit(&self, req: VoxelRequest) -> anyhow::Result<Receiver<VoxelResponse>> {
        anyhow::ensure!(
            req.signals.len() == self.nb,
            "voxel has {} values, expected {}",
            req.signals.len(),
            self.nb
        );
        if self.depth.load(Ordering::Acquire) >= self.capacity {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("queue full ({} requests)", self.capacity);
        }
        let (resp_tx, resp_rx) = channel();
        self.depth.fetch_add(1, Ordering::AcqRel);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Request(Envelope {
                req,
                resp_tx,
                enqueued: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(resp_rx)
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, req: VoxelRequest) -> anyhow::Result<VoxelResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped the request"))
    }

    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Current queue depth (requests admitted but not yet answered).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Graceful shutdown: flush pending work, join the worker.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<Msg>,
    engine: &mut dyn Engine,
    metrics: &ServingMetrics,
    depth: &AtomicUsize,
) {
    assert_eq!(
        engine.batch_size(),
        cfg.batcher.batch_size,
        "engine batch size must match the batcher"
    );
    let mut batcher: Batcher<(u64, Sender<VoxelResponse>, Instant)> =
        Batcher::new(cfg.batcher.clone(), cfg.nb);
    let mut shutting_down = false;

    loop {
        // Wait for work, bounded by the oldest request's deadline.
        let timeout = match batcher.oldest_wait(Instant::now()) {
            Some(w) => cfg.batcher.max_wait.saturating_sub(w),
            None => {
                if shutting_down {
                    break;
                }
                Duration::from_millis(50)
            }
        };
        let handle = |msg: Msg, batcher: &mut Batcher<_>, shutting_down: &mut bool| {
            match msg {
                Msg::Request(env) => {
                    let pend = Pending {
                        signals: env.req.signals,
                        tag: (env.req.id, env.resp_tx, env.enqueued),
                        enqueued: env.enqueued,
                    };
                    // capacity is enforced on submit; push cannot fail
                    // here unless capacity raced — drop in that case.
                    if batcher.push(pend).is_err() {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        depth.fetch_sub(1, Ordering::AcqRel);
                    }
                }
                Msg::Shutdown => *shutting_down = true,
            }
        };
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                handle(msg, &mut batcher, &mut shutting_down);
                // Greedily drain whatever else is already queued on the
                // channel: requests age in the channel too, and cutting
                // before draining would degrade into 1-row batches under
                // bursty load.
                while !batcher.is_full() {
                    match rx.try_recv() {
                        Ok(msg) => handle(msg, &mut batcher, &mut shutting_down),
                        Err(_) => break,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                shutting_down = true;
            }
        }

        // Cut and process every ready batch (all pending on shutdown).
        while (shutting_down && !batcher.is_empty()) || batcher.ready(Instant::now()) {
            let Some(batch) = batcher.cut() else { break };
            let t0 = Instant::now();
            match engine.infer_batch(&batch.signals) {
                Ok(out) => {
                    let batch_us = t0.elapsed().as_micros() as u64;
                    metrics.batch_latency.record_us(batch_us);
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    metrics.padded_rows.fetch_add(
                        (engine.batch_size() - batch.real) as u64,
                        Ordering::Relaxed,
                    );
                    for (row, (id, resp_tx, enq)) in batch.tags.into_iter().enumerate() {
                        let report = aggregate_voxel(&out, row, &cfg.thresholds);
                        metrics
                            .request_latency
                            .record_us(enq.elapsed().as_micros() as u64);
                        metrics.responses.fetch_add(1, Ordering::Relaxed);
                        depth.fetch_sub(1, Ordering::AcqRel);
                        let _ = resp_tx.send(VoxelResponse { id, report });
                    }
                }
                Err(e) => {
                    log::error!("engine failure: {e}");
                    for (_, _resp_tx, _) in batch.tags.into_iter() {
                        depth.fetch_sub(1, Ordering::AcqRel);
                        // dropping resp_tx signals the error to the caller
                    }
                }
            }
        }

        if shutting_down && batcher.is_empty() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::native::NativeEngine;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::{artifacts_root, Manifest};
    use crate::model::Weights;

    fn start_native(batch: usize, queue_capacity: usize) -> Option<(Coordinator, Manifest)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let man = Manifest::load(&dir).unwrap();
        let man2 = man.clone();
        let mut cfg = CoordinatorConfig::for_batch(man.nb, batch);
        cfg.batcher.queue_capacity = queue_capacity;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let coord = Coordinator::start(cfg, move || {
            let w = Weights::load_init(&man2)?;
            Ok(Box::new(NativeEngine::with_batch(&man2, &w, batch)?) as Box<dyn Engine>)
        })
        .unwrap();
        Some((coord, man))
    }

    #[test]
    fn serves_requests_end_to_end() {
        let Some((coord, man)) = start_native(8, 1000) else {
            return;
        };
        let ds = synth_dataset(20, &man.bvalues, 20.0, 1);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap(),
            );
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
            let d = resp.report.get(crate::ivim::Param::D);
            assert!(d.mean >= 0.0 && d.mean <= 0.005);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, 20);
        assert!(snap.batches >= 3); // 20 voxels / batch 8
        coord.shutdown();
    }

    #[test]
    fn rejects_wrong_width() {
        let Some((coord, _)) = start_native(8, 1000) else {
            return;
        };
        assert!(coord
            .submit(VoxelRequest {
                id: 0,
                signals: vec![0.0; 3],
            })
            .is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let Some((coord, man)) = start_native(64, 2) else {
            return;
        };
        let ds = synth_dataset(10, &man.bvalues, 20.0, 2);
        let mut accepted = 0;
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..10 {
            match coord.submit(VoxelRequest {
                id: i as u64,
                signals: ds.voxel(i).to_vec(),
            }) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure with capacity 2");
        // accepted requests still complete (deadline flush)
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert_eq!(
            coord.metrics().snapshot().rejected as usize
                + coord.metrics().snapshot().responses as usize,
            accepted + rejected
        );
    }

    #[test]
    fn shutdown_flushes_pending() {
        let Some((coord, man)) = start_native(64, 1000) else {
            return;
        };
        let ds = synth_dataset(5, &man.bvalues, 20.0, 3);
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        coord.shutdown(); // must flush the partial batch
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        }
    }

    #[test]
    fn factory_failure_propagates() {
        let cfg = CoordinatorConfig::for_batch(4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }
}
