//! The coordinator proper: a sharded pool of worker threads, each owning
//! its own inference engine, fed by a dynamic batcher with backpressure
//! and per-shard metrics.
//!
//! ```text
//! clients ──► submit() ──► dispatcher thread (owns the Batcher)
//!                               │ round-robin full batches
//!                ┌──────────────┼──────────────┐
//!                ▼              ▼              ▼
//!            shard 0        shard 1   ...  shard K-1     (each owns an
//!                │              │              │          Engine built
//!                └──────── responses ──────────┘          in-thread)
//! ```
//!
//! Engines are not `Send` (PJRT handles are `Rc`-based), so the
//! coordinator takes an engine *factory* and each shard constructs its
//! engine inside its own thread.  Requests travel over an mpsc channel;
//! each request carries its own response channel (one-shot style), so
//! cross-shard completion order never scrambles routing.
//!
//! Graceful shutdown drains everything: the dispatcher flushes the
//! batcher, forwards the final partial batch, closes every shard channel
//! and the coordinator joins all threads — no request admitted before
//! `shutdown()` is dropped.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher, BatcherConfig, Pending};
use super::metrics::ServingMetrics;
use super::uncertainty::{aggregate_voxel, Thresholds};
use crate::infer::Engine;

pub use super::uncertainty::UncertaintyReport;

/// A request: one voxel's normalised signals.
#[derive(Debug, Clone)]
pub struct VoxelRequest {
    pub id: u64,
    pub signals: Vec<f32>,
}

/// The response: aggregated prediction + uncertainty.
#[derive(Debug, Clone)]
pub struct VoxelResponse {
    pub id: u64,
    pub report: UncertaintyReport,
}

struct Envelope {
    req: VoxelRequest,
    resp_tx: Sender<VoxelResponse>,
    enqueued: Instant,
}

enum Msg {
    Request(Envelope),
    Shutdown,
}

/// Tag carried through the batcher for each real row.
type RowTag = (u64, Sender<VoxelResponse>, Instant);

/// Work unit sent to a shard: a fully formed (padded) batch.
enum ShardMsg {
    Batch(Batch<RowTag>),
    Shutdown,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    pub thresholds: Thresholds,
    /// Voxel width (number of b-values) — validated on submit.
    pub nb: usize,
    /// Worker shards, each owning one engine (min 1).
    pub shards: usize,
}

impl CoordinatorConfig {
    pub fn for_batch(nb: usize, batch_size: usize) -> Self {
        CoordinatorConfig {
            batcher: BatcherConfig {
                batch_size,
                ..Default::default()
            },
            thresholds: Thresholds::default(),
            nb,
            shards: 1,
        }
    }

    /// `for_batch` with a K-shard worker pool.
    pub fn sharded(nb: usize, batch_size: usize, shards: usize) -> Self {
        CoordinatorConfig {
            shards: shards.max(1),
            ..Self::for_batch(nb, batch_size)
        }
    }
}

/// Handle to a running coordinator.  Dropping shuts the pool down.
pub struct Coordinator {
    tx: Sender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
    shard_workers: Vec<JoinHandle<()>>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    capacity: usize,
    nb: usize,
    shards: usize,
}

/// Everything one shard worker needs, bundled so the spawn loop stays
/// readable.
struct ShardCtx {
    index: usize,
    rx: Receiver<ShardMsg>,
    metrics: Arc<ServingMetrics>,
    depth: Arc<AtomicUsize>,
    thresholds: Thresholds,
    batch_size: usize,
}

impl Coordinator {
    /// Start the pool.  `engine_factory` runs once per shard, on that
    /// shard's thread, and must produce engines whose `batch_size()`
    /// equals the batcher's.
    pub fn start<F>(cfg: CoordinatorConfig, engine_factory: F) -> anyhow::Result<Coordinator>
    where
        F: Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let metrics = Arc::new(ServingMetrics::with_shards(shards));
        let depth = Arc::new(AtomicUsize::new(0));
        let capacity = cfg.batcher.queue_capacity;
        let nb = cfg.nb;
        let factory = Arc::new(engine_factory);

        // Spawn the shard workers first; each builds its engine in-thread
        // and reports readiness (engine batch size) or the build error.
        let (ready_tx, ready_rx) = channel::<(usize, anyhow::Result<usize>)>();
        let mut shard_txs = Vec::with_capacity(shards);
        let mut shard_workers = Vec::with_capacity(shards);
        for k in 0..shards {
            let (btx, brx) = channel::<ShardMsg>();
            shard_txs.push(btx);
            let ctx = ShardCtx {
                index: k,
                rx: brx,
                metrics: Arc::clone(&metrics),
                depth: Arc::clone(&depth),
                thresholds: cfg.thresholds,
                batch_size: cfg.batcher.batch_size,
            };
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            shard_workers.push(
                std::thread::Builder::new()
                    .name(format!("uivim-shard-{k}"))
                    .spawn(move || {
                        let mut engine = match (*factory)() {
                            Ok(e) => {
                                let _ = ready.send((k, Ok(e.batch_size())));
                                e
                            }
                            Err(e) => {
                                let _ = ready.send((k, Err(e)));
                                return;
                            }
                        };
                        shard_loop(ctx, engine.as_mut());
                    })?,
            );
        }
        drop(ready_tx);

        // Wait for every shard to build (or fail fast, draining the rest).
        let mut build_err = None;
        for _ in 0..shards {
            match ready_rx.recv() {
                Ok((_, Ok(engine_batch))) => {
                    if engine_batch != cfg.batcher.batch_size {
                        build_err = Some(anyhow::anyhow!(
                            "engine batch size {engine_batch} != batcher {}",
                            cfg.batcher.batch_size
                        ));
                    }
                }
                Ok((k, Err(e))) => {
                    build_err = Some(e.context(format!("shard {k} engine construction")));
                }
                Err(_) => {
                    build_err =
                        Some(anyhow::anyhow!("a shard died during engine construction"));
                    break;
                }
            }
        }
        if let Some(e) = build_err {
            for tx in &shard_txs {
                let _ = tx.send(ShardMsg::Shutdown);
            }
            for w in shard_workers {
                let _ = w.join();
            }
            return Err(e);
        }

        // Dispatcher thread: owns the batcher, round-robins batches.
        let (tx, rx) = channel::<Msg>();
        let d_metrics = Arc::clone(&metrics);
        let d_depth = Arc::clone(&depth);
        let d_cfg = cfg.clone();
        let dispatcher = std::thread::Builder::new()
            .name("uivim-dispatcher".into())
            .spawn(move || dispatcher_loop(d_cfg, rx, shard_txs, &d_metrics, &d_depth))?;

        Ok(Coordinator {
            tx,
            dispatcher: Some(dispatcher),
            shard_workers,
            metrics,
            depth,
            capacity,
            nb,
            shards,
        })
    }

    /// Submit a voxel; returns a receiver for the response, or an error
    /// immediately under backpressure.
    pub fn submit(&self, req: VoxelRequest) -> anyhow::Result<Receiver<VoxelResponse>> {
        anyhow::ensure!(
            req.signals.len() == self.nb,
            "voxel has {} values, expected {}",
            req.signals.len(),
            self.nb
        );
        if self.depth.load(Ordering::Acquire) >= self.capacity {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("queue full ({} requests)", self.capacity);
        }
        let (resp_tx, resp_rx) = channel();
        self.depth.fetch_add(1, Ordering::AcqRel);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Request(Envelope {
                req,
                resp_tx,
                enqueued: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(resp_rx)
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, req: VoxelRequest) -> anyhow::Result<VoxelResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped the request"))
    }

    pub fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    /// Number of worker shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Current queue depth (requests admitted but not yet answered).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    fn stop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.shard_workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Graceful shutdown: flush pending work through every shard, join
    /// the dispatcher and all workers.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Dispatcher: batch formation + round-robin fan-out.
fn dispatcher_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<Msg>,
    shard_txs: Vec<Sender<ShardMsg>>,
    metrics: &ServingMetrics,
    depth: &AtomicUsize,
) {
    let mut batcher: Batcher<RowTag> = Batcher::new(cfg.batcher.clone(), cfg.nb);
    let mut shutting_down = false;
    let mut next_shard = 0usize;

    loop {
        // Wait for work, bounded by the oldest request's deadline.
        let timeout = match batcher.oldest_wait(Instant::now()) {
            Some(w) => cfg.batcher.max_wait.saturating_sub(w),
            None => {
                if shutting_down {
                    break;
                }
                Duration::from_millis(50)
            }
        };
        let handle = |msg: Msg, batcher: &mut Batcher<RowTag>, shutting_down: &mut bool| {
            match msg {
                Msg::Request(env) => {
                    let pend = Pending {
                        signals: env.req.signals,
                        tag: (env.req.id, env.resp_tx, env.enqueued),
                        enqueued: env.enqueued,
                    };
                    // capacity is enforced on submit; push cannot fail
                    // here unless capacity raced — drop in that case.
                    if batcher.push(pend).is_err() {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        depth.fetch_sub(1, Ordering::AcqRel);
                    }
                }
                Msg::Shutdown => *shutting_down = true,
            }
        };
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                handle(msg, &mut batcher, &mut shutting_down);
                // Greedily drain whatever else is already queued on the
                // channel: requests age in the channel too, and cutting
                // before draining would degrade into 1-row batches under
                // bursty load.
                while !batcher.is_full() {
                    match rx.try_recv() {
                        Ok(msg) => handle(msg, &mut batcher, &mut shutting_down),
                        Err(_) => break,
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                shutting_down = true;
            }
        }

        // Cut and dispatch every ready batch (all pending on shutdown).
        // Batch/padding counters are recorded by the shard that actually
        // serves the batch, so failed or dropped batches never inflate
        // the aggregate metrics.
        while (shutting_down && !batcher.is_empty()) || batcher.ready(Instant::now()) {
            let Some(batch) = batcher.cut() else { break };
            dispatch_round_robin(batch, &shard_txs, &mut next_shard, depth);
        }

        if shutting_down && batcher.is_empty() {
            break;
        }
    }

    // Close every shard: workers drain their queues and exit.
    for tx in &shard_txs {
        let _ = tx.send(ShardMsg::Shutdown);
    }
}

/// Round-robin a batch onto the shard pool.  If the chosen shard's
/// channel is gone (its thread died), fall through to the next surviving
/// shard; if every shard is gone, drop the responders so callers see an
/// error instead of hanging, and release their queue-depth slots.
fn dispatch_round_robin(
    batch: Batch<RowTag>,
    shard_txs: &[Sender<ShardMsg>],
    next_shard: &mut usize,
    depth: &AtomicUsize,
) {
    let mut pending = Some(batch);
    for _ in 0..shard_txs.len() {
        let k = *next_shard;
        *next_shard = (*next_shard + 1) % shard_txs.len();
        match shard_txs[k].send(ShardMsg::Batch(pending.take().expect("batch present"))) {
            Ok(()) => return,
            Err(std::sync::mpsc::SendError(ShardMsg::Batch(b))) => pending = Some(b),
            Err(std::sync::mpsc::SendError(ShardMsg::Shutdown)) => return,
        }
    }
    if let Some(b) = pending {
        for _ in b.tags {
            depth.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// One shard: pull batches, run the engine, answer requests.
fn shard_loop(ctx: ShardCtx, engine: &mut dyn Engine) {
    debug_assert_eq!(engine.batch_size(), ctx.batch_size);
    let shard = ctx.metrics.shard(ctx.index);
    while let Ok(msg) = ctx.rx.recv() {
        let batch = match msg {
            ShardMsg::Batch(b) => b,
            ShardMsg::Shutdown => break,
        };
        let t0 = Instant::now();
        match engine.infer_batch(&batch.signals) {
            Ok(out) => {
                let batch_us = t0.elapsed().as_micros() as u64;
                ctx.metrics.batch_latency.record_us(batch_us);
                ctx.metrics.batches.fetch_add(1, Ordering::Relaxed);
                ctx.metrics.padded_rows.fetch_add(
                    (ctx.batch_size - batch.real) as u64,
                    Ordering::Relaxed,
                );
                shard.busy_us.fetch_add(batch_us, Ordering::Relaxed);
                shard.batches.fetch_add(1, Ordering::Relaxed);
                for (row, (id, resp_tx, enq)) in batch.tags.into_iter().enumerate() {
                    let report = aggregate_voxel(&out, row, &ctx.thresholds);
                    ctx.metrics
                        .request_latency
                        .record_us(enq.elapsed().as_micros() as u64);
                    ctx.metrics.responses.fetch_add(1, Ordering::Relaxed);
                    shard.responses.fetch_add(1, Ordering::Relaxed);
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                    let _ = resp_tx.send(VoxelResponse { id, report });
                }
            }
            Err(e) => {
                eprintln!("uivim-shard-{}: engine failure: {e:#}", ctx.index);
                shard.engine_errors.fetch_add(1, Ordering::Relaxed);
                for (_, _resp_tx, _) in batch.tags.into_iter() {
                    ctx.depth.fetch_sub(1, Ordering::AcqRel);
                    // dropping resp_tx signals the error to the caller
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::native::NativeEngine;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::Manifest;
    use crate::testing::fixture;

    fn start_native(
        batch: usize,
        queue_capacity: usize,
        shards: usize,
    ) -> (Coordinator, Manifest) {
        let (man, w) = fixture::tiny_fixture();
        let man2 = man.clone();
        let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
        cfg.batcher.queue_capacity = queue_capacity;
        cfg.batcher.max_wait = Duration::from_millis(1);
        let coord = Coordinator::start(cfg, move || {
            Ok(Box::new(NativeEngine::with_batch(&man2, &w, batch)?) as Box<dyn Engine>)
        })
        .unwrap();
        (coord, man)
    }

    #[test]
    fn serves_requests_end_to_end() {
        let (coord, man) = start_native(8, 1000, 1);
        let ds = synth_dataset(20, &man.bvalues, 20.0, 1);
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap(),
            );
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
            let d = resp.report.get(crate::ivim::Param::D);
            assert!(d.mean >= 0.0 && d.mean <= 0.005);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, 20);
        assert!(snap.batches >= 3); // 20 voxels / batch 8
        coord.shutdown();
    }

    #[test]
    fn sharded_pool_serves_and_spreads_load() {
        let (coord, man) = start_native(4, 100_000, 3);
        assert_eq!(coord.shards(), 3);
        let n = 120;
        let ds = synth_dataset(n, &man.bvalues, 20.0, 4);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, i as u64);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.responses, n as u64);
        assert_eq!(snap.per_shard.len(), 3);
        let shard_total: u64 = snap.per_shard.iter().map(|s| s.responses).sum();
        assert_eq!(shard_total, n as u64, "every response owned by a shard");
        // Round-robin dispatch: with 30 batches and 3 shards no shard
        // can have been starved.
        assert!(
            snap.per_shard.iter().all(|s| s.batches > 0),
            "a shard was starved: {:?}",
            snap.per_shard
        );
        coord.shutdown();
    }

    #[test]
    fn sharded_results_match_single_worker() {
        let (c1, man) = start_native(8, 10_000, 1);
        let (c4, _) = start_native(8, 10_000, 4);
        let ds = synth_dataset(64, &man.bvalues, 20.0, 5);
        let collect = |coord: &Coordinator| -> Vec<f64> {
            let rxs: Vec<_> = (0..64)
                .map(|i| {
                    coord
                        .submit(VoxelRequest {
                            id: i as u64,
                            signals: ds.voxel(i).to_vec(),
                        })
                        .unwrap()
                })
                .collect();
            rxs.into_iter()
                .map(|rx| {
                    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    r.report.get(crate::ivim::Param::D).mean
                })
                .collect()
        };
        let a = collect(&c1);
        let b = collect(&c4);
        // Per-voxel results are unchanged by sharding: identical engines,
        // identical per-voxel math, batch membership does not leak.
        // (Batch *padding* rows never land on real voxels' outputs.)
        assert_eq!(a, b);
        c1.shutdown();
        c4.shutdown();
    }

    #[test]
    fn rejects_wrong_width() {
        let (coord, _) = start_native(8, 1000, 1);
        assert!(coord
            .submit(VoxelRequest {
                id: 0,
                signals: vec![0.0; 3],
            })
            .is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (coord, man) = start_native(64, 2, 2);
        let ds = synth_dataset(10, &man.bvalues, 20.0, 2);
        let mut accepted = 0;
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..10 {
            match coord.submit(VoxelRequest {
                id: i as u64,
                signals: ds.voxel(i).to_vec(),
            }) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure with capacity 2");
        // accepted requests still complete (deadline flush)
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert_eq!(
            coord.metrics().snapshot().rejected as usize
                + coord.metrics().snapshot().responses as usize,
            accepted + rejected
        );
    }

    #[test]
    fn shutdown_flushes_pending() {
        let (coord, man) = start_native(64, 1000, 2);
        let ds = synth_dataset(5, &man.bvalues, 20.0, 3);
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                coord
                    .submit(VoxelRequest {
                        id: i as u64,
                        signals: ds.voxel(i).to_vec(),
                    })
                    .unwrap()
            })
            .collect();
        coord.shutdown(); // must flush the partial batch through a shard
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
        }
    }

    #[test]
    fn factory_failure_propagates() {
        let cfg = CoordinatorConfig::for_batch(4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn factory_failure_propagates_sharded() {
        // One factory that fails for every shard: start() must join all
        // workers and surface the error instead of hanging.
        let cfg = CoordinatorConfig::sharded(4, 4, 4);
        let r = Coordinator::start(cfg, || anyhow::bail!("boom"));
        assert!(r.is_err());
    }

    #[test]
    fn batch_size_mismatch_rejected() {
        let (man, w) = fixture::tiny_fixture();
        let cfg = CoordinatorConfig::for_batch(man.nb, 8);
        let r = Coordinator::start(cfg, move || {
            // engine batch 16 != batcher batch 8
            Ok(Box::new(NativeEngine::with_batch(&man, &w, 16)?) as Box<dyn Engine>)
        });
        assert!(r.is_err());
    }
}
