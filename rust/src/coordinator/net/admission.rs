//! Deadline-aware admission control — the *pure* decision kernel.
//!
//! The rule is deliberately free of clocks, sockets and atomics so the
//! exact code the server runs is also what `testing::sched` drives
//! under virtual time: estimate how long a newly admitted request would
//! wait behind the current backlog, and shed it with an explicit
//! `OVERLOADED` reply when that estimate already exceeds the request's
//! own deadline.  Shedding beats queuing here because an answer that
//! arrives after the deadline is worthless to the client *and* cost a
//! batch slot that an in-deadline request could have used.

/// Estimated queue delay in µs for a request admitted now.
///
/// * `queued_batches` — formed batches already sitting in the shard
///   deques (each costs one batch service time).
/// * `pending_requests` — admitted requests not yet in a formed batch
///   (the batcher's backlog); they round up to whole batches.
/// * `batch_size` / `shards` — how much parallelism drains the backlog.
/// * `ewma_batch_us` — the live batch service-time estimate
///   (`ServingMetrics::ewma_batch_us`); 0 before the first batch, which
///   makes the estimate 0 — a cold coordinator never sheds on delay.
pub fn estimate_delay_us(
    queued_batches: usize,
    pending_requests: usize,
    batch_size: usize,
    shards: usize,
    ewma_batch_us: u64,
) -> u64 {
    let forming = pending_requests.div_ceil(batch_size.max(1));
    let batches = (queued_batches + forming) as u64;
    (batches * ewma_batch_us) / shards.max(1) as u64
}

/// Shed decision: `deadline_us == 0` means "no deadline" and is never
/// shed on delay; otherwise shed when the estimated wait alone already
/// exceeds the deadline.
pub fn should_shed(deadline_us: u64, est_delay_us: u64) -> bool {
    deadline_us != 0 && est_delay_us > deadline_us
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_coordinator_never_sheds() {
        // ewma 0 (no batch has ever run) -> estimate 0 -> admit anything
        assert_eq!(estimate_delay_us(100, 100, 8, 1, 0), 0);
        assert!(!should_shed(1, 0));
    }

    #[test]
    fn no_deadline_is_never_shed() {
        assert!(!should_shed(0, u64::MAX));
    }

    #[test]
    fn delay_scales_with_backlog_and_divides_by_shards() {
        // 4 queued batches + 9 pending at batch 8 = 4 + 2 = 6 batches
        assert_eq!(estimate_delay_us(4, 9, 8, 1, 100), 600);
        assert_eq!(estimate_delay_us(4, 9, 8, 2, 100), 300);
        assert_eq!(estimate_delay_us(4, 9, 8, 4, 100), 150);
        // empty system waits for nothing
        assert_eq!(estimate_delay_us(0, 0, 8, 4, 100), 0);
    }

    #[test]
    fn shed_is_strict_greater_than_deadline() {
        assert!(!should_shed(600, 600), "exactly-at-deadline still admits");
        assert!(should_shed(599, 600));
        assert!(!should_shed(601, 600));
    }

    #[test]
    fn degenerate_sizes_are_clamped_not_divided_by_zero() {
        assert_eq!(estimate_delay_us(0, 5, 0, 0, 100), 500);
    }
}
