//! The network front door: a dependency-free, length-prefixed TCP
//! ingest layer over [`Coordinator::lease`].
//!
//! ```text
//! client ──frame──► conn thread ──lease()/submit_leased()──► coordinator
//!        ◄─frame──  (one per connection, blocking socket,
//!                    short read tick = reply-sweep cadence)
//! ```
//!
//! * **Wire format** — `util::frame`: 28-byte header (magic, version,
//!   kind, status, id, deadline µs, value count) + f32 payload.  The
//!   parser is hardened: fixed-capacity reassembly, header validated
//!   before any payload is awaited, typed rejections.
//! * **Zero-copy ingest** — request signals decode *directly into a
//!   [`Coordinator::lease`] buffer*; there is no intermediate `Vec` on
//!   the serving path, so steady-state ingest allocates nothing (the
//!   lease slab's `created()` high-water stays flat).
//! * **Admission control** — three gates, each answered with an
//!   explicit status frame instead of a stall: a per-connection
//!   in-flight quota and the coordinator's queue-full backpressure
//!   both return [`Status::Overloaded`], and [`admission::should_shed`]
//!   sheds any request whose estimated queue delay
//!   (`Coordinator::estimated_queue_delay_us`: deque backlog × EWMA
//!   batch latency) already exceeds its deadline.  A request that
//!   expires *after* admission is answered [`Status::Expired`] and its
//!   response receiver dropped (reply-side shedding — the shard's send
//!   tolerates a dropped receiver).
//! * **Connection cap** — beyond `max_conns` live connections the
//!   acceptor writes one `OVERLOADED` goodbye frame and closes.
//! * **Shutdown** — connections stop reading, answer or `SHUTDOWN`-
//!   reject everything still pending, then close; no admitted request
//!   is silently dropped.

pub mod admission;
pub mod client;

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::server::{Coordinator, VoxelResponse};
use super::uncertainty::{UncertaintyReport, VoxelEstimate};
use crate::ivim::Param;
use crate::util::frame::{encode_response, FrameAssembler, FrameKind, Status};

pub use client::{NetClient, NetReply};

/// f64 slots in an `OK` response payload: (mean, std, relative) per
/// IVIM parameter + the confidence flag as 0.0/1.0.
pub const REPORT_VALUES: usize = 13;

/// Serialise a report into the response payload layout (f64 passes
/// through the wire bit-exactly, so framed results match the direct
/// `submit_leased` path bit for bit).
pub fn encode_report(report: &UncertaintyReport, out: &mut [f64; REPORT_VALUES]) {
    for p in Param::ALL {
        let e = report.get(p);
        let i = 3 * p.index();
        out[i] = e.mean;
        out[i + 1] = e.std;
        out[i + 2] = e.relative;
    }
    out[REPORT_VALUES - 1] = if report.confident { 1.0 } else { 0.0 };
}

/// Inverse of [`encode_report`].
pub fn decode_report(values: &[f64; REPORT_VALUES]) -> UncertaintyReport {
    let mut estimates = [VoxelEstimate {
        mean: 0.0,
        std: 0.0,
        relative: 0.0,
    }; 4];
    for p in Param::ALL {
        let i = 3 * p.index();
        estimates[p.index()] = VoxelEstimate {
            mean: values[i],
            std: values[i + 1],
            relative: values[i + 2],
        };
    }
    UncertaintyReport {
        estimates,
        confident: values[REPORT_VALUES - 1] != 0.0,
    }
}

/// Front-door configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Live-connection cap; excess connections get an `OVERLOADED`
    /// goodbye frame and are closed.
    pub max_conns: usize,
    /// Per-connection in-flight request quota; requests past it are
    /// answered `OVERLOADED` (one client cannot monopolise the queue).
    pub conn_quota: usize,
    /// Socket read tick — also the reply-sweep cadence, so it bounds
    /// added response latency.
    pub read_timeout: Duration,
    /// Slow-loris guard: a connection idling with a *partial* frame
    /// buffered for longer than this is closed.
    pub idle_timeout: Duration,
    /// Acceptor poll interval (the listener is non-blocking so
    /// shutdown never hangs on `accept`).
    pub accept_poll: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 64,
            conn_quota: 256,
            read_timeout: Duration::from_millis(2),
            idle_timeout: Duration::from_secs(2),
            accept_poll: Duration::from_millis(2),
        }
    }
}

/// Handle to a running TCP front door.  Dropping shuts it down (the
/// coordinator behind it is owned separately and keeps running).
pub struct NetServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port — see
    /// [`addr`](Self::addr)) and start accepting framed requests for
    /// `coord`.
    pub fn start(
        coord: Arc<Coordinator>,
        listen: &str,
        cfg: NetConfig,
    ) -> anyhow::Result<NetServer> {
        let listener =
            TcpListener::bind(listen).map_err(|e| anyhow::anyhow!("bind {listen}: {e}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(0));

        let a_shutdown = Arc::clone(&shutdown);
        let a_conns = Arc::clone(&conns);
        let acceptor = std::thread::Builder::new()
            .name("uivim-net-accept".into())
            .spawn(move || {
                accept_loop(listener, coord, cfg, a_shutdown, a_conns, live);
            })?;

        Ok(NetServer {
            addr,
            shutdown,
            acceptor: Some(acceptor),
            conns,
        })
    }

    /// The bound address (resolves the port when `listen` used port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, answer or reject everything pending on every
    /// open connection, join all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // a connection thread that panicked while holding the lock must
        // not turn shutdown into a second panic — take the list anyway
        let handles =
            std::mem::take(&mut *self.conns.lock().unwrap_or_else(|e| e.into_inner()));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    cfg: NetConfig,
    shutdown: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    live: Arc<AtomicUsize>,
) {
    let mut goodbye = Vec::new();
    while !shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if live.load(Ordering::Acquire) >= cfg.max_conns {
                    // explicit rejection, never a silent stall
                    encode_response(&mut goodbye, 0, Status::Overloaded, &[]);
                    let _ = stream.write_all(&goodbye);
                    continue;
                }
                live.fetch_add(1, Ordering::AcqRel);
                // relaxed: monotonic telemetry counter; the `live` gate
                // above is the one that needs (and has) real ordering.
                coord
                    .metrics()
                    .net_connections
                    .fetch_add(1, Ordering::Relaxed);
                let conn = Conn::new(stream, Arc::clone(&coord), cfg.clone());
                let c_shutdown = Arc::clone(&shutdown);
                let c_live = Arc::clone(&live);
                let spawned = std::thread::Builder::new()
                    .name("uivim-net-conn".into())
                    .spawn(move || {
                        // decrement on every exit path, including panics
                        struct LiveGuard(Arc<AtomicUsize>);
                        impl Drop for LiveGuard {
                            fn drop(&mut self) {
                                self.0.fetch_sub(1, Ordering::AcqRel);
                            }
                        }
                        let _guard = LiveGuard(c_live);
                        conn.run(&c_shutdown);
                    });
                match spawned {
                    // recover a poisoned list — joining is best-effort
                    Ok(h) => conns.lock().unwrap_or_else(|e| e.into_inner()).push(h),
                    Err(_) => {
                        live.fetch_sub(1, Ordering::AcqRel);
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(cfg.accept_poll);
            }
            Err(_) => std::thread::sleep(cfg.accept_poll),
        }
    }
}

/// One admitted request awaiting its response.
struct PendingReply {
    id: u64,
    /// Absolute expiry (None = no deadline).
    deadline: Option<Instant>,
    rx: Receiver<VoxelResponse>,
}

enum ReadOutcome {
    Progress,
    Idle,
    Closed,
    Dead,
}

/// Per-connection state: fixed read buffer, reusable reply buffer, and
/// the in-flight request set — nothing here allocates in steady state.
struct Conn {
    stream: TcpStream,
    coord: Arc<Coordinator>,
    cfg: NetConfig,
    asm: FrameAssembler,
    reply: Vec<u8>,
    values: [f64; REPORT_VALUES],
    pending: Vec<PendingReply>,
    nb: usize,
    last_progress: Instant,
}

impl Conn {
    fn new(stream: TcpStream, coord: Arc<Coordinator>, cfg: NetConfig) -> Conn {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(cfg.read_timeout));
        let nb = coord.nb();
        Conn {
            stream,
            coord,
            cfg,
            asm: FrameAssembler::new(nb),
            reply: Vec::new(),
            values: [0.0; REPORT_VALUES],
            pending: Vec::new(),
            nb,
            last_progress: Instant::now(),
        }
    }

    fn run(mut self, shutdown: &AtomicBool) {
        loop {
            if !self.sweep_replies() {
                return; // client gone; dropped receivers shed the rest
            }
            if shutdown.load(Ordering::Acquire) {
                self.drain_pending();
                return;
            }
            match self.read_some() {
                ReadOutcome::Progress => {
                    self.last_progress = Instant::now();
                    if !self.process_frames() {
                        return;
                    }
                }
                ReadOutcome::Idle => {
                    // slow-loris: half a frame, then silence
                    if self.asm.buffered() > 0
                        && self.last_progress.elapsed() > self.cfg.idle_timeout
                    {
                        return;
                    }
                }
                ReadOutcome::Closed => {
                    // peer finished writing; answer what it already sent
                    self.drain_pending();
                    return;
                }
                ReadOutcome::Dead => return,
            }
        }
    }

    /// Write one response frame; `false` = connection dead.
    fn send_reply(&mut self, id: u64, status: Status, with_values: bool) -> bool {
        let vals: &[f64] = if with_values { &self.values } else { &[] };
        encode_response(&mut self.reply, id, status, vals);
        self.stream.write_all(&self.reply).is_ok()
    }

    /// Deliver every ready response; expire overdue ones (dropping the
    /// receiver — the shard's send tolerates it).  `false` = dead.
    fn sweep_replies(&mut self) -> bool {
        // relaxed: net_expired is a monotonic telemetry counter —
        // snapshot-only readers, no ordering needed.
        let mut i = 0;
        while i < self.pending.len() {
            let now = Instant::now();
            let expired = self.pending[i].deadline.is_some_and(|d| now > d);
            let polled = self.pending[i].rx.try_recv();
            match polled {
                Ok(resp) => {
                    let id = self.pending.swap_remove(i).id;
                    let ok = if expired {
                        self.coord
                            .metrics()
                            .net_expired
                            .fetch_add(1, Ordering::Relaxed);
                        self.send_reply(id, Status::Expired, false)
                    } else {
                        encode_report(&resp.report, &mut self.values);
                        self.send_reply(id, Status::Ok, true)
                    };
                    if !ok {
                        return false;
                    }
                }
                Err(TryRecvError::Empty) => {
                    if expired {
                        // reply-side shedding: stop waiting, free the slot
                        let id = self.pending.swap_remove(i).id;
                        self.coord
                            .metrics()
                            .net_expired
                            .fetch_add(1, Ordering::Relaxed);
                        if !self.send_reply(id, Status::Expired, false) {
                            return false;
                        }
                    } else {
                        i += 1;
                    }
                }
                Err(TryRecvError::Disconnected) => {
                    // the pool dropped the responder (engine failure or
                    // shutdown): tell the client rather than stall it
                    let id = self.pending.swap_remove(i).id;
                    if !self.send_reply(id, Status::Shutdown, false) {
                        return false;
                    }
                }
            }
        }
        true
    }

    fn read_some(&mut self) -> ReadOutcome {
        let spare = self.asm.spare();
        if spare.is_empty() {
            // cannot happen after process_frames (the buffer outsizes
            // any legal frame), but never misread "full" as "closed"
            return ReadOutcome::Idle;
        }
        match self.stream.read(spare) {
            Ok(0) => ReadOutcome::Closed,
            Ok(n) => {
                self.asm.commit(n);
                ReadOutcome::Progress
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                ReadOutcome::Idle
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => ReadOutcome::Idle,
            Err(_) => ReadOutcome::Dead,
        }
    }

    /// Handle every complete buffered frame; `false` = close.
    fn process_frames(&mut self) -> bool {
        // relaxed: net_bad_frames is a monotonic telemetry counter.
        loop {
            match self.asm.poll() {
                Ok(Some(h)) => {
                    if !self.handle_request(h) {
                        return false;
                    }
                }
                Ok(None) => return true,
                Err(_) => {
                    // stream desynchronised or hostile: one typed
                    // rejection, then close
                    self.coord
                        .metrics()
                        .net_bad_frames
                        .fetch_add(1, Ordering::Relaxed);
                    let _ = self.send_reply(0, Status::BadRequest, false);
                    return false;
                }
            }
        }
    }

    fn handle_request(&mut self, h: crate::util::frame::FrameHeader) -> bool {
        // relaxed: net_frames/net_bad_frames/net_shed are monotonic
        // telemetry counters — snapshot-only readers, no ordering needed.
        if h.kind != FrameKind::Request {
            // clients have no business pushing response frames
            self.coord
                .metrics()
                .net_bad_frames
                .fetch_add(1, Ordering::Relaxed);
            let _ = self.send_reply(h.id, Status::BadRequest, false);
            return false;
        }
        self.coord
            .metrics()
            .net_frames
            .fetch_add(1, Ordering::Relaxed);

        // Admission gates, cheapest first.  All are answered explicitly.
        let verdict = if h.n_values != self.nb {
            self.coord
                .metrics()
                .net_bad_frames
                .fetch_add(1, Ordering::Relaxed);
            Some(Status::BadRequest)
        } else if self.pending.len() >= self.cfg.conn_quota {
            self.coord.metrics().net_shed.fetch_add(1, Ordering::Relaxed);
            Some(Status::Overloaded)
        } else if admission::should_shed(h.deadline_us, self.coord.estimated_queue_delay_us()) {
            self.coord.metrics().net_shed.fetch_add(1, Ordering::Relaxed);
            Some(Status::Overloaded)
        } else {
            None
        };
        if let Some(status) = verdict {
            self.asm.consume(&h);
            return self.send_reply(h.id, status, false);
        }

        // Zero-copy ingest: decode straight into a leased slab buffer.
        let mut lease = self.coord.lease();
        if !self.asm.decode_request_into(&h, lease.signals_mut()) {
            drop(lease); // reclaims into the slab
            self.coord
                .metrics()
                .net_bad_frames
                .fetch_add(1, Ordering::Relaxed);
            self.asm.consume(&h);
            return self.send_reply(h.id, Status::BadRequest, false);
        }
        let deadline =
            (h.deadline_us != 0).then(|| Instant::now() + Duration::from_micros(h.deadline_us));
        let id = h.id;
        self.asm.consume(&h);
        match self.coord.submit_leased(id, lease) {
            Ok(rx) => {
                self.pending.push(PendingReply { id, deadline, rx });
                true
            }
            Err(_) => {
                // queue-full backpressure raced the estimate; the lease
                // was already reclaimed by submit_leased
                self.coord.metrics().net_shed.fetch_add(1, Ordering::Relaxed);
                self.send_reply(id, Status::Overloaded, false)
            }
        }
    }

    /// Shutdown / half-close: wait (bounded) for the coordinator to
    /// answer what was admitted, then `SHUTDOWN`-reject the remainder.
    fn drain_pending(&mut self) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !self.pending.is_empty() && Instant::now() < deadline {
            if !self.sweep_replies() {
                return;
            }
            if !self.pending.is_empty() {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        while let Some(p) = self.pending.pop() {
            if !self.send_reply(p.id, Status::Shutdown, false) {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> UncertaintyReport {
        let mut estimates = [VoxelEstimate {
            mean: 0.0,
            std: 0.0,
            relative: 0.0,
        }; 4];
        for p in Param::ALL {
            let i = p.index();
            estimates[i] = VoxelEstimate {
                mean: 0.5 + i as f64,
                std: 0.125 * (i as f64 + 1.0),
                relative: 0.25 / (i as f64 + 1.0),
            };
        }
        UncertaintyReport {
            estimates,
            confident: true,
        }
    }

    #[test]
    fn report_payload_roundtrip_bit_exact() {
        let r = report();
        let mut values = [0.0f64; REPORT_VALUES];
        encode_report(&r, &mut values);
        let back = decode_report(&values);
        for p in Param::ALL {
            let (a, b) = (r.get(p), back.get(p));
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.std.to_bits(), b.std.to_bits());
            assert_eq!(a.relative.to_bits(), b.relative.to_bits());
        }
        assert_eq!(r.confident, back.confident);
    }

    #[test]
    fn confidence_flag_encodes_both_ways() {
        let mut r = report();
        r.confident = false;
        let mut values = [0.0f64; REPORT_VALUES];
        encode_report(&r, &mut values);
        assert_eq!(values[REPORT_VALUES - 1], 0.0);
        assert!(!decode_report(&values).confident);
    }

    #[test]
    fn config_defaults_are_sane() {
        let cfg = NetConfig::default();
        assert!(cfg.max_conns >= 1);
        assert!(cfg.conn_quota >= 1);
        assert!(cfg.idle_timeout > cfg.read_timeout);
    }
}
