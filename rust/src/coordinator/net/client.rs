//! Blocking framed-TCP client for the coordinator's front door.
//!
//! One request/response pair per call; buffers (encode scratch, frame
//! reassembly, report values) are owned by the client and reused, so a
//! long-lived client allocates only at construction.  Used by the
//! `repro client` smoke subcommand, the loopback integration tests and
//! the `repro serve --listen` demo driver.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::{decode_report, REPORT_VALUES};
use crate::coordinator::uncertainty::UncertaintyReport;
use crate::util::frame::{encode_request, FrameAssembler, FrameKind, Status};

/// One decoded response frame.
#[derive(Debug)]
pub struct NetReply {
    /// Echoed request id.
    pub id: u64,
    pub status: Status,
    /// The aggregated report — present only on [`Status::Ok`].
    pub report: Option<UncertaintyReport>,
}

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    stream: TcpStream,
    asm: FrameAssembler,
    buf: Vec<u8>,
    values: [f64; REPORT_VALUES],
}

impl NetClient {
    /// Connect with a 30 s reply timeout.
    pub fn connect(addr: &str) -> anyhow::Result<NetClient> {
        Self::connect_with_timeout(addr, Duration::from_secs(30))
    }

    /// Connect; `recv` fails after `timeout` without a reply.
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> anyhow::Result<NetClient> {
        let stream =
            TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(timeout))?;
        Ok(NetClient {
            stream,
            asm: FrameAssembler::new(REPORT_VALUES),
            buf: Vec::new(),
            values: [0.0; REPORT_VALUES],
        })
    }

    /// Send one request frame (`deadline_us` 0 = no deadline).
    pub fn send(&mut self, id: u64, deadline_us: u64, signals: &[f32]) -> anyhow::Result<()> {
        encode_request(&mut self.buf, id, deadline_us, signals);
        self.stream
            .write_all(&self.buf)
            .map_err(|e| anyhow::anyhow!("send request {id}: {e}"))
    }

    /// Send raw bytes as-is (test hook for malformed / partial frames).
    pub fn send_raw(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        self.stream
            .write_all(bytes)
            .map_err(|e| anyhow::anyhow!("send raw bytes: {e}"))
    }

    /// Block for the next response frame.
    pub fn recv(&mut self) -> anyhow::Result<NetReply> {
        loop {
            let polled = self
                .asm
                .poll()
                .map_err(|e| anyhow::anyhow!("server sent an invalid frame: {e}"))?;
            if let Some(h) = polled {
                anyhow::ensure!(
                    h.kind == FrameKind::Response,
                    "server sent a non-response frame"
                );
                let status = Status::from_u8(h.status)
                    .ok_or_else(|| anyhow::anyhow!("unknown response status {}", h.status))?;
                let report = if status == Status::Ok {
                    anyhow::ensure!(
                        h.n_values == REPORT_VALUES,
                        "OK response carries {} values, expected {REPORT_VALUES}",
                        h.n_values
                    );
                    self.asm.decode_response_into(&h, &mut self.values);
                    Some(decode_report(&self.values))
                } else {
                    None
                };
                let id = h.id;
                self.asm.consume(&h);
                return Ok(NetReply { id, status, report });
            }
            let spare = self.asm.spare();
            let n = self
                .stream
                .read(spare)
                .map_err(|e| anyhow::anyhow!("waiting for a reply: {e}"))?;
            anyhow::ensure!(n > 0, "server closed the connection");
            self.asm.commit(n);
        }
    }

    /// Convenience: one request, one reply.
    pub fn request(
        &mut self,
        id: u64,
        deadline_us: u64,
        signals: &[f32],
    ) -> anyhow::Result<NetReply> {
        self.send(id, deadline_us, signals)?;
        self.recv()
    }
}
