//! Per-shard bounded work deques with steal-on-idle — the successor to
//! the single shared MPMC batch queue.
//!
//! One `Mutex`+`Condvar` in front of K shards serialises every claim;
//! past ~8 shards the lock convoy erodes exactly the per-sample headroom
//! the mask-based BayesNN datapath wins (ROADMAP).  Here each shard owns
//! a bounded deque:
//!
//! * the **dispatcher pushes** to a shard's local deque, balancing with
//!   power-of-two-choices on depth (two random deques, take the
//!   shallower);
//! * a **shard pops LIFO** from its own deque (the freshest batch is the
//!   cache-warm one);
//! * an **idle shard steals FIFO** from a victim, scanning the other
//!   deques from a seeded-random start offset (the oldest batch is the
//!   one closest to its deadline, so stealing drains the victim's
//!   backlog in arrival order).
//!
//! Contention is now per-deque: the dispatcher and at most one thief
//! touch any given lock, instead of K shards convoying on one.
//!
//! Every operation short of the blocking [`ShardDeques::pop`] is a
//! single non-blocking atomic protocol step ([`ShardDeques::try_pop`],
//! [`ShardDeques::pop_local`], [`ShardDeques::steal_from`],
//! [`ShardDeques::push_to`], …).  That is deliberate: the deterministic
//! concurrency harness (`testing::sched`) replays interleavings of these
//! exact steps from a script, so races like "steal racing shutdown" are
//! table rows, not sleep-based flakes.  Victim/placement randomness is
//! always drawn from a caller-supplied [`Pcg32`], never from ambient
//! state, so a fixed seed reproduces a schedule bit-for-bit.
//!
//! Shutdown contract (mirrors the old shared queue): [`close`] wakes
//! every sleeper and makes pushes fail, but **claims keep succeeding
//! until all deques are empty** — including cross-shard steals — so no
//! admitted item is stranded.  [`drain`] (the dead-pool failsafe) empties
//! every deque and hands the items back to the caller to fail them fast.
//!
//! [`close`]: ShardDeques::close
//! [`drain`]: ShardDeques::drain

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::util::rng::Pcg32;

/// How a claimed item was obtained — feeds the per-shard
/// `local_batches` / `stolen_batches` counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Claim {
    /// Popped LIFO from the shard's own deque.
    Local,
    /// Stolen FIFO from `victim`'s deque.
    Stolen { victim: usize },
}

/// The soft per-deque cap for a coordinator admitting at most
/// `queue_capacity` requests, batched into groups of `batch_size`,
/// spread over `shards` deques: the worst-case admitted backlog, in
/// batches, split evenly.  One definition shared by the production
/// `WorkSource` and the `testing::sched` harness, so the deterministic
/// coverage always exercises the placement bound that ships.
pub fn cap_for(queue_capacity: usize, batch_size: usize, shards: usize) -> usize {
    queue_capacity
        .div_ceil(batch_size.max(1))
        .div_ceil(shards.max(1))
        .max(2)
}

/// K bounded deques plus the sleep/wake machinery for blocking pops.
pub struct ShardDeques<T> {
    deques: Vec<Mutex<VecDeque<T>>>,
    /// Lock-free mirrors of each deque's length: the balance signal for
    /// power-of-two-choices pushes, the cheap emptiness peek before a
    /// steal locks a victim, and the `deque_depth` metrics gauge.
    depths: Vec<AtomicUsize>,
    /// Items across all deques.  SeqCst: paired with `sleepers` in a
    /// store-then-load (Dekker) protocol against lost wakeups.
    total: AtomicUsize,
    closed: AtomicBool,
    /// Shards park here only after a full local+steal scan found
    /// nothing — the slow path; pushes touch it only when a sleeper is
    /// registered.
    sleep: Mutex<()>,
    wake: Condvar,
    sleepers: AtomicUsize,
    /// Soft per-deque bound: `push_balanced` routes around deques at
    /// this depth while any other has room.  It is a balancing hint,
    /// not admission control (the coordinator gates admission at
    /// `submit()`); only [`ShardDeques::close`] makes a push fail.
    cap: usize,
}

impl<T> ShardDeques<T> {
    /// `shards` deques (min 1), each soft-bounded at `cap_per_shard`
    /// items (min 1).
    pub fn new(shards: usize, cap_per_shard: usize) -> Self {
        let shards = shards.max(1);
        ShardDeques {
            deques: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            depths: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            total: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            cap: cap_per_shard.max(1),
        }
    }

    pub fn shards(&self) -> usize {
        self.deques.len()
    }

    /// Current depth of shard `k`'s deque (gauge; racy by nature).
    pub fn depth(&self, k: usize) -> usize {
        self.depths[k].load(Ordering::Acquire)
    }

    /// Items across all deques (gauge; racy by nature).
    pub fn total(&self) -> usize {
        self.total.load(Ordering::SeqCst)
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    // hot-path: deque ops — push/pop/steal run per batch under the
    // dispatcher and every shard; only pointer moves, no allocation.

    /// Push to shard `k`'s deque.  `Err` hands the item back once the
    /// deques are closed (every shard dead, or shutdown already
    /// flushed); the caller must fail it rather than strand it.
    pub fn push_to(&self, k: usize, item: T) -> Result<(), T> {
        if self.is_closed() {
            return Err(item);
        }
        {
            let mut q = self.deques[k].lock().expect("deque lock");
            // `close` → `drain` takes each deque lock once *after*
            // setting the flag, so re-checking under the lock means a
            // racing push either fails here or lands before the drain
            // sweeps this deque — an item is never stranded.
            if self.is_closed() {
                return Err(item);
            }
            q.push_back(item);
            self.depths[k].fetch_add(1, Ordering::Release);
            // inside the critical section: a claimer can only reach this
            // item after the lock is released, so its decrement always
            // follows this increment — `total` never transiently
            // underflows.
            self.total.fetch_add(1, Ordering::SeqCst);
        }
        self.notify_one();
        Ok(())
    }

    /// Balanced push: power-of-two-choices on depth (two seeded-random
    /// deques, take the shallower), routing around deques at the soft
    /// cap while an alternative has room.  Returns the chosen shard, or
    /// `Err` with the item once closed.
    pub fn push_balanced(&self, item: T, rng: &mut Pcg32) -> Result<usize, T> {
        let n = self.deques.len();
        let mut k = if n == 1 {
            0
        } else {
            let a = rng.below(n as u32) as usize;
            let b = rng.below(n as u32) as usize;
            if self.depth(a) <= self.depth(b) {
                a
            } else {
                b
            }
        };
        if self.depth(k) >= self.cap {
            // both picks saturated: take any deque with room, else keep
            // the pick (soft bound — admission control lives upstream)
            if let Some(open) = (0..n).find(|&i| self.depth(i) < self.cap) {
                k = open;
            }
        }
        self.push_to(k, item).map(|()| k)
    }

    /// Non-blocking LIFO pop from shard `k`'s own deque.
    pub fn pop_local(&self, k: usize) -> Option<T> {
        if self.depth(k) == 0 {
            return None;
        }
        let popped = self.deques[k].lock().expect("deque lock").pop_back();
        if popped.is_some() {
            self.depths[k].fetch_sub(1, Ordering::Release);
            self.total.fetch_sub(1, Ordering::SeqCst);
        }
        popped
    }

    /// Non-blocking FIFO steal from `victim`'s deque (front = oldest =
    /// closest to its deadline).  Succeeds even after [`close`]: steals
    /// are how a surviving shard drains a stalled sibling's backlog
    /// during shutdown.
    ///
    /// [`close`]: ShardDeques::close
    pub fn steal_from(&self, victim: usize) -> Option<T> {
        if self.depth(victim) == 0 {
            return None;
        }
        let stolen = self.deques[victim].lock().expect("deque lock").pop_front();
        if stolen.is_some() {
            self.depths[victim].fetch_sub(1, Ordering::Release);
            self.total.fetch_sub(1, Ordering::SeqCst);
        }
        stolen
    }

    /// One non-blocking claim attempt for shard `k`: local LIFO pop,
    /// else one FIFO steal scan over the other deques from a
    /// seeded-random start offset.  `None` means every deque *looked*
    /// empty at the moment it was peeked (a concurrent push may already
    /// have changed that — [`ShardDeques::pop`] handles the retry).
    pub fn try_pop(&self, k: usize, rng: &mut Pcg32) -> Option<(T, Claim)> {
        if let Some(item) = self.pop_local(k) {
            return Some((item, Claim::Local));
        }
        let n = self.deques.len();
        if n > 1 {
            let start = rng.below((n - 1) as u32) as usize;
            for i in 0..n - 1 {
                let victim = (k + 1 + (start + i) % (n - 1)) % n;
                if let Some(item) = self.steal_from(victim) {
                    return Some((item, Claim::Stolen { victim }));
                }
            }
        }
        None
    }

    /// Blocking claim for shard worker loops.  Returns `None` only once
    /// the deques are closed **and** fully drained, so shutdown never
    /// drops an admitted item.
    pub fn pop(&self, k: usize, rng: &mut Pcg32) -> Option<(T, Claim)> {
        loop {
            if let Some(got) = self.try_pop(k, rng) {
                return Some(got);
            }
            if self.is_closed() && self.total() == 0 {
                return None;
            }
            // Park, guarding against the lost-wakeup race: register as
            // a sleeper *then* re-check, while the pusher increments
            // `total` *then* checks for sleepers (both SeqCst).  One of
            // the two always observes the other.
            let guard = self.sleep.lock().expect("sleep lock");
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            if self.total() == 0 && !self.is_closed() {
                let _g = self.wake.wait(guard).expect("sleep lock");
            }
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    // hot-path: end

    fn notify_one(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            // Taking the sleep lock first means a shard between its
            // re-check and its wait (it holds the lock there) cannot
            // miss this notification.
            let _g = self.sleep.lock().expect("sleep lock");
            self.wake.notify_one();
        }
    }

    /// Close: pushes start failing, every sleeper wakes.  Claims keep
    /// draining whatever is already queued.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let _g = self.sleep.lock().expect("sleep lock");
        self.wake.notify_all();
    }

    /// Empty every deque and hand the items back (the dead-pool
    /// failsafe: when no shard survives to claim them, the caller fails
    /// them fast instead of stranding their requests).  Call after
    /// [`ShardDeques::close`].
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        for (k, dq) in self.deques.iter().enumerate() {
            let mut q = dq.lock().expect("deque lock");
            while let Some(item) = q.pop_front() {
                self.depths[k].fetch_sub(1, Ordering::Release);
                self.total.fetch_sub(1, Ordering::SeqCst);
                out.push(item);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn local_pop_is_lifo_steal_is_fifo() {
        let d: ShardDeques<u32> = ShardDeques::new(2, 16);
        for v in [1, 2, 3] {
            d.push_to(0, v).unwrap();
        }
        assert_eq!(d.depth(0), 3);
        assert_eq!(d.total(), 3);
        // owner pops the freshest
        assert_eq!(d.pop_local(0), Some(3));
        // thief steals the oldest
        assert_eq!(d.steal_from(0), Some(1));
        assert_eq!(d.pop_local(0), Some(2));
        assert_eq!(d.total(), 0);
        assert_eq!(d.pop_local(0), None);
        assert_eq!(d.steal_from(0), None);
    }

    #[test]
    fn try_pop_prefers_local_then_steals() {
        let d: ShardDeques<u32> = ShardDeques::new(3, 16);
        let mut rng = Pcg32::new(7);
        d.push_to(0, 10).unwrap();
        d.push_to(1, 20).unwrap();
        let (v, how) = d.try_pop(0, &mut rng).unwrap();
        assert_eq!((v, how), (10, Claim::Local));
        let (v, how) = d.try_pop(0, &mut rng).unwrap();
        assert_eq!(v, 20);
        assert_eq!(how, Claim::Stolen { victim: 1 });
        assert!(d.try_pop(0, &mut rng).is_none());
    }

    #[test]
    fn push_balanced_prefers_the_shallower_deque() {
        let d: ShardDeques<u32> = ShardDeques::new(2, 100);
        let mut rng = Pcg32::new(1);
        // preload shard 0 so every two-choice pick favours shard 1
        for v in 0..10 {
            d.push_to(0, v).unwrap();
        }
        let mut to_one = 0;
        for v in 0..10 {
            if d.push_balanced(v, &mut rng).unwrap() == 1 {
                to_one += 1;
            }
        }
        // p2c sends at least the clear majority to the empty deque
        // (deterministic for the fixed seed)
        assert!(to_one >= 8, "p2c ignored the depth signal: {to_one}/10");
    }

    #[test]
    fn push_balanced_routes_around_the_soft_cap() {
        let d: ShardDeques<u32> = ShardDeques::new(3, 2);
        let mut rng = Pcg32::new(3);
        // 6 pushes exactly fill 3 deques of cap 2 — none may exceed the
        // cap while a sibling has room
        for v in 0..6 {
            d.push_balanced(v, &mut rng).unwrap();
        }
        for k in 0..3 {
            assert_eq!(d.depth(k), 2, "deque {k} missed the cap route-around");
        }
        // saturated: the soft bound still admits (admission control is
        // upstream)
        d.push_balanced(99, &mut rng).unwrap();
        assert_eq!(d.total(), 7);
    }

    #[test]
    fn close_fails_pushes_but_steals_keep_draining() {
        let d: ShardDeques<u32> = ShardDeques::new(2, 16);
        d.push_to(1, 5).unwrap();
        d.close();
        assert!(d.push_to(0, 6).is_err(), "push must fail after close");
        assert!(d.push_balanced(7, &mut Pcg32::new(1)).is_err());
        // the queued item is still claimable — cross-shard
        assert_eq!(d.steal_from(1), Some(5));
        assert_eq!(d.total(), 0);
    }

    #[test]
    fn drain_returns_everything_in_deque_order() {
        let d: ShardDeques<u32> = ShardDeques::new(2, 16);
        d.push_to(0, 1).unwrap();
        d.push_to(0, 2).unwrap();
        d.push_to(1, 3).unwrap();
        d.close();
        let got = d.drain();
        assert_eq!(got, vec![1, 2, 3]);
        assert_eq!(d.total(), 0);
        assert_eq!(d.depth(0), 0);
        assert_eq!(d.depth(1), 0);
    }

    #[test]
    fn blocking_pop_returns_none_only_after_close_and_drain() {
        let d: Arc<ShardDeques<u64>> = Arc::new(ShardDeques::new(2, 1024));
        let seen = Arc::new(AtomicU64::new(0));
        let n_items = 200u64;
        let workers: Vec<_> = (0..2)
            .map(|k| {
                let d = Arc::clone(&d);
                let seen = Arc::clone(&seen);
                std::thread::spawn(move || {
                    let mut rng = Pcg32::with_stream(99, k as u64);
                    let mut count = 0u64;
                    while d.pop(k, &mut rng).is_some() {
                        count += 1;
                    }
                    seen.fetch_add(count, Ordering::SeqCst);
                })
            })
            .collect();
        let mut rng = Pcg32::new(4);
        for v in 0..n_items {
            d.push_balanced(v, &mut rng).unwrap();
        }
        d.close();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(seen.load(Ordering::SeqCst), n_items, "drained exactly once each");
        assert_eq!(d.total(), 0);
    }

    #[test]
    fn concurrent_claims_never_lose_or_duplicate() {
        // Every push lands on shard 0, whose worker never runs — the
        // three thief workers (shards 1..4) can only claim by stealing,
        // so every item is claimed exactly once *and* every claim is a
        // steal, deterministically.  The sum of claimed values equals
        // the pushed sum iff nothing was lost or duplicated.
        let shards = 4usize;
        let d: Arc<ShardDeques<u64>> = Arc::new(ShardDeques::new(shards, 4096));
        let sum = Arc::new(AtomicU64::new(0));
        let stolen = Arc::new(AtomicU64::new(0));
        let workers: Vec<_> = (1..shards)
            .map(|k| {
                let d = Arc::clone(&d);
                let sum = Arc::clone(&sum);
                let stolen = Arc::clone(&stolen);
                std::thread::spawn(move || {
                    let mut rng = Pcg32::with_stream(7, k as u64);
                    while let Some((v, how)) = d.pop(k, &mut rng) {
                        sum.fetch_add(v, Ordering::SeqCst);
                        if matches!(how, Claim::Stolen { victim: 0 }) {
                            stolen.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();
        let n = 2000u64;
        let mut want = 0u64;
        for v in 1..=n {
            d.push_to(0, v).unwrap();
            want += v;
        }
        d.close();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(sum.load(Ordering::SeqCst), want);
        assert_eq!(
            stolen.load(Ordering::SeqCst),
            n,
            "with no shard-0 worker, every claim must be a steal from shard 0"
        );
    }
}
