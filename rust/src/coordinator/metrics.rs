//! Serving metrics: latency histogram, throughput and queue gauges.
//!
//! Lock-cheap: counters are atomics; the histogram uses fixed log-spaced
//! buckets so recording is a single atomic increment.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram from 1 us to ~16 s.
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds.
    buckets: [AtomicU64; 24],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record_us(&self, us: u64) {
        // relaxed: independent monotonic counters; readers tolerate a
        // momentarily torn view across buckets/count/sum (telemetry).
        let idx = (63 - us.max(1).leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        // relaxed: telemetry snapshot read, no ordering needed
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            // relaxed: telemetry snapshot read, no ordering needed
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        // relaxed: telemetry snapshot read, no ordering needed
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        // relaxed: bucket reads race recorders; an approximate
        // percentile over telemetry tolerates that by design.
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1); // bucket upper bound
            }
        }
        self.max_us()
    }
}

/// Per-shard counters: one worker thread owning one engine.
#[derive(Default)]
pub struct ShardMetrics {
    pub batches: AtomicU64,
    pub responses: AtomicU64,
    pub engine_errors: AtomicU64,
    /// Time the shard spent inside `infer_batch`.
    pub busy_us: AtomicU64,
    /// Batches claimed LIFO from this shard's own deque (equals
    /// `batches` under the legacy shared-queue dispatch).
    pub local_batches: AtomicU64,
    /// Batches this shard stole FIFO from a sibling's deque while idle.
    pub stolen_batches: AtomicU64,
}

impl ShardMetrics {
    pub fn snapshot(&self) -> ShardSnapshot {
        // relaxed: point-in-time telemetry copy; counters are
        // independent and a torn cross-counter view is acceptable.
        ShardSnapshot {
            batches: self.batches.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            engine_errors: self.engine_errors.load(Ordering::Relaxed),
            busy_us: self.busy_us.load(Ordering::Relaxed),
            local_batches: self.local_batches.load(Ordering::Relaxed),
            stolen_batches: self.stolen_batches.load(Ordering::Relaxed),
            deque_depth: 0,
        }
    }
}

/// Point-in-time copy of one shard's counters, plus the shard's live
/// deque-depth gauge (filled by `Coordinator::snapshot()`; zero when
/// snapshotting the bare counter block, which cannot see the deques).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardSnapshot {
    pub batches: u64,
    pub responses: u64,
    pub engine_errors: u64,
    pub busy_us: u64,
    /// Batches claimed from this shard's own deque.
    pub local_batches: u64,
    /// Batches stolen from a sibling while idle.
    pub stolen_batches: u64,
    /// Batches currently queued in this shard's deque (gauge).
    pub deque_depth: usize,
}

/// Aggregate serving metrics shared between the coordinator and its
/// observers.
#[derive(Default)]
pub struct ServingMetrics {
    pub request_latency: LatencyHistogram,
    pub batch_latency: LatencyHistogram,
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
    pub rejected: AtomicU64,
    /// Volume-streaming counters (incremented by `volume::stream`
    /// drivers through `Coordinator::metrics()`): slices fully
    /// submitted into the coordinator.
    pub slices_ingested: AtomicU64,
    /// Volumes whose every voxel response has been assembled.
    pub volumes_completed: AtomicU64,
    /// Times a streaming driver had to drain completions before it
    /// could admit the next slice (backpressure events).
    pub stream_stalls: AtomicU64,
    /// Exponentially-weighted moving average of batch service latency
    /// in µs, stored as `f64` bits (0 until the first batch).  This is
    /// the admission controller's delay-per-batch estimate: unlike the
    /// histogram mean it tracks the *current* service rate, so a warm-up
    /// transient cannot poison shed decisions forever.
    pub ewma_batch_us: AtomicU64,
    /// Network front door (`coordinator::net`): connections accepted.
    pub net_connections: AtomicU64,
    /// Request frames fully parsed off the wire.
    pub net_frames: AtomicU64,
    /// Requests shed by admission control with an `OVERLOADED` reply
    /// (per-connection quota, queue full, or estimated delay past the
    /// request deadline).
    pub net_shed: AtomicU64,
    /// Frames rejected by the hardened parser or request validation
    /// (bad magic/version/kind, oversize, wrong width, non-finite).
    pub net_bad_frames: AtomicU64,
    /// Admitted requests whose deadline passed before the response
    /// could be written back (answered with `EXPIRED`).
    pub net_expired: AtomicU64,
    /// One slot per worker shard (`new()` allocates a single slot; the
    /// sharded coordinator uses `with_shards(k)`).
    pub shards: Vec<ShardMetrics>,
}

/// EWMA smoothing factor: each new batch contributes 20%.
const EWMA_ALPHA: f64 = 0.2;

impl ServingMetrics {
    pub fn new() -> Self {
        Self::with_shards(1)
    }

    /// Metrics block with `k` per-shard slots.
    pub fn with_shards(k: usize) -> Self {
        ServingMetrics {
            shards: (0..k.max(1)).map(|_| ShardMetrics::default()).collect(),
            ..Default::default()
        }
    }

    pub fn shard(&self, k: usize) -> &ShardMetrics {
        &self.shards[k]
    }

    /// Fold one batch's service time into the EWMA (lock-free CAS loop;
    /// the first sample seeds the average directly).
    pub fn record_batch_ewma(&self, us: u64) {
        // relaxed: the CAS loop only needs atomicity of the single
        // EWMA word, not ordering against any other memory.
        let mut cur = self.ewma_batch_us.load(Ordering::Relaxed);
        loop {
            let prev = f64::from_bits(cur);
            let next = if prev == 0.0 {
                us as f64
            } else {
                prev + EWMA_ALPHA * (us as f64 - prev)
            };
            match self.ewma_batch_us.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Current EWMA batch latency in µs (0 before the first batch).
    pub fn ewma_batch_us(&self) -> f64 {
        // relaxed: single-word estimate read; staleness is fine
        f64::from_bits(self.ewma_batch_us.load(Ordering::Relaxed))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // relaxed: point-in-time telemetry copy; counters are
        // independent and a torn cross-counter view is acceptable.
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            slices_ingested: self.slices_ingested.load(Ordering::Relaxed),
            volumes_completed: self.volumes_completed.load(Ordering::Relaxed),
            stream_stalls: self.stream_stalls.load(Ordering::Relaxed),
            ewma_batch_us: self.ewma_batch_us(),
            net_connections: self.net_connections.load(Ordering::Relaxed),
            net_frames: self.net_frames.load(Ordering::Relaxed),
            net_shed: self.net_shed.load(Ordering::Relaxed),
            net_bad_frames: self.net_bad_frames.load(Ordering::Relaxed),
            net_expired: self.net_expired.load(Ordering::Relaxed),
            mean_request_us: self.request_latency.mean_us(),
            p50_request_us: self.request_latency.percentile_us(50.0) as f64,
            p99_request_us: self.request_latency.percentile_us(99.0) as f64,
            mean_batch_us: self.batch_latency.mean_us(),
            pooled_outputs: 0,
            pooled_signals: 0,
            pooled_requests: 0,
            queue_depth: 0,
            per_shard: self.shards.iter().map(|s| s.snapshot()).collect(),
        }
    }
}

/// Point-in-time copy of the counters, plus the coordinator's live
/// gauges (buffer-pool occupancy and pending queue depth — filled by
/// `Coordinator::snapshot()`; zero when snapshotting the bare counter
/// block, which cannot see the pools).
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub rejected: u64,
    /// Slices fully submitted by streaming-volume drivers.
    pub slices_ingested: u64,
    /// Volumes completely assembled by streaming-volume drivers.
    pub volumes_completed: u64,
    /// Backpressure events: a streaming driver drained completions
    /// before admitting the next slice.
    pub stream_stalls: u64,
    /// EWMA batch service latency in µs — the admission controller's
    /// live delay-per-batch estimate (0 before the first batch).
    pub ewma_batch_us: f64,
    /// TCP connections accepted by the network front door.
    pub net_connections: u64,
    /// Request frames fully parsed off the wire.
    pub net_frames: u64,
    /// Requests answered `OVERLOADED` by admission control.
    pub net_shed: u64,
    /// Frames rejected by parsing or request validation.
    pub net_bad_frames: u64,
    /// Admitted requests that expired before their response was written.
    pub net_expired: u64,
    pub mean_request_us: f64,
    pub p50_request_us: f64,
    pub p99_request_us: f64,
    pub mean_batch_us: f64,
    /// Idle recycled `InferOutput` buffers in the coordinator pool.
    pub pooled_outputs: usize,
    /// Idle recycled batch signal buffers in the coordinator pool.
    pub pooled_signals: usize,
    /// Idle leased per-request signal buffers (the `Coordinator::lease`
    /// slab) waiting for the next caller.
    pub pooled_requests: usize,
    /// Requests admitted but not yet answered (pending queue length).
    pub queue_depth: usize,
    pub per_shard: Vec<ShardSnapshot>,
}

impl MetricsSnapshot {
    /// Batches claimed from the claiming shard's own deque, summed.
    pub fn local_batches(&self) -> u64 {
        self.per_shard.iter().map(|s| s.local_batches).sum()
    }

    /// Batches stolen across shards, summed.
    pub fn stolen_batches(&self) -> u64 {
        self.per_shard.iter().map(|s| s.stolen_batches).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let h = LatencyHistogram::new();
        for us in [1u64, 10, 100, 1000, 1000, 10_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean_us() > 1.0);
        assert_eq!(h.max_us(), 10_000);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(99.0), 0);
    }

    #[test]
    fn percentile_monotone_in_p() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_us(i);
        }
        let p50 = h.percentile_us(50.0);
        let p90 = h.percentile_us(90.0);
        let p999 = h.percentile_us(99.9);
        assert!(p50 <= p90 && p90 <= p999);
    }

    #[test]
    fn snapshot_copies_counters() {
        let m = ServingMetrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.responses.fetch_add(3, Ordering::Relaxed);
        m.request_latency.record_us(42);
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.responses, 3);
        assert!(s.mean_request_us > 0.0);
        assert_eq!(s.per_shard.len(), 1);
    }

    #[test]
    fn per_shard_slots_are_independent() {
        let m = ServingMetrics::with_shards(4);
        m.shard(0).batches.fetch_add(2, Ordering::Relaxed);
        m.shard(3).responses.fetch_add(7, Ordering::Relaxed);
        m.shard(3).busy_us.fetch_add(123, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.per_shard.len(), 4);
        assert_eq!(s.per_shard[0].batches, 2);
        assert_eq!(s.per_shard[1].batches, 0);
        assert_eq!(s.per_shard[3].responses, 7);
        assert_eq!(s.per_shard[3].busy_us, 123);
    }

    #[test]
    fn shard_count_clamped_to_one() {
        assert_eq!(ServingMetrics::with_shards(0).shards.len(), 1);
    }

    #[test]
    fn stream_counters_snapshot() {
        let m = ServingMetrics::with_shards(2);
        m.slices_ingested.fetch_add(8, Ordering::Relaxed);
        m.volumes_completed.fetch_add(1, Ordering::Relaxed);
        m.stream_stalls.fetch_add(3, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.slices_ingested, 8);
        assert_eq!(s.volumes_completed, 1);
        assert_eq!(s.stream_stalls, 3);
    }

    #[test]
    fn ewma_seeds_then_converges() {
        let m = ServingMetrics::new();
        assert_eq!(m.ewma_batch_us(), 0.0);
        m.record_batch_ewma(100);
        assert_eq!(m.ewma_batch_us(), 100.0, "first sample seeds directly");
        m.record_batch_ewma(200);
        // 100 + 0.2 * (200 - 100)
        assert_eq!(m.ewma_batch_us(), 120.0);
        // a long run of constant samples converges to that constant
        for _ in 0..200 {
            m.record_batch_ewma(50);
        }
        assert!((m.ewma_batch_us() - 50.0).abs() < 1e-6);
        let s = m.snapshot();
        assert!((s.ewma_batch_us - 50.0).abs() < 1e-6);
    }

    #[test]
    fn net_counters_snapshot() {
        let m = ServingMetrics::new();
        m.net_connections.fetch_add(2, Ordering::Relaxed);
        m.net_frames.fetch_add(10, Ordering::Relaxed);
        m.net_shed.fetch_add(3, Ordering::Relaxed);
        m.net_bad_frames.fetch_add(1, Ordering::Relaxed);
        m.net_expired.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(
            (
                s.net_connections,
                s.net_frames,
                s.net_shed,
                s.net_bad_frames,
                s.net_expired
            ),
            (2, 10, 3, 1, 4)
        );
    }

    #[test]
    fn steal_counters_partition_and_sum() {
        let m = ServingMetrics::with_shards(2);
        m.shard(0).local_batches.fetch_add(3, Ordering::Relaxed);
        m.shard(1).stolen_batches.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.per_shard[0].local_batches, 3);
        assert_eq!(s.per_shard[0].stolen_batches, 0);
        assert_eq!(s.per_shard[1].stolen_batches, 2);
        assert_eq!(s.local_batches(), 3);
        assert_eq!(s.stolen_batches(), 2);
        // gauges are zero on the bare counter snapshot
        assert!(s.per_shard.iter().all(|p| p.deque_depth == 0));
        assert_eq!(s.pooled_requests, 0);
    }
}
