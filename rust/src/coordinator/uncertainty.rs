//! Uncertainty aggregation (paper §IV "evaluation stage"): the N mask
//! samples per voxel collapse to mean (prediction) and std/mean
//! (relative uncertainty), plus a clinical confidence flag against a
//! per-parameter threshold ("clinicians are able to set numerical
//! thresholds to determine diagnosis with high uncertainty", §VI-B).

use crate::infer::InferOutput;
use crate::ivim::Param;

/// Aggregated estimate of one parameter for one voxel.
#[derive(Debug, Clone, Copy)]
pub struct VoxelEstimate {
    pub mean: f64,
    pub std: f64,
    /// std / mean — the paper's Fig. 7 metric.
    pub relative: f64,
}

/// Full per-voxel report across the four IVIM parameters.
#[derive(Debug, Clone)]
pub struct UncertaintyReport {
    pub estimates: [VoxelEstimate; 4],
    /// True when every parameter's relative uncertainty is under the
    /// configured threshold.
    pub confident: bool,
}

impl UncertaintyReport {
    pub fn get(&self, p: Param) -> &VoxelEstimate {
        &self.estimates[p.index()]
    }
}

/// Uncertainty thresholds per parameter (relative units).  Defaults follow
/// the shape of the paper's Fig. 7: perfusion-related parameters tolerate
/// more relative spread than D / S0.
#[derive(Debug, Clone, Copy)]
pub struct Thresholds {
    pub d: f64,
    pub dstar: f64,
    pub f: f64,
    pub s0: f64,
}

impl Default for Thresholds {
    fn default() -> Self {
        Thresholds {
            d: 0.35,
            dstar: 0.5,
            f: 0.5,
            s0: 0.1,
        }
    }
}

impl Thresholds {
    pub fn get(&self, p: Param) -> f64 {
        match p {
            Param::D => self.d,
            Param::DStar => self.dstar,
            Param::F => self.f,
            Param::S0 => self.s0,
        }
    }
}

/// Aggregate one voxel of an [`InferOutput`].
pub fn aggregate_voxel(out: &InferOutput, voxel: usize, thr: &Thresholds) -> UncertaintyReport {
    let mut estimates = [VoxelEstimate {
        mean: 0.0,
        std: 0.0,
        relative: 0.0,
    }; 4];
    let mut confident = true;
    for p in Param::ALL {
        let mean = out.mean(p, voxel);
        let std = out.std(p, voxel);
        let relative = if mean.abs() < 1e-12 { 0.0 } else { std / mean };
        estimates[p.index()] = VoxelEstimate {
            mean,
            std,
            relative,
        };
        if relative > thr.get(p) {
            confident = false;
        }
    }
    UncertaintyReport {
        estimates,
        confident,
    }
}

/// Aggregate every voxel of a batch output.
pub fn aggregate_batch(out: &InferOutput, thr: &Thresholds) -> Vec<UncertaintyReport> {
    (0..out.batch).map(|v| aggregate_voxel(out, v, thr)).collect()
}

/// Mean relative uncertainty of one parameter across a set of reports —
/// the Fig. 7 series value for one SNR level.
pub fn mean_relative(reports: &[UncertaintyReport], p: Param) -> f64 {
    if reports.is_empty() {
        return 0.0;
    }
    reports.iter().map(|r| r.get(p).relative).sum::<f64>() / reports.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_output() -> InferOutput {
        let mut out = InferOutput::new(4, 2);
        // voxel 0: tight spread; voxel 1: wide spread
        for (s, v) in [(0usize, 0.0101f32), (1, 0.0099), (2, 0.0100), (3, 0.0100)] {
            out.set(Param::DStar, s, 0, v);
        }
        for (s, v) in [(0usize, 0.02f32), (1, 0.18), (2, 0.05), (3, 0.15)] {
            out.set(Param::DStar, s, 1, v);
        }
        // give the other params stable values everywhere
        for p in [Param::D, Param::F, Param::S0] {
            for s in 0..4 {
                for v in 0..2 {
                    out.set(p, s, v, p.convert(0.5) as f32);
                }
            }
        }
        out
    }

    #[test]
    fn tight_voxel_is_confident() {
        let out = synthetic_output();
        let thr = Thresholds::default();
        let r0 = aggregate_voxel(&out, 0, &thr);
        assert!(r0.confident);
        assert!(r0.get(Param::DStar).relative < 0.05);
    }

    #[test]
    fn wide_voxel_is_flagged() {
        let out = synthetic_output();
        let thr = Thresholds::default();
        let r1 = aggregate_voxel(&out, 1, &thr);
        assert!(!r1.confident);
        assert!(r1.get(Param::DStar).relative > 0.5);
    }

    #[test]
    fn batch_aggregation_covers_all() {
        let out = synthetic_output();
        let reports = aggregate_batch(&out, &Thresholds::default());
        assert_eq!(reports.len(), 2);
        let m = mean_relative(&reports, Param::DStar);
        assert!(m > 0.0);
        assert_eq!(mean_relative(&[], Param::D), 0.0);
    }

    #[test]
    fn zero_spread_zero_uncertainty() {
        let out = synthetic_output();
        let r = aggregate_voxel(&out, 0, &Thresholds::default());
        assert_eq!(r.get(Param::F).std, 0.0);
        assert_eq!(r.get(Param::F).relative, 0.0);
    }

    /// Output whose F-parameter samples give an exactly representable
    /// relative uncertainty of 0.5 (mean 2, std 1), with the other
    /// parameters held constant (relative 0).
    fn half_relative_output() -> InferOutput {
        let mut out = InferOutput::new(4, 1);
        for (s, v) in [(0usize, 1.0f32), (1, 1.0), (2, 3.0), (3, 3.0)] {
            out.set(Param::F, s, 0, v);
        }
        for p in [Param::D, Param::DStar, Param::S0] {
            for s in 0..4 {
                out.set(p, s, 0, 1.0);
            }
        }
        out
    }

    #[test]
    fn std_and_relative_follow_definition() {
        let out = half_relative_output();
        let r = aggregate_voxel(&out, 0, &Thresholds::default());
        let e = r.get(Param::F);
        assert_eq!(e.mean, 2.0);
        assert_eq!(e.std, 1.0);
        assert_eq!(e.relative, 0.5);
    }

    #[test]
    fn confidence_flag_is_strict_greater_than_threshold() {
        let out = half_relative_output();
        let mut thr = Thresholds {
            d: 10.0,
            dstar: 10.0,
            f: 0.5,
            s0: 10.0,
        };
        // relative == threshold exactly -> still confident (strict >)
        let r = aggregate_voxel(&out, 0, &thr);
        assert_eq!(r.get(Param::F).relative, thr.f);
        assert!(r.confident, "exactly-at-threshold must not be flagged");
        // nudge the threshold below -> flagged
        thr.f = 0.5 - 1e-9;
        assert!(!aggregate_voxel(&out, 0, &thr).confident);
        // one bad parameter flips the whole voxel even when others pass:
        // D has relative 0.0, and 0.0 > -eps, so D alone trips the flag
        thr.f = 10.0;
        thr.d = -f64::EPSILON;
        assert!(!aggregate_voxel(&out, 0, &thr).confident);
    }

    #[test]
    fn near_zero_mean_defines_relative_as_zero() {
        let mut out = InferOutput::new(2, 1);
        // mean ~ 0 but nonzero std: the guard must zero the relative
        // uncertainty instead of dividing by ~0
        out.set(Param::DStar, 0, 0, 1e-13);
        out.set(Param::DStar, 1, 0, -1e-13);
        let r = aggregate_voxel(&out, 0, &Thresholds::default());
        let e = r.get(Param::DStar);
        assert!(e.mean.abs() < 1e-12);
        assert_eq!(e.relative, 0.0);
    }

    /// End-to-end: aggregate a real engine output built from the in-tree
    /// fixture and check the reports' internal consistency.
    #[test]
    fn aggregates_fixture_engine_output_consistently() {
        use crate::infer::registry::{build, EngineOpts};
        use crate::testing::fixture;
        let (man, w) = fixture::tiny_fixture();
        let mut eng = build("native", &man, &w, &EngineOpts::default()).unwrap();
        let ds = crate::ivim::synth::synth_dataset(man.batch_infer, &man.bvalues, 20.0, 31);
        let out = eng.infer_batch(&ds.signals).unwrap();
        let thr = Thresholds::default();
        let reports = aggregate_batch(&out, &thr);
        assert_eq!(reports.len(), man.batch_infer);
        for (v, r) in reports.iter().enumerate() {
            let mut all_under = true;
            for p in Param::ALL {
                let e = r.get(p);
                assert!(e.mean.is_finite() && e.std >= 0.0, "voxel {v} {p:?}");
                // definition: relative = std/mean with the ~0-mean guard
                let want = if e.mean.abs() < 1e-12 {
                    0.0
                } else {
                    e.std / e.mean
                };
                assert_eq!(e.relative, want, "voxel {v} {p:?}");
                if e.relative > thr.get(p) {
                    all_under = false;
                }
            }
            assert_eq!(r.confident, all_under, "voxel {v} flag disagrees");
        }
        // the batch helper and the per-voxel path agree
        let m = mean_relative(&reports, Param::F);
        let direct: f64 =
            reports.iter().map(|r| r.get(Param::F).relative).sum::<f64>() / reports.len() as f64;
        assert_eq!(m, direct);
    }
}
