//! Dynamic batcher: size-or-deadline batching with tail padding.
//!
//! The AOT inference executable has a static batch shape `B`, so the
//! batcher's invariants are load-bearing:
//!
//! 1. a batch never exceeds `B` voxels;
//! 2. a request never waits longer than `max_wait` before being flushed;
//! 3. tail batches are zero-padded up to `B` — padding rows are marked
//!    (`real`) so their outputs are dropped, and the zero fill makes a
//!    padding leak deterministic and obvious rather than a silent copy
//!    of a neighbouring patient's voxel;
//! 4. FIFO order is preserved within and across batches.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::pool::VecPool;

/// Configuration of the dynamic batcher.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Engine batch size (the AOT executable's static B).
    pub batch_size: usize,
    /// Maximum time the oldest queued request may wait.
    pub max_wait: Duration,
    /// Queue capacity before backpressure kicks in.
    pub queue_capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch_size: 64,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
        }
    }
}

/// One queued request.
#[derive(Debug, Clone)]
pub struct Pending<T> {
    pub signals: Vec<f32>,
    pub tag: T,
    pub enqueued: Instant,
}

/// A formed batch ready for the engine.
#[derive(Debug, Clone)]
pub struct Batch<T> {
    /// Row-major `[batch_size][nb]` signals, padded to the full size.
    pub signals: Vec<f32>,
    /// Tags of the real (non-padding) rows, in row order.
    pub tags: Vec<T>,
    /// Number of real rows (<= batch_size).
    pub real: usize,
}

/// The batcher state machine.  Single-consumer; thread-safety is provided
/// by the server's ownership structure (one batcher per worker).
pub struct Batcher<T> {
    cfg: BatcherConfig,
    nb: usize,
    queue: VecDeque<Pending<T>>,
    /// Recycling pool for the per-batch signal buffers (`cut` would
    /// otherwise allocate one `Vec<f32>` per batch).  Shared with
    /// whoever consumes the batches, which returns buffers after use.
    signal_pool: Option<Arc<VecPool>>,
    /// Recycling pool for the **per-request** signal buffers: once `cut`
    /// has copied a pending request's signals into the batch buffer, the
    /// request's own `Vec` is dead weight — with a pool it goes back to
    /// `Coordinator::lease()` for the next caller instead of being
    /// dropped, closing the last caller-side allocation on the serving
    /// path.
    request_pool: Option<Arc<VecPool>>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig, nb: usize) -> Self {
        assert!(cfg.batch_size > 0, "batch_size must be positive");
        Batcher {
            cfg,
            nb,
            queue: VecDeque::new(),
            signal_pool: None,
            request_pool: None,
        }
    }

    /// Batcher whose cut batches draw their signal buffers from (and,
    /// via the consumer, return them to) `pool`.
    pub fn with_pool(cfg: BatcherConfig, nb: usize, pool: Arc<VecPool>) -> Self {
        let mut b = Self::new(cfg, nb);
        b.signal_pool = Some(pool);
        b
    }

    /// [`Batcher::with_pool`] plus a **request** pool: `cut` reclaims
    /// each pending request's own signal `Vec` into `request_pool` the
    /// moment its rows are copied into the batch buffer.
    pub fn with_pools(
        cfg: BatcherConfig,
        nb: usize,
        signal_pool: Arc<VecPool>,
        request_pool: Arc<VecPool>,
    ) -> Self {
        let mut b = Self::with_pool(cfg, nb, signal_pool);
        b.request_pool = Some(request_pool);
        b
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// True when the queue is at capacity (backpressure: callers must
    /// retry or shed load).
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.cfg.queue_capacity
    }

    /// Enqueue a request.  Returns `Err` with the request when full.
    pub fn push(&mut self, req: Pending<T>) -> Result<(), Pending<T>> {
        if self.is_full() {
            return Err(req);
        }
        assert_eq!(req.signals.len(), self.nb, "voxel width mismatch");
        self.queue.push_back(req);
        Ok(())
    }

    /// Age of the oldest queued request.
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| now.duration_since(p.enqueued))
    }

    /// Should a batch be cut right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.cfg.batch_size {
            return true;
        }
        match self.oldest_wait(now) {
            Some(w) => !self.queue.is_empty() && w >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Cut a batch (caller checked `ready`, but cutting an early batch is
    /// legal too).  Zero-fills the tail up to the static shape.
    pub fn cut(&mut self) -> Option<Batch<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.queue.len().min(self.cfg.batch_size);
        let want = self.cfg.batch_size * self.nb;
        let mut signals = match &self.signal_pool {
            Some(pool) => pool.take(want),
            None => Vec::with_capacity(want),
        };
        let mut tags = Vec::with_capacity(take);
        for _ in 0..take {
            let p = self.queue.pop_front().expect("non-empty");
            signals.extend_from_slice(&p.signals);
            tags.push(p.tag);
            // the request's own buffer is consumed: back to the lease
            // slab for the next caller
            if let Some(pool) = &self.request_pool {
                pool.put(p.signals);
            }
        }
        // Zero-pad to the static shape; padded rows are dropped by `real`.
        signals.resize(self.cfg.batch_size * self.nb, 0.0);
        Some(Batch {
            signals,
            tags,
            real: take,
        })
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(i: usize, nb: usize) -> Pending<usize> {
        Pending {
            signals: vec![i as f32; nb],
            tag: i,
            enqueued: Instant::now(),
        }
    }

    fn mk(batch: usize, cap: usize) -> Batcher<usize> {
        Batcher::new(
            BatcherConfig {
                batch_size: batch,
                max_wait: Duration::from_millis(1),
                queue_capacity: cap,
            },
            4,
        )
    }

    #[test]
    fn cuts_full_batches_fifo() {
        let mut b = mk(4, 100);
        for i in 0..10 {
            b.push(pend(i, 4)).unwrap();
        }
        assert!(b.ready(Instant::now()));
        let batch = b.cut().unwrap();
        assert_eq!(batch.real, 4);
        assert_eq!(batch.tags, vec![0, 1, 2, 3]);
        assert_eq!(batch.signals.len(), 16);
        let batch2 = b.cut().unwrap();
        assert_eq!(batch2.tags, vec![4, 5, 6, 7]);
    }

    #[test]
    fn pads_tail_batches() {
        let mut b = mk(4, 100);
        b.push(pend(7, 4)).unwrap();
        b.push(pend(8, 4)).unwrap();
        let batch = b.cut().unwrap();
        assert_eq!(batch.real, 2);
        assert_eq!(batch.tags, vec![7, 8]);
        assert_eq!(batch.signals.len(), 16);
        // real rows intact, padding rows zero-filled
        assert_eq!(&batch.signals[0..4], &[7.0, 7.0, 7.0, 7.0]);
        assert_eq!(&batch.signals[4..8], &[8.0, 8.0, 8.0, 8.0]);
        assert_eq!(&batch.signals[8..12], &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(&batch.signals[12..16], &[0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn deadline_triggers_ready() {
        let mut b = mk(64, 100);
        assert!(!b.ready(Instant::now()));
        b.push(pend(0, 4)).unwrap();
        let now = Instant::now();
        assert!(!b.ready(now)); // not full, not old
        let later = now + Duration::from_millis(5);
        assert!(b.ready(later)); // oldest exceeded max_wait
    }

    #[test]
    fn backpressure_at_capacity() {
        let mut b = mk(4, 3);
        for i in 0..3 {
            b.push(pend(i, 4)).unwrap();
        }
        assert!(b.is_full());
        let rejected = b.push(pend(9, 4));
        assert!(rejected.is_err());
        assert_eq!(rejected.unwrap_err().tag, 9);
        // draining frees capacity
        b.cut().unwrap();
        assert!(b.push(pend(10, 4)).is_ok());
    }

    #[test]
    fn empty_cut_is_none() {
        let mut b = mk(4, 10);
        assert!(b.cut().is_none());
    }

    /// A pool-backed batcher recycles returned signal buffers: the
    /// second cut reuses the first cut's allocation instead of
    /// allocating a fresh `Vec` per batch.
    #[test]
    fn pooled_cut_recycles_signal_buffers() {
        let pool = Arc::new(VecPool::new(4));
        let mut b = Batcher::with_pool(
            BatcherConfig {
                batch_size: 4,
                max_wait: Duration::from_millis(1),
                queue_capacity: 100,
            },
            4,
            Arc::clone(&pool),
        );
        for i in 0..8 {
            b.push(pend(i, 4)).unwrap();
        }
        let first = b.cut().unwrap();
        assert_eq!(first.tags, vec![0, 1, 2, 3]);
        let ptr = first.signals.as_ptr();
        pool.put(first.signals); // the consumer's hand-back
        let second = b.cut().unwrap();
        assert_eq!(second.signals.as_ptr(), ptr, "cut must reuse the pooled buffer");
        assert_eq!(second.tags, vec![4, 5, 6, 7]);
        assert_eq!(&second.signals[0..4], &[4.0; 4]);
    }

    /// A request-pool-backed batcher hands each consumed pending's own
    /// signal `Vec` back at cut time — the lease slab's reclaim point.
    #[test]
    fn cut_reclaims_request_buffers_into_the_lease_pool() {
        let signal_pool = Arc::new(VecPool::new(4));
        let request_pool = Arc::new(VecPool::new(8));
        let mut b = Batcher::with_pools(
            BatcherConfig {
                batch_size: 4,
                max_wait: Duration::from_millis(1),
                queue_capacity: 100,
            },
            4,
            Arc::clone(&signal_pool),
            Arc::clone(&request_pool),
        );
        for i in 0..6 {
            let mut signals = request_pool.take(4);
            signals.resize(4, i as f32);
            b.push(Pending {
                signals,
                tag: i,
                enqueued: Instant::now(),
            })
            .unwrap();
        }
        assert_eq!(request_pool.created(), 6);
        assert_eq!(request_pool.idle(), 0, "all six buffers are leased out");
        let batch = b.cut().unwrap();
        assert_eq!(batch.real, 4);
        assert_eq!(
            request_pool.idle(),
            4,
            "cut returns each consumed request's buffer"
        );
        let tail = b.cut().unwrap();
        assert_eq!(tail.real, 2);
        assert_eq!(request_pool.idle(), 6);
        // steady state: a new wave of requests reuses the reclaimed
        // buffers — the high-water mark does not move
        for i in 0..6 {
            let mut signals = request_pool.take(4);
            signals.resize(4, i as f32);
            b.push(Pending {
                signals,
                tag: 10 + i,
                enqueued: Instant::now(),
            })
            .unwrap();
        }
        while b.cut().is_some() {}
        assert_eq!(request_pool.created(), 6, "wave 2 allocated nothing");
    }

    #[test]
    fn property_batch_invariants() {
        use crate::testing::{forall, zip, Gen};
        // For any queue length and batch size: cut yields <= batch_size
        // real rows, padded signal length == batch_size * nb, FIFO order.
        forall(
            80,
            zip(Gen::usize_in(1, 32), Gen::usize_in(1, 100)),
            |&(bs, n): &(usize, usize)| {
                let mut b = Batcher::new(
                    BatcherConfig {
                        batch_size: bs,
                        max_wait: Duration::from_millis(1),
                        queue_capacity: 1000,
                    },
                    2,
                );
                for i in 0..n {
                    b.push(Pending {
                        signals: vec![i as f32; 2],
                        tag: i,
                        enqueued: Instant::now(),
                    })
                    .unwrap();
                }
                let mut seen = Vec::new();
                while let Some(batch) = b.cut() {
                    if batch.real > bs || batch.signals.len() != bs * 2 {
                        return false;
                    }
                    seen.extend(batch.tags);
                }
                seen == (0..n).collect::<Vec<_>>()
            },
        );
    }

    #[test]
    fn property_tail_padding_is_zero_filled() {
        use crate::testing::{forall, zip, Gen};
        let nb = 3usize;
        // For any batch size and queue length: every padding row of every
        // cut batch is exactly zero, and every real row carries its own
        // (non-zero) signals untouched.
        forall(
            80,
            zip(Gen::usize_in(1, 24), Gen::usize_in(1, 80)),
            |&(bs, n): &(usize, usize)| {
                let mut b = Batcher::new(
                    BatcherConfig {
                        batch_size: bs,
                        max_wait: Duration::from_millis(1),
                        queue_capacity: 1000,
                    },
                    nb,
                );
                for i in 0..n {
                    b.push(Pending {
                        signals: vec![(i + 1) as f32; nb], // never zero
                        tag: i,
                        enqueued: Instant::now(),
                    })
                    .unwrap();
                }
                let mut next = 0usize;
                while let Some(batch) = b.cut() {
                    for row in 0..bs {
                        let r = &batch.signals[row * nb..(row + 1) * nb];
                        if row < batch.real {
                            if r != vec![(next + 1) as f32; nb].as_slice() {
                                return false;
                            }
                            next += 1;
                        } else if r.iter().any(|&v| v != 0.0) {
                            return false;
                        }
                    }
                }
                next == n
            },
        );
    }

    #[test]
    fn property_fifo_holds_within_and_across_batches() {
        use crate::testing::{forall, zip, Gen};
        // Interleave pushes and cuts: tags must still come out in global
        // FIFO order.  `cut_every` controls how often a cut is forced
        // mid-stream (early partial cuts are legal).
        forall(
            60,
            zip(Gen::usize_in(1, 16), Gen::usize_in(1, 7)),
            |&(bs, cut_every): &(usize, usize)| {
                let mut b = Batcher::new(
                    BatcherConfig {
                        batch_size: bs,
                        max_wait: Duration::from_millis(1),
                        queue_capacity: 1000,
                    },
                    2,
                );
                let n = 40usize;
                let mut seen = Vec::new();
                for i in 0..n {
                    b.push(Pending {
                        signals: vec![i as f32; 2],
                        tag: i,
                        enqueued: Instant::now(),
                    })
                    .unwrap();
                    if (i + 1) % cut_every == 0 {
                        if let Some(batch) = b.cut() {
                            seen.extend(batch.tags);
                        }
                    }
                }
                while let Some(batch) = b.cut() {
                    seen.extend(batch.tags);
                }
                seen == (0..n).collect::<Vec<_>>()
            },
        );
    }

    #[test]
    fn property_deadline_flush_fires_with_partial_batch() {
        use crate::testing::{forall, zip, Gen};
        // For any batch size >= 2 and any shorter queue: the batch is not
        // ready before the deadline, becomes ready after it, and the
        // flush yields exactly one partial batch with all queued rows.
        forall(
            80,
            zip(Gen::usize_in(2, 32), Gen::usize_in(1, 31)),
            |&(bs, k): &(usize, usize)| {
                let k = k.min(bs - 1); // strictly partial
                let max_wait = Duration::from_millis(5);
                let mut b = Batcher::new(
                    BatcherConfig {
                        batch_size: bs,
                        max_wait,
                        queue_capacity: 1000,
                    },
                    2,
                );
                let t0 = Instant::now();
                for i in 0..k {
                    b.push(Pending {
                        signals: vec![i as f32; 2],
                        tag: i,
                        enqueued: t0,
                    })
                    .unwrap();
                }
                // not full, not old -> not ready at enqueue time
                if b.ready(t0) {
                    return false;
                }
                // past the deadline -> ready despite being partial
                let late = t0 + max_wait * 2;
                if !b.ready(late) {
                    return false;
                }
                let Some(batch) = b.cut() else { return false };
                batch.real == k
                    && batch.tags == (0..k).collect::<Vec<_>>()
                    && b.is_empty()
                    && b.cut().is_none()
            },
        );
    }
}
