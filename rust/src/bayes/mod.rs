//! Uncertainty-method baselines (paper §II-C): MC-Dropout and Deep
//! Ensembles heads over the native engine, for the
//! Masksembles-vs-alternatives ablation.
//!
//! * [`McDropout`] — random Bernoulli masks drawn *per forward pass*
//!   (the runtime randomness the paper's hardware specifically removes;
//!   its cost shows up in the Table I sampler-energy ablation).  The
//!   per-sample engine rebuild inside `execute_into` *is* that sampler
//!   cost — it is the one backend that allocates in steady state, by
//!   construction of the method.
//! * [`DeepEnsemble`] — N independently initialised weight sets; the
//!   calibration gold standard at N-times the memory cost.  Member
//!   engines are built once at construction (the plan phase), so its
//!   hot path is allocation-free like the native engine's.
//!
//! Both are registry backends (`mc-dropout`, `ensemble`) and reach the
//! native engine only through [`registry::build`].

use crate::infer::registry::{self, EngineName, EngineOpts};
use crate::infer::{Engine, InferOutput};
use crate::ivim::Param;
use crate::masks::MaskSet;
use crate::model::{Manifest, Weights};
use crate::util::rng::Pcg32;

/// MC-Dropout: the manifest's network evaluated under freshly sampled
/// Bernoulli masks each call (rate ~= 1 - 1/scale, matching the
/// Masksembles keep fraction).
pub struct McDropout {
    man: Manifest,
    weights: Weights,
    batch: usize,
    n_samples: usize,
    keep_prob: f64,
    rng: Pcg32,
    /// One-sample output reused across the per-sample engine runs.
    scratch: InferOutput,
}

impl McDropout {
    pub fn new(man: &Manifest, weights: &Weights, seed: u64) -> Self {
        Self::with_batch(man, weights, man.batch_infer, seed)
    }

    /// MC-Dropout head with an explicit batch size (registry path).
    pub fn with_batch(man: &Manifest, weights: &Weights, batch: usize, seed: u64) -> Self {
        McDropout {
            man: man.clone(),
            weights: weights.clone(),
            batch,
            n_samples: man.n_samples,
            keep_prob: 1.0 / man.scale,
            rng: Pcg32::new(seed),
            scratch: InferOutput::new(1, batch),
        }
    }

    fn sample_mask(&mut self, width: usize) -> MaskSet {
        // Bernoulli per neuron; re-draw all-zero masks (a dead layer
        // would zero the subnet exactly like the elision bug class).
        loop {
            let bits: Vec<u8> = (0..width)
                .map(|_| u8::from(self.rng.next_f64() < self.keep_prob))
                .collect();
            if bits.iter().any(|&b| b == 1) {
                return MaskSet {
                    n: 1,
                    width,
                    bits,
                };
            }
        }
    }
}

impl Engine for McDropout {
    fn name(&self) -> &str {
        "mc-dropout"
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn n_samples(&self) -> usize {
        self.n_samples
    }

    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        out.reset(self.n_samples, self.batch);
        for s in 0..self.n_samples {
            // Build a one-sample manifest clone with random masks — the
            // runtime-sampler cost Masksembles' fixed masks avoid.
            let mut man = self.man.clone();
            man.n_samples = 1;
            for sn in man.subnets.clone() {
                for layer in 1..=2usize {
                    let m = self.sample_mask(man.nb);
                    man.masks.insert(format!("{sn}.mask{layer}"), m);
                }
            }
            let opts = EngineOpts {
                batch: Some(self.batch),
                ..Default::default()
            };
            let mut eng = registry::build(EngineName::Native, &man, &self.weights, &opts)?;
            eng.execute_into(signals, &mut self.scratch)?;
            for p in Param::ALL {
                for v in 0..self.batch {
                    out.set(p, s, v, self.scratch.get(p, 0, v));
                }
            }
        }
        Ok(())
    }
}

/// Deep Ensemble: N independently initialised (optionally independently
/// trained) weight vectors, no masks (all-ones).  Member engines are
/// built once up front; `execute_into` just runs them in turn.
pub struct DeepEnsemble {
    members: Vec<Box<dyn Engine>>,
    batch: usize,
    /// One-sample output reused across member runs.
    scratch: InferOutput,
}

impl DeepEnsemble {
    /// Build from explicit member weights.
    pub fn new(man: &Manifest, members: Vec<Weights>) -> anyhow::Result<Self> {
        Self::with_batch(man, members, man.batch_infer)
    }

    /// Ensemble with an explicit batch size (registry path).
    pub fn with_batch(
        man: &Manifest,
        member_weights: Vec<Weights>,
        batch: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!member_weights.is_empty(), "ensemble needs members");
        let dense = Self::all_ones_manifest(man);
        let opts = EngineOpts {
            batch: Some(batch),
            ..Default::default()
        };
        let members = member_weights
            .iter()
            .map(|w| registry::build(EngineName::Native, &dense, w, &opts))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(DeepEnsemble {
            members,
            batch,
            scratch: InferOutput::new(1, batch),
        })
    }

    /// Fresh ensemble with random independent initialisations.
    pub fn init_random(man: &Manifest, n: usize, seed: u64) -> anyhow::Result<Self> {
        Self::init_random_with_batch(man, n, seed, man.batch_infer)
    }

    /// `init_random` with an explicit batch size (registry path).
    pub fn init_random_with_batch(
        man: &Manifest,
        n: usize,
        seed: u64,
        batch: usize,
    ) -> anyhow::Result<Self> {
        let members = (0..n)
            .map(|i| Weights::init_random(man, seed + i as u64))
            .collect();
        Self::with_batch(man, members, batch)
    }

    fn all_ones_manifest(man: &Manifest) -> Manifest {
        let mut m = man.clone();
        m.n_samples = 1;
        for sn in m.subnets.clone() {
            for layer in 1..=2usize {
                m.masks.insert(
                    format!("{sn}.mask{layer}"),
                    MaskSet {
                        n: 1,
                        width: m.nb,
                        bits: vec![1u8; m.nb],
                    },
                );
            }
        }
        m
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Memory cost relative to a single model — the ensemble's known
    /// downside (paper §II-C: "heavy operational costs").
    pub fn memory_ratio(&self) -> f64 {
        self.members.len() as f64
    }
}

impl Engine for DeepEnsemble {
    fn name(&self) -> &str {
        "deep-ensemble"
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn n_samples(&self) -> usize {
        self.members.len()
    }

    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        let n = self.members.len();
        out.reset(n, self.batch);
        let batch = self.batch;
        let scratch = &mut self.scratch;
        for (s, eng) in self.members.iter_mut().enumerate() {
            eng.execute_into(signals, scratch)?;
            for p in Param::ALL {
                for v in 0..batch {
                    out.set(p, s, v, scratch.get(p, 0, v));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn mc_dropout_produces_spread() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 42);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
        let out = mcd.infer_batch(&ds.signals).unwrap();
        let spread: f64 = (0..out.batch).map(|v| out.std(Param::F, v)).sum();
        assert!(spread > 0.0, "random masks must induce variance");
    }

    #[test]
    fn mc_dropout_is_stochastic_across_calls() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 42);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 2);
        let a = mcd.infer_batch(&ds.signals).unwrap();
        let b = mcd.infer_batch(&ds.signals).unwrap();
        // unlike Masksembles, MC-Dropout is NOT repeatable run-to-run
        assert_ne!(a.samples[Param::F.index()], b.samples[Param::F.index()]);
    }

    #[test]
    fn deep_ensemble_members_disagree() {
        let Some((man, _)) = setup() else { return };
        let mut de = DeepEnsemble::init_random(&man, 3, 7).unwrap();
        assert_eq!(de.len(), 3);
        assert_eq!(de.memory_ratio(), 3.0);
        assert_eq!(de.n_samples(), 3);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 3);
        let out = de.infer_batch(&ds.signals).unwrap();
        let spread: f64 = (0..out.batch).map(|v| out.std(Param::D, v)).sum();
        assert!(spread > 0.0);
    }

    #[test]
    fn deep_ensemble_hot_path_reuses_output() {
        let Some((man, _)) = setup() else { return };
        let mut de = DeepEnsemble::init_random(&man, 2, 9).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 4);
        let mut out = InferOutput::new(de.n_samples(), de.batch_size());
        de.execute_into(&ds.signals, &mut out).unwrap();
        let before: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        de.execute_into(&ds.signals, &mut out).unwrap();
        let after: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        assert_eq!(before, after, "ensemble hot path must not reallocate");
    }

    #[test]
    fn ensemble_needs_members() {
        let Some((man, _)) = setup() else { return };
        assert!(DeepEnsemble::new(&man, vec![]).is_err());
    }
}
