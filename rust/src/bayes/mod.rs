//! Uncertainty-method baselines (paper §II-C): MC-Dropout and Deep
//! Ensembles heads over the native engine, for the
//! Masksembles-vs-alternatives ablation.
//!
//! * [`McDropout`] — random Bernoulli masks drawn *per forward pass*
//!   (the runtime randomness the paper's hardware specifically removes;
//!   its cost shows up in the Table I sampler-energy ablation).  Since
//!   the mask-lifecycle refactor the head owns **one** `NativeEngine`
//!   plus one [`MaskPlan`] and runs `resample → swap_masks →
//!   execute_into` per call — genuinely zero-alloc in steady state, so
//!   the sampler overhead is the *mask swap*, measurable in isolation
//!   (the ablation's fresh-build column shows what the old
//!   engine-rebuild-per-sample path cost instead).
//! * [`DeepEnsemble`] — N independently initialised weight sets; the
//!   calibration gold standard at N-times the memory cost.  Member
//!   engines are built once at construction (the plan phase) from a
//!   shared all-ones [`MaskPlan`], so its hot path is allocation-free
//!   like the native engine's.
//!
//! * [`AccelMcDropout`] — the fixed-point twin of [`McDropout`]: the
//!   same resample → swap → execute loop over the accelerator
//!   simulator's Q4.12 datapath (`AccelSimulator::swap_masks`), so
//!   MC-sampling studies and DSE sweeps can draw many masks over one
//!   fixed quantised weight block without re-instantiating the datapath.
//!
//! `DeepEnsemble` members come from [`registry::build`]; `McDropout` and
//! `AccelMcDropout` hold concrete engines because the hot swap is
//! engine-specific state, not part of the `Engine` trait.

pub mod pipeline;

use crate::accel::{AccelConfig, AccelSimulator, CycleStats, Scheme};
use crate::infer::native::NativeEngine;
use crate::infer::registry::{self, EngineOpts};
use crate::infer::{Engine, InferOutput};
use crate::ivim::Param;
use crate::masks::MaskPlan;
use crate::model::{Manifest, Weights};
use crate::util::rng::Pcg32;

/// MC-Dropout: the manifest's network evaluated under freshly sampled
/// Bernoulli masks each call (keep rate 1/scale, matching the
/// Masksembles keep fraction).
///
/// The redraw can be restricted to a layer range: the last-layer-only
/// variant (`layer_lo = layer_hi = 2`, registry name `mc-dropout-ll`)
/// resamples just the final masked layer per pass — untouched layers
/// keep their mask bits and packed blocks bit-identical across passes,
/// so the per-pass sampler cost shrinks with the redrawn fraction
/// (ROADMAP direction #3's cheap-sampler axis).
pub struct McDropout {
    engine: NativeEngine,
    plan: MaskPlan,
    rng: Pcg32,
    batch: usize,
    n_samples: usize,
    layer_lo: usize,
    layer_hi: usize,
    name: &'static str,
}

impl McDropout {
    pub fn new(man: &Manifest, weights: &Weights, seed: u64) -> anyhow::Result<Self> {
        Self::with_batch(man, weights, man.batch_infer, seed)
    }

    /// MC-Dropout head with an explicit batch size (registry path).
    pub fn with_batch(
        man: &Manifest,
        weights: &Weights,
        batch: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        Self::build(man, weights, batch, seed, 1, (1, 2), "mc-dropout")
    }

    /// Full-resample head over a `threads`-lane tiled engine (bit-exact
    /// vs `threads = 1` — the engine's tiling contract).
    pub fn with_batch_threads(
        man: &Manifest,
        weights: &Weights,
        batch: usize,
        seed: u64,
        threads: usize,
    ) -> anyhow::Result<Self> {
        Self::build(man, weights, batch, seed, threads, (1, 2), "mc-dropout")
    }

    /// Last-layer-only head: only layer-2 plans are redrawn per pass
    /// (registry name `mc-dropout-ll`).
    pub fn last_layer_with_batch(
        man: &Manifest,
        weights: &Weights,
        batch: usize,
        seed: u64,
        threads: usize,
    ) -> anyhow::Result<Self> {
        Self::build(man, weights, batch, seed, threads, (2, 2), "mc-dropout-ll")
    }

    fn build(
        man: &Manifest,
        weights: &Weights,
        batch: usize,
        seed: u64,
        threads: usize,
        layers: (usize, usize),
        name: &'static str,
    ) -> anyhow::Result<Self> {
        let mut rng = Pcg32::new(seed);
        let plan = MaskPlan::bernoulli(man, 1.0 / man.scale, &mut rng);
        let mut engine = NativeEngine::with_batch_threads(man, weights, batch, threads)?;
        engine.swap_masks(&plan)?;
        Ok(McDropout {
            engine,
            plan,
            rng,
            batch,
            n_samples: man.n_samples,
            layer_lo: layers.0,
            layer_hi: layers.1,
            name,
        })
    }

    /// The live plan (tests: untouched-layer bit-identity).
    pub fn plan(&self) -> &MaskPlan {
        &self.plan
    }

    /// Buffer capacities of the head's entire state (plan + engine) —
    /// the steady-state no-allocation witness.
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = self.plan.alloc_signature();
        sig.extend(self.engine.alloc_signature());
        sig
    }
}

impl Engine for McDropout {
    fn name(&self) -> &str {
        self.name
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn n_samples(&self) -> usize {
        self.n_samples
    }

    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        // The runtime-sampler cost Masksembles' fixed masks avoid, now
        // an in-place mask redraw + union re-pack instead of a full
        // engine rebuild per sample: no steady-state allocation.  The
        // full range delegates to the same code path, so `mc-dropout`
        // stays bit-identical to the pre-range implementation.
        self.plan.resample_layer_range(self.layer_lo, self.layer_hi, &mut self.rng);
        self.engine.swap_masks(&self.plan)?;
        self.engine.execute_into(signals, out)
    }
}

/// MC-Dropout over the accelerator simulator — the fixed-point twin of
/// [`McDropout`]: one [`AccelSimulator`] + one [`MaskPlan`] + [`Pcg32`],
/// running `resample → swap_masks → execute_into` per call.  The
/// quantised weight block is built once; every mask draw is an in-place
/// kept-column re-selection (zero steady-state allocation), which is
/// exactly how SoftDropConnect-style mask sampling runs on the paper's
/// fixed-weight hardware.
pub struct AccelMcDropout {
    sim: AccelSimulator,
    plan: MaskPlan,
    rng: Pcg32,
    batch: usize,
    n_samples: usize,
}

impl AccelMcDropout {
    pub fn new(man: &Manifest, weights: &Weights, seed: u64) -> anyhow::Result<Self> {
        Self::with_batch(man, weights, man.batch_infer, seed)
    }

    /// Fixed-point MC-Dropout head with an explicit batch size (registry
    /// path).  Runs the batch-level scheme, like the `accel` engine.
    pub fn with_batch(
        man: &Manifest,
        weights: &Weights,
        batch: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let mut rng = Pcg32::new(seed);
        let plan = MaskPlan::bernoulli(man, 1.0 / man.scale, &mut rng);
        let cfg = AccelConfig {
            batch,
            ..Default::default()
        };
        let mut sim = AccelSimulator::new(man, weights, cfg, Scheme::BatchLevel)?;
        sim.swap_masks(&plan)?;
        Ok(AccelMcDropout {
            sim,
            plan,
            rng,
            batch,
            n_samples: man.n_samples,
        })
    }

    /// Cycle stats of the last executed batch (the simulator's counters
    /// keep working under resampled masks).
    pub fn last_stats(&self) -> CycleStats {
        self.sim.last_stats
    }

    /// Buffer capacities of the head's entire state (plan + simulator) —
    /// the steady-state no-allocation witness.
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = self.plan.alloc_signature();
        sig.extend(self.sim.alloc_signature());
        sig
    }
}

impl Engine for AccelMcDropout {
    fn name(&self) -> &str {
        "accel-mc"
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn n_samples(&self) -> usize {
        self.n_samples
    }

    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        self.plan.resample(&mut self.rng);
        self.sim.swap_masks(&self.plan)?;
        self.sim.execute_into(signals, out)
    }
}

/// Deep Ensemble: N independently initialised (optionally independently
/// trained) weight vectors, no masks (all-ones plan).  Member engines
/// are built once up front; `execute_into` just runs them in turn.
pub struct DeepEnsemble {
    members: Vec<Box<dyn Engine>>,
    batch: usize,
    /// One-sample output reused across member runs.
    scratch: InferOutput,
}

impl DeepEnsemble {
    /// Build from explicit member weights.
    pub fn new(man: &Manifest, members: Vec<Weights>) -> anyhow::Result<Self> {
        Self::with_batch(man, members, man.batch_infer)
    }

    /// Ensemble with an explicit batch size (registry path).
    pub fn with_batch(
        man: &Manifest,
        member_weights: Vec<Weights>,
        batch: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!member_weights.is_empty(), "ensemble needs members");
        // Members run dense: a one-sample all-ones plan baked into the
        // member manifest (the same plan type the hot swap uses).
        let mut dense = man.clone();
        MaskPlan::all_ones(man, 1).apply_to_manifest(&mut dense);
        let opts = EngineOpts {
            batch: Some(batch),
            ..Default::default()
        };
        let members = member_weights
            .iter()
            .map(|w| registry::build("native", &dense, w, &opts))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(DeepEnsemble {
            members,
            batch,
            scratch: InferOutput::new(1, batch),
        })
    }

    /// Fresh ensemble with random independent initialisations.
    pub fn init_random(man: &Manifest, n: usize, seed: u64) -> anyhow::Result<Self> {
        Self::init_random_with_batch(man, n, seed, man.batch_infer)
    }

    /// `init_random` with an explicit batch size (registry path).
    pub fn init_random_with_batch(
        man: &Manifest,
        n: usize,
        seed: u64,
        batch: usize,
    ) -> anyhow::Result<Self> {
        let members = (0..n)
            .map(|i| Weights::init_random(man, seed + i as u64))
            .collect();
        Self::with_batch(man, members, batch)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Memory cost relative to a single model — the ensemble's known
    /// downside (paper §II-C: "heavy operational costs").
    pub fn memory_ratio(&self) -> f64 {
        self.members.len() as f64
    }
}

impl Engine for DeepEnsemble {
    fn name(&self) -> &str {
        "deep-ensemble"
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn n_samples(&self) -> usize {
        self.members.len()
    }

    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        let n = self.members.len();
        out.reset(n, self.batch);
        let batch = self.batch;
        let scratch = &mut self.scratch;
        for (s, eng) in self.members.iter_mut().enumerate() {
            eng.execute_into(signals, scratch)?;
            for p in Param::ALL {
                for v in 0..batch {
                    out.set(p, s, v, scratch.get(p, 0, v));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn mc_dropout_produces_spread() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 42).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
        let out = mcd.infer_batch(&ds.signals).unwrap();
        let spread: f64 = (0..out.batch).map(|v| out.std(Param::F, v)).sum();
        assert!(spread > 0.0, "random masks must induce variance");
    }

    #[test]
    fn mc_dropout_is_stochastic_across_calls() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 42).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 2);
        let a = mcd.infer_batch(&ds.signals).unwrap();
        let b = mcd.infer_batch(&ds.signals).unwrap();
        // unlike Masksembles, MC-Dropout is NOT repeatable run-to-run
        assert_ne!(a.samples[Param::F.index()], b.samples[Param::F.index()]);
    }

    #[test]
    fn mc_dropout_is_deterministic_in_seed() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 5);
        let mut a = McDropout::new(&man, &w, 7).unwrap();
        let mut b = McDropout::new(&man, &w, 7).unwrap();
        let oa = a.infer_batch(&ds.signals).unwrap();
        let ob = b.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(oa.samples[p.index()], ob.samples[p.index()]);
        }
    }

    /// ISSUE #3 acceptance: the rewritten MC-Dropout hot loop performs
    /// zero heap allocation in steady state — every buffer capacity
    /// (mask plan, packed weight blocks, engine scratch, output) is
    /// stable across calls after the first.
    #[test]
    fn mc_dropout_steady_state_never_reallocates() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 11).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 6);
        let mut out = InferOutput::new(mcd.n_samples(), mcd.batch_size());
        mcd.execute_into(&ds.signals, &mut out).unwrap();
        let sig = mcd.alloc_signature();
        let out_ptrs: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        for _ in 0..20 {
            mcd.execute_into(&ds.signals, &mut out).unwrap();
            assert_eq!(mcd.alloc_signature(), sig, "hot loop reallocated");
            let after: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
            assert_eq!(out_ptrs, after, "output buffers were reallocated");
        }
    }

    /// Satellite (ISSUE #8): the last-layer-only head redraws only
    /// layer-2 plans — untouched layers' mask bits, index lists and
    /// union stay bit-identical across passes — and remains
    /// seed-deterministic and spread-producing.
    #[test]
    fn last_layer_head_keeps_untouched_layers_bit_identical() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 9);
        let mut ll = McDropout::last_layer_with_batch(&man, &w, man.batch_infer, 21, 1).unwrap();
        assert_eq!(Engine::name(&ll), "mc-dropout-ll");
        let n_subnets = man.subnets.len();
        let l1_bits: Vec<_> = (0..n_subnets).map(|si| ll.plan().layer(si, 1).to_mask_set()).collect();
        let l1_kept: Vec<Vec<Vec<u32>>> =
            (0..n_subnets).map(|si| ll.plan().layer(si, 1).kept_lists().to_vec()).collect();
        let l2_bits: Vec<_> = (0..n_subnets).map(|si| ll.plan().layer(si, 2).to_mask_set()).collect();
        let mut out = InferOutput::new(ll.n_samples(), ll.batch_size());
        let mut l2_changed = false;
        for pass in 0..4 {
            ll.execute_into(&ds.signals, &mut out).unwrap();
            for si in 0..n_subnets {
                assert_eq!(
                    ll.plan().layer(si, 1).to_mask_set(),
                    l1_bits[si],
                    "pass {pass}: layer-1 bits redrawn by the last-layer head"
                );
                assert_eq!(ll.plan().layer(si, 1).kept_lists(), l1_kept[si].as_slice());
            }
            l2_changed |= (0..n_subnets).any(|si| ll.plan().layer(si, 2).to_mask_set() != l2_bits[si]);
        }
        assert!(l2_changed, "layer-2 plans never changed");
        // seed-deterministic like the full head
        let mut a = McDropout::last_layer_with_batch(&man, &w, man.batch_infer, 33, 1).unwrap();
        let mut b = McDropout::last_layer_with_batch(&man, &w, man.batch_infer, 33, 1).unwrap();
        let oa = a.infer_batch(&ds.signals).unwrap();
        let ob = b.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(oa.samples[p.index()], ob.samples[p.index()]);
        }
        let spread: f64 = (0..oa.batch).map(|v| oa.std(Param::F, v)).sum();
        assert!(spread > 0.0, "masked layers still induce variance");
    }

    /// The threaded full head is bit-identical to the serial head in
    /// the same seed — the tiled engine inside changes nothing.
    #[test]
    fn mc_dropout_threads_match_serial_bit_for_bit() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 10);
        let mut serial = McDropout::with_batch(&man, &w, man.batch_infer, 55).unwrap();
        let mut tiled = McDropout::with_batch_threads(&man, &w, man.batch_infer, 55, 4).unwrap();
        for _ in 0..4 {
            let oa = serial.infer_batch(&ds.signals).unwrap();
            let ob = tiled.infer_batch(&ds.signals).unwrap();
            for p in Param::ALL {
                assert_eq!(oa.samples[p.index()], ob.samples[p.index()]);
            }
        }
    }

    #[test]
    fn accel_mc_produces_spread_and_is_seed_deterministic() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 7);
        let mut a = AccelMcDropout::new(&man, &w, 13).unwrap();
        let mut b = AccelMcDropout::new(&man, &w, 13).unwrap();
        let oa = a.infer_batch(&ds.signals).unwrap();
        let ob = b.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(oa.samples[p.index()], ob.samples[p.index()]);
        }
        let spread: f64 = Param::ALL
            .iter()
            .flat_map(|&p| (0..oa.batch).map(move |v| (p, v)))
            .map(|(p, v)| oa.std(p, v) / (p.range().1 - p.range().0))
            .sum();
        assert!(spread > 0.0, "random masks must induce variance");
        // like McDropout, NOT repeatable across calls on one instance
        let oc = a.infer_batch(&ds.signals).unwrap();
        assert!(
            Param::ALL
                .iter()
                .any(|&p| oa.samples[p.index()] != oc.samples[p.index()]),
            "a second call must redraw the masks"
        );
        assert!(a.last_stats().cycles > 0, "cycle counters keep working");
    }

    /// The fixed-point sampler hot loop performs zero heap allocation in
    /// steady state, like its f32 twin.
    #[test]
    fn accel_mc_steady_state_never_reallocates() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = AccelMcDropout::new(&man, &w, 29).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 8);
        let mut out = InferOutput::new(mcd.n_samples(), mcd.batch_size());
        mcd.execute_into(&ds.signals, &mut out).unwrap();
        let sig = mcd.alloc_signature();
        for _ in 0..20 {
            mcd.execute_into(&ds.signals, &mut out).unwrap();
            assert_eq!(mcd.alloc_signature(), sig, "hot loop reallocated");
        }
    }

    #[test]
    fn deep_ensemble_members_disagree() {
        let Some((man, _)) = setup() else { return };
        let mut de = DeepEnsemble::init_random(&man, 3, 7).unwrap();
        assert_eq!(de.len(), 3);
        assert_eq!(de.memory_ratio(), 3.0);
        assert_eq!(de.n_samples(), 3);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 3);
        let out = de.infer_batch(&ds.signals).unwrap();
        let spread: f64 = (0..out.batch).map(|v| out.std(Param::D, v)).sum();
        assert!(spread > 0.0);
    }

    #[test]
    fn deep_ensemble_hot_path_reuses_output() {
        let Some((man, _)) = setup() else { return };
        let mut de = DeepEnsemble::init_random(&man, 2, 9).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 4);
        let mut out = InferOutput::new(de.n_samples(), de.batch_size());
        de.execute_into(&ds.signals, &mut out).unwrap();
        let before: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        de.execute_into(&ds.signals, &mut out).unwrap();
        let after: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        assert_eq!(before, after, "ensemble hot path must not reallocate");
    }

    #[test]
    fn ensemble_needs_members() {
        let Some((man, _)) = setup() else { return };
        assert!(DeepEnsemble::new(&man, vec![]).is_err());
    }
}
