//! Uncertainty-method baselines (paper §II-C): MC-Dropout and Deep
//! Ensembles heads over the native engine, for the
//! Masksembles-vs-alternatives ablation.
//!
//! * [`McDropout`] — random Bernoulli masks drawn *per forward pass*
//!   (the runtime randomness the paper's hardware specifically removes;
//!   its cost shows up in the Table I sampler-energy ablation).
//! * [`DeepEnsemble`] — N independently initialised weight sets; the
//!   calibration gold standard at N-times the memory cost.

use crate::infer::native::NativeEngine;
use crate::infer::{Engine, InferOutput};
use crate::ivim::Param;
use crate::masks::MaskSet;
use crate::model::{Manifest, Weights};
use crate::util::rng::Pcg32;

/// MC-Dropout: the manifest's network evaluated under freshly sampled
/// Bernoulli masks each call (rate ~= 1 - 1/scale, matching the
/// Masksembles keep fraction).
pub struct McDropout {
    man: Manifest,
    weights: Weights,
    batch: usize,
    n_samples: usize,
    keep_prob: f64,
    rng: Pcg32,
}

impl McDropout {
    pub fn new(man: &Manifest, weights: &Weights, seed: u64) -> Self {
        McDropout {
            man: man.clone(),
            weights: weights.clone(),
            batch: man.batch_infer,
            n_samples: man.n_samples,
            keep_prob: 1.0 / man.scale,
            rng: Pcg32::new(seed),
        }
    }

    fn sample_mask(&mut self, width: usize) -> MaskSet {
        // Bernoulli per neuron; re-draw all-zero masks (a dead layer
        // would zero the subnet exactly like the elision bug class).
        loop {
            let bits: Vec<u8> = (0..width)
                .map(|_| u8::from(self.rng.next_f64() < self.keep_prob))
                .collect();
            if bits.iter().any(|&b| b == 1) {
                return MaskSet {
                    n: 1,
                    width,
                    bits,
                };
            }
        }
    }
}

impl Engine for McDropout {
    fn name(&self) -> &str {
        "mc-dropout"
    }
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer_batch(&mut self, signals: &[f32]) -> anyhow::Result<InferOutput> {
        let mut out = InferOutput::new(self.n_samples, self.batch);
        for s in 0..self.n_samples {
            // Build a one-sample manifest clone with random masks.
            let mut man = self.man.clone();
            man.n_samples = 1;
            for sn in man.subnets.clone() {
                for layer in 1..=2usize {
                    let m = self.sample_mask(man.nb);
                    man.masks.insert(format!("{sn}.mask{layer}"), m);
                }
            }
            let mut eng = NativeEngine::with_batch(&man, &self.weights, self.batch)?;
            let one = eng.infer_batch(signals)?;
            for p in Param::ALL {
                for v in 0..self.batch {
                    out.set(p, s, v, one.get(p, 0, v));
                }
            }
        }
        Ok(out)
    }
}

/// Deep Ensemble: N independently initialised (optionally independently
/// trained) weight vectors, no masks (all-ones).
pub struct DeepEnsemble {
    man: Manifest,
    members: Vec<Weights>,
    batch: usize,
}

impl DeepEnsemble {
    /// Build from explicit member weights.
    pub fn new(man: &Manifest, members: Vec<Weights>) -> anyhow::Result<Self> {
        anyhow::ensure!(!members.is_empty(), "ensemble needs members");
        Ok(DeepEnsemble {
            man: Self::all_ones_manifest(man),
            members,
            batch: man.batch_infer,
        })
    }

    /// Fresh ensemble with random independent initialisations.
    pub fn init_random(man: &Manifest, n: usize, seed: u64) -> anyhow::Result<Self> {
        let members = (0..n)
            .map(|i| Weights::init_random(man, seed + i as u64))
            .collect();
        Self::new(man, members)
    }

    fn all_ones_manifest(man: &Manifest) -> Manifest {
        let mut m = man.clone();
        m.n_samples = 1;
        for sn in m.subnets.clone() {
            for layer in 1..=2usize {
                m.masks.insert(
                    format!("{sn}.mask{layer}"),
                    MaskSet {
                        n: 1,
                        width: m.nb,
                        bits: vec![1u8; m.nb],
                    },
                );
            }
        }
        m
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Memory cost relative to a single model — the ensemble's known
    /// downside (paper §II-C: "heavy operational costs").
    pub fn memory_ratio(&self) -> f64 {
        self.members.len() as f64
    }
}

impl Engine for DeepEnsemble {
    fn name(&self) -> &str {
        "deep-ensemble"
    }
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer_batch(&mut self, signals: &[f32]) -> anyhow::Result<InferOutput> {
        let n = self.members.len();
        let mut out = InferOutput::new(n, self.batch);
        for (s, w) in self.members.iter().enumerate() {
            let mut eng = NativeEngine::with_batch(&self.man, w, self.batch)?;
            let one = eng.infer_batch(signals)?;
            for p in Param::ALL {
                for v in 0..self.batch {
                    out.set(p, s, v, one.get(p, 0, v));
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn mc_dropout_produces_spread() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 42);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
        let out = mcd.infer_batch(&ds.signals).unwrap();
        let spread: f64 = (0..out.batch).map(|v| out.std(Param::F, v)).sum();
        assert!(spread > 0.0, "random masks must induce variance");
    }

    #[test]
    fn mc_dropout_is_stochastic_across_calls() {
        let Some((man, w)) = setup() else { return };
        let mut mcd = McDropout::new(&man, &w, 42);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 2);
        let a = mcd.infer_batch(&ds.signals).unwrap();
        let b = mcd.infer_batch(&ds.signals).unwrap();
        // unlike Masksembles, MC-Dropout is NOT repeatable run-to-run
        assert_ne!(a.samples[Param::F.index()], b.samples[Param::F.index()]);
    }

    #[test]
    fn deep_ensemble_members_disagree() {
        let Some((man, _)) = setup() else { return };
        let mut de = DeepEnsemble::init_random(&man, 3, 7).unwrap();
        assert_eq!(de.len(), 3);
        assert_eq!(de.memory_ratio(), 3.0);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 3);
        let out = de.infer_batch(&ds.signals).unwrap();
        let spread: f64 = (0..out.batch).map(|v| out.std(Param::D, v)).sum();
        assert!(spread > 0.0);
    }

    #[test]
    fn ensemble_needs_members() {
        let Some((man, _)) = setup() else { return };
        assert!(DeepEnsemble::new(&man, vec![]).is_err());
    }
}
