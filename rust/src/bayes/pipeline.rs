//! Pipelined MC sampling — the paper's *operation reordering* applied
//! to the software MC loop (ISSUE #8 tentpole).
//!
//! The serial heads in `bayes::mod` run `resample → swap_masks →
//! execute_into` on one thread, so every pass pays the full mask-redraw
//! latency on the critical path.  Here a persistent background worker
//! prepares pass *i+1*'s plan (resample + validate) while the engine
//! executes pass *i*; between passes the live and shadow plans swap
//! through a one-slot protocol.  Only the *swap* stays on the critical
//! path — exactly the reordering the paper's hardware uses to hide
//! sampling cost behind compute.
//!
//! ## Why this is bit-exact (the serial engine stays the oracle)
//!
//! * **RNG hand-off rule** — there is exactly ONE [`Pcg32`] and it
//!   travels with the plan through the slot: submit carries it to the
//!   worker, the worker alone draws from it (one redraw per pass, in
//!   pass order), and it comes back with the prepared plan.  The draw
//!   sequence is therefore identical to the serial head's, pass for
//!   pass.
//! * **Prior-state independence** — `LayerPlan::resample` overwrites
//!   every bit from fresh draws and its RNG consumption never depends
//!   on the prior mask state (golden-tested in `masks::plan`), so
//!   redrawing the *stale* shadow clone yields the same bits as
//!   redrawing the live plan would have.
//! * **Shadow-plan ownership** — two plans exist, allocated once at
//!   construction; ownership alternates by move through the slot
//!   (zero per-pass allocation, no sharing: the worker never touches
//!   the plan the engine is executing with).
//!
//! Validation runs **on the prep thread** against a captured
//! [`PlanShape`] — the mirror of the engines' validate-before-mutate
//! rule — so a bad plan is flagged before the hand-off, and the
//! engine-side `swap_masks` validation still guards the actual swap
//! (a rejected swap leaves the engine exactly as it was).

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::accel::{AccelConfig, AccelSimulator, Scheme};
use crate::infer::native::NativeEngine;
use crate::infer::{Engine, InferOutput};
use crate::masks::MaskPlan;
use crate::model::{Manifest, Weights};
use crate::util::rng::Pcg32;

/// The shape contract a prepared plan must satisfy, captured from the
/// construction-time plan — the prep thread's mirror of the engine's
/// swap validation.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanShape {
    nb: usize,
    n_samples: usize,
    subnets: Vec<String>,
}

impl PlanShape {
    pub fn of(plan: &MaskPlan) -> PlanShape {
        PlanShape {
            nb: plan.nb(),
            n_samples: plan.n_samples(),
            subnets: plan.subnets().to_vec(),
        }
    }

    /// Validate a plan against the captured shape — every check the
    /// engines run before mutating, so a mismatch is caught on the prep
    /// thread before the hand-off.
    pub fn check(&self, plan: &MaskPlan) -> anyhow::Result<()> {
        anyhow::ensure!(
            plan.nb() == self.nb && plan.n_samples() == self.n_samples,
            "prepared plan is {}x{}, pipeline needs {}x{}",
            plan.n_samples(),
            plan.nb(),
            self.n_samples,
            self.nb
        );
        anyhow::ensure!(
            plan.subnets() == &self.subnets[..],
            "prepared plan subnets {:?} != pipeline subnets {:?}",
            plan.subnets(),
            self.subnets
        );
        for sn in &self.subnets {
            for layer in [1usize, 2] {
                let lp = plan
                    .layer_for(sn, layer)
                    .ok_or_else(|| anyhow::anyhow!("prepared plan has no subnet '{sn}'"))?;
                anyhow::ensure!(
                    lp.width() == self.nb && lp.n() == self.n_samples,
                    "prepared layer {sn}.{layer} is {}x{}, pipeline needs {}x{}",
                    lp.n(),
                    lp.width(),
                    self.n_samples,
                    self.nb
                );
            }
        }
        Ok(())
    }
}

/// A prepared hand-off: the redrawn plan, the travelling RNG, and the
/// prep-side validation verdict.
pub type Prepared = (MaskPlan, Pcg32, anyhow::Result<()>);

/// One-slot exchange state.  `Preparing` marks the window where the
/// worker owns the plan outside the lock (the overlap itself).
enum Slot {
    Empty,
    Request { plan: MaskPlan, rng: Pcg32 },
    Preparing,
    Ready { plan: MaskPlan, rng: Pcg32, check: Result<(), String> },
    Shutdown,
}

/// The prepare/swap hand-off protocol: a single slot guarded by a
/// mutex + condvar (recheck-under-lock, as in `coordinator/deque.rs`).
/// All transitions move the plan and RNG **by value** — Vec-pointer
/// moves, zero per-pass allocation.
///
/// The synchronous steps ([`PrepProtocol::try_prep`],
/// [`PrepProtocol::try_take`]) let the deterministic `testing::sched`
/// harness drive prepare-racing-swap interleavings without threads;
/// [`PrepWorker`] drives the same state machine from a real thread.
pub struct PrepProtocol {
    slot: Mutex<Slot>,
    cv: Condvar,
    shape: PlanShape,
    layer_lo: usize,
    layer_hi: usize,
}

impl PrepProtocol {
    pub fn new(shape: PlanShape, layer_lo: usize, layer_hi: usize) -> PrepProtocol {
        PrepProtocol {
            slot: Mutex::new(Slot::Empty),
            cv: Condvar::new(),
            shape,
            layer_lo,
            layer_hi,
        }
    }

    /// Hand the stale plan and the travelling RNG to the prep side.
    /// Errors if the slot is occupied or shut down.
    pub fn submit(&self, plan: MaskPlan, rng: Pcg32) -> anyhow::Result<()> {
        let mut sl = self.slot.lock().unwrap();
        match *sl {
            Slot::Empty => {
                *sl = Slot::Request { plan, rng };
                self.cv.notify_all();
                Ok(())
            }
            Slot::Shutdown => anyhow::bail!("prep worker is shut down"),
            _ => anyhow::bail!("prep slot already holds a plan"),
        }
    }

    /// Resample + validate outside the lock, then post the result.
    /// Returns false if shutdown raced the preparation.
    fn do_prep(&self, mut plan: MaskPlan, mut rng: Pcg32) -> bool {
        plan.resample_layer_range(self.layer_lo, self.layer_hi, &mut rng);
        let check = self.shape.check(&plan).map_err(|e| e.to_string());
        let mut sl = self.slot.lock().unwrap();
        if matches!(*sl, Slot::Shutdown) {
            return false;
        }
        *sl = Slot::Ready { plan, rng, check };
        self.cv.notify_all();
        true
    }

    /// Blocking worker step: wait for a request, prepare it, post the
    /// result.  Returns false on shutdown.
    pub fn prep_one(&self) -> bool {
        let (plan, rng) = {
            let mut sl = self.slot.lock().unwrap();
            loop {
                match std::mem::replace(&mut *sl, Slot::Preparing) {
                    Slot::Request { plan, rng } => break (plan, rng),
                    Slot::Shutdown => {
                        *sl = Slot::Shutdown;
                        return false;
                    }
                    other => *sl = other,
                }
                sl = self.cv.wait(sl).unwrap();
            }
        };
        self.do_prep(plan, rng)
    }

    /// Non-blocking worker step: prepare a pending request if there is
    /// one.  Returns whether work was done.
    pub fn try_prep(&self) -> bool {
        let (plan, rng) = {
            let mut sl = self.slot.lock().unwrap();
            match std::mem::replace(&mut *sl, Slot::Preparing) {
                Slot::Request { plan, rng } => (plan, rng),
                other => {
                    *sl = other;
                    return false;
                }
            }
        };
        self.do_prep(plan, rng)
    }

    /// Consume the prepared plan (blocking).  Errors on shutdown.
    pub fn take(&self) -> anyhow::Result<Prepared> {
        let mut sl = self.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *sl, Slot::Empty) {
                Slot::Ready { plan, rng, check } => {
                    return Ok((plan, rng, check.map_err(|e| anyhow::anyhow!(e))));
                }
                Slot::Shutdown => {
                    *sl = Slot::Shutdown;
                    anyhow::bail!("prep worker is shut down");
                }
                other => *sl = other,
            }
            sl = self.cv.wait(sl).unwrap();
        }
    }

    /// Consume the prepared plan if one is ready (non-blocking).
    pub fn try_take(&self) -> Option<Prepared> {
        let mut sl = self.slot.lock().unwrap();
        match std::mem::replace(&mut *sl, Slot::Empty) {
            Slot::Ready { plan, rng, check } => {
                Some((plan, rng, check.map_err(|e| anyhow::anyhow!(e))))
            }
            other => {
                *sl = other;
                None
            }
        }
    }

    /// Inspect the prepared plan without consuming it (blocking) — the
    /// shadow half of the steady-state alloc-signature witness.
    pub fn with_ready<R>(&self, f: impl FnOnce(&MaskPlan) -> R) -> anyhow::Result<R> {
        let mut sl = self.slot.lock().unwrap();
        loop {
            match &*sl {
                Slot::Ready { plan, .. } => return Ok(f(plan)),
                Slot::Shutdown => anyhow::bail!("prep worker is shut down"),
                _ => {}
            }
            sl = self.cv.wait(sl).unwrap();
        }
    }

    /// Tear the protocol down: both sides observe the state and stop.
    pub fn shutdown(&self) {
        let mut sl = self.slot.lock().unwrap();
        *sl = Slot::Shutdown;
        self.cv.notify_all();
    }
}

/// The persistent background preparer: one thread looping
/// [`PrepProtocol::prep_one`] until shutdown.  Dropping joins it.
pub struct PrepWorker {
    proto: Arc<PrepProtocol>,
    handle: Option<JoinHandle<()>>,
}

impl PrepWorker {
    pub fn spawn(proto: Arc<PrepProtocol>) -> PrepWorker {
        let p = Arc::clone(&proto);
        let handle = std::thread::spawn(move || while p.prep_one() {});
        PrepWorker {
            proto,
            handle: Some(handle),
        }
    }
}

impl Drop for PrepWorker {
    fn drop(&mut self) {
        self.proto.shutdown();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The hot-swap half of the [`Engine`] contract — what a backend needs
/// for the pipeline to drive it (mask swap is engine-specific state,
/// not part of the `Engine` trait).
pub trait MaskSwapEngine: Engine {
    fn swap_plan(&mut self, plan: &MaskPlan) -> anyhow::Result<()>;
    fn plan_alloc_signature(&self) -> Vec<usize>;
}

impl MaskSwapEngine for NativeEngine {
    fn swap_plan(&mut self, plan: &MaskPlan) -> anyhow::Result<()> {
        self.swap_masks(plan)
    }
    fn plan_alloc_signature(&self) -> Vec<usize> {
        self.alloc_signature()
    }
}

impl MaskSwapEngine for AccelSimulator {
    fn swap_plan(&mut self, plan: &MaskPlan) -> anyhow::Result<()> {
        self.swap_masks(plan)
    }
    fn plan_alloc_signature(&self) -> Vec<usize> {
        self.alloc_signature()
    }
}

/// An MC head whose mask preparation overlaps execution: pass *k* uses
/// exactly the *k*-th redraw of the seed's stream (bit-identical to the
/// serial heads), but the redraw happened while pass *k-1* executed.
pub struct Pipelined<E: MaskSwapEngine> {
    engine: E,
    live: MaskPlan,
    proto: Arc<PrepProtocol>,
    /// Held for Drop (shutdown + join).
    _worker: PrepWorker,
    name: &'static str,
    batch: usize,
    n_samples: usize,
}

impl<E: MaskSwapEngine> Pipelined<E> {
    /// Wrap an engine.  Mirrors the serial heads' construction exactly:
    /// seed the RNG, draw the initial Bernoulli plan, swap it in — then
    /// clone it once as the shadow (the only extra allocation) and hand
    /// shadow + RNG to the background worker, which immediately starts
    /// preparing pass 1.
    pub fn new(
        mut engine: E,
        man: &Manifest,
        batch: usize,
        seed: u64,
        layers: (usize, usize),
        name: &'static str,
    ) -> anyhow::Result<Self> {
        let mut rng = Pcg32::new(seed);
        let live = MaskPlan::bernoulli(man, 1.0 / man.scale, &mut rng);
        engine.swap_plan(&live)?;
        let shadow = live.clone();
        let proto = Arc::new(PrepProtocol::new(PlanShape::of(&live), layers.0, layers.1));
        proto.submit(shadow, rng)?;
        let worker = PrepWorker::spawn(Arc::clone(&proto));
        Ok(Pipelined {
            engine,
            live,
            proto,
            _worker: worker,
            name,
            batch,
            n_samples: man.n_samples,
        })
    }

    /// The wrapped engine (read-only: cycle stats, dot mode, …).
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Live-plan + engine buffer capacities (steady-state witness).
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = self.live.alloc_signature();
        sig.extend(self.engine.plan_alloc_signature());
        sig
    }

    /// Shadow-plan capacities, read in place once it is prepared — the
    /// other half of the no-per-pass-allocation contract.
    pub fn shadow_alloc_signature(&self) -> anyhow::Result<Vec<usize>> {
        self.proto.with_ready(|p| p.alloc_signature())
    }
}

impl<E: MaskSwapEngine> Engine for Pipelined<E> {
    fn name(&self) -> &str {
        self.name
    }
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn n_samples(&self) -> usize {
        self.n_samples
    }

    // hot-path: pipelined MC steady state — plan hand-off and swap must
    // stay alloc-free or the overlap gain is spent on the allocator.
    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        // Pass k: the worker already drew mask set k into the shadow
        // plan while pass k-1 executed (or during construction).
        let (next, rng, check) = self.proto.take()?;
        if let Err(e) = check {
            // Prep-side validation failed: the engine still holds the
            // old masks untouched.  Park the protocol so later calls
            // error loudly instead of deadlocking on an empty slot.
            self.proto.shutdown();
            return Err(e);
        }
        if let Err(e) = self.engine.swap_plan(&next) {
            // Validate-before-mutate: the engine is exactly as it was.
            self.proto.shutdown();
            return Err(e);
        }
        let old = std::mem::replace(&mut self.live, next);
        // Hand the stale plan and the RNG back: the worker draws pass
        // k+1 while we execute pass k below.
        self.proto.submit(old, rng)?;
        self.engine.execute_into(signals, out)
    }
    // hot-path: end
}

/// Pipelined f32 MC-Dropout (registry: `mc-dropout` with overlap on).
pub fn mc_dropout(
    man: &Manifest,
    weights: &Weights,
    batch: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Pipelined<NativeEngine>> {
    let engine = NativeEngine::with_batch_threads(man, weights, batch, threads)?;
    Pipelined::new(engine, man, batch, seed, (1, 2), "mc-dropout+overlap")
}

/// Pipelined last-layer-only MC-Dropout (registry: `mc-dropout-ll`
/// with overlap on).
pub fn mc_dropout_last_layer(
    man: &Manifest,
    weights: &Weights,
    batch: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Pipelined<NativeEngine>> {
    let engine = NativeEngine::with_batch_threads(man, weights, batch, threads)?;
    Pipelined::new(engine, man, batch, seed, (2, 2), "mc-dropout-ll+overlap")
}

/// Pipelined fixed-point MC-Dropout over the accelerator simulator
/// (registry: `accel-mc` with overlap on).
pub fn accel_mc(
    man: &Manifest,
    weights: &Weights,
    batch: usize,
    seed: u64,
) -> anyhow::Result<Pipelined<AccelSimulator>> {
    let cfg = AccelConfig {
        batch,
        ..Default::default()
    };
    let sim = AccelSimulator::new(man, weights, cfg, Scheme::BatchLevel)?;
    Pipelined::new(sim, man, batch, seed, (1, 2), "accel-mc+overlap")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bayes::{AccelMcDropout, McDropout};
    use crate::ivim::synth::synth_dataset;
    use crate::ivim::Param;
    use crate::testing::fixture;

    /// Tentpole golden gate (ISSUE #8 acceptance): the pipelined head is
    /// bit-identical to the serial oracle for >= 4 passes on the native
    /// backend, at 1 and 4 worker threads.
    #[test]
    fn pipelined_matches_serial_mc_dropout_bit_for_bit() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 61);
        for threads in [1usize, 4] {
            let mut serial = McDropout::with_batch(&man, &w, man.batch_infer, 7).unwrap();
            let mut piped = mc_dropout(&man, &w, man.batch_infer, 7, threads).unwrap();
            let mut a = InferOutput::new(1, 1);
            let mut b = InferOutput::new(1, 1);
            for pass in 0..5 {
                serial.execute_into(&ds.signals, &mut a).unwrap();
                piped.execute_into(&ds.signals, &mut b).unwrap();
                for p in Param::ALL {
                    assert_eq!(
                        a.samples[p.index()],
                        b.samples[p.index()],
                        "t{threads} pass {pass}: pipelined != serial for {p:?}"
                    );
                }
            }
        }
    }

    /// Same gate on the fixed-point backend — outputs AND cycle stats.
    #[test]
    fn pipelined_matches_serial_accel_mc_bit_for_bit() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 62);
        let mut serial = AccelMcDropout::with_batch(&man, &w, man.batch_infer, 13).unwrap();
        let mut piped = accel_mc(&man, &w, man.batch_infer, 13).unwrap();
        let mut a = InferOutput::new(1, 1);
        let mut b = InferOutput::new(1, 1);
        for pass in 0..5 {
            serial.execute_into(&ds.signals, &mut a).unwrap();
            piped.execute_into(&ds.signals, &mut b).unwrap();
            for p in Param::ALL {
                assert_eq!(
                    a.samples[p.index()],
                    b.samples[p.index()],
                    "pass {pass}: pipelined != serial for {p:?}"
                );
            }
            let (sa, sb) = (serial.last_stats(), piped.engine().last_stats);
            assert_eq!(sa.cycles, sb.cycles, "pass {pass}: cycle counters diverged");
        }
    }

    /// The last-layer pipelined head tracks its serial twin bit-for-bit.
    #[test]
    fn pipelined_last_layer_matches_serial_bit_for_bit() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 63);
        let mut serial = McDropout::last_layer_with_batch(&man, &w, man.batch_infer, 19, 1).unwrap();
        let mut piped = mc_dropout_last_layer(&man, &w, man.batch_infer, 19, 1).unwrap();
        let mut a = InferOutput::new(1, 1);
        let mut b = InferOutput::new(1, 1);
        for pass in 0..4 {
            serial.execute_into(&ds.signals, &mut a).unwrap();
            piped.execute_into(&ds.signals, &mut b).unwrap();
            for p in Param::ALL {
                assert_eq!(
                    a.samples[p.index()],
                    b.samples[p.index()],
                    "pass {pass}: ll pipelined != serial for {p:?}"
                );
            }
        }
    }

    /// Steady state allocates nothing: live plan, engine, AND the
    /// in-flight shadow plan keep their capacities across 20 passes.
    #[test]
    fn pipelined_steady_state_never_reallocates() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 64);
        let mut piped = mc_dropout(&man, &w, man.batch_infer, 5, 2).unwrap();
        let mut out = InferOutput::new(piped.n_samples(), piped.batch_size());
        piped.execute_into(&ds.signals, &mut out).unwrap();
        let sig = piped.alloc_signature();
        let shadow_sig = piped.shadow_alloc_signature().unwrap();
        let out_ptrs: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        for _ in 0..20 {
            piped.execute_into(&ds.signals, &mut out).unwrap();
            assert_eq!(piped.alloc_signature(), sig, "live plan or engine reallocated");
            assert_eq!(
                piped.shadow_alloc_signature().unwrap(),
                shadow_sig,
                "shadow plan reallocated"
            );
            let after: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
            assert_eq!(out_ptrs, after, "output buffers reallocated");
        }
    }

    /// Satellite (bugfix sweep): a shadow plan that fails validation
    /// mid-pipeline is flagged on the prep thread, the engine keeps its
    /// old masks untouched, and the protocol errors loudly afterwards
    /// instead of deadlocking.
    #[test]
    fn pipelined_mismatch_injection_fails_cleanly() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 65);
        let mut rng = Pcg32::new(3);
        let plan = MaskPlan::bernoulli(&man, 1.0 / man.scale, &mut rng);
        let mut eng = NativeEngine::with_batch(&man, &w, man.batch_infer).unwrap();
        eng.swap_masks(&plan).unwrap();
        let baseline = eng.infer_batch(&ds.signals).unwrap();
        // A hostile shape: claims one more sample than the plan carries.
        let hostile = PlanShape {
            nb: man.nb,
            n_samples: man.n_samples + 1,
            subnets: man.subnets.clone(),
        };
        let proto = PrepProtocol::new(hostile, 1, 2);
        proto.submit(plan.clone(), rng).unwrap();
        assert!(proto.try_prep(), "request must be preparable");
        let (bad_plan, _rng, check) = proto.try_take().expect("prepared");
        let err = check.expect_err("mismatched shape must be flagged by the prep side");
        assert!(err.to_string().contains("prepared plan"), "{err}");
        // The engine-side guard agrees and leaves the engine untouched:
        let mut wrong = MaskPlan::all_ones(&man, man.n_samples + 1);
        let mut r2 = Pcg32::new(4);
        wrong.resample(&mut r2);
        assert!(eng.swap_masks(&wrong).is_err());
        drop(bad_plan);
        let after = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(baseline.samples[p.index()], after.samples[p.index()]);
        }
        // Degraded protocol: errors, never hangs.
        proto.shutdown();
        assert!(proto.take().is_err());
        assert!(proto.submit(wrong, r2).is_err());
    }

    /// Protocol unit coverage: occupancy, empty takes, shutdown.
    #[test]
    fn prep_protocol_rejects_double_submit_and_handles_shutdown() {
        let (man, _) = fixture::tiny_fixture();
        let mut rng = Pcg32::new(8);
        let plan = MaskPlan::bernoulli(&man, 0.5, &mut rng);
        let proto = PrepProtocol::new(PlanShape::of(&plan), 1, 2);
        assert!(proto.try_take().is_none(), "empty slot has nothing to take");
        assert!(!proto.try_prep(), "empty slot has nothing to prepare");
        proto.submit(plan.clone(), rng.clone()).unwrap();
        let e = proto.submit(plan.clone(), rng.clone()).unwrap_err();
        assert!(e.to_string().contains("already holds"), "{e}");
        assert!(proto.try_take().is_none(), "request is not yet ready");
        assert!(proto.try_prep());
        let (p2, r2, check) = proto.try_take().expect("ready after prep");
        check.unwrap();
        assert_eq!(p2.nb(), plan.nb());
        // round-trips keep working
        proto.submit(p2, r2).unwrap();
        assert!(proto.try_prep());
        assert!(proto.try_take().is_some());
        // shutdown with a pending request: worker step refuses, both
        // sides error
        proto.submit(plan, rng).unwrap();
        proto.shutdown();
        assert!(!proto.prep_one(), "prep after shutdown must stop");
        assert!(proto.take().is_err());
        assert!(proto.with_ready(|p| p.nb()).is_err());
    }

    /// The worker thread joins on drop, pending request or not.
    #[test]
    fn prep_worker_drop_joins() {
        let (man, _) = fixture::tiny_fixture();
        let mut rng = Pcg32::new(12);
        let plan = MaskPlan::bernoulli(&man, 0.5, &mut rng);
        for submit_first in [false, true] {
            let proto = Arc::new(PrepProtocol::new(PlanShape::of(&plan), 1, 2));
            if submit_first {
                proto.submit(plan.clone(), rng.clone()).unwrap();
            }
            let worker = PrepWorker::spawn(Arc::clone(&proto));
            drop(worker); // must not hang
        }
    }
}
