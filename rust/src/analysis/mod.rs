//! `repro lint` — a dependency-free, repo-specific static analyzer
//! (DESIGN.md §6 "Invariants & enforcement").
//!
//! The codebase rests on hand-proven invariants — zero-alloc serving
//! paths, `unsafe` confined to four audited kernel files, panic-free
//! wire parsing, justified memory orderings.  This module *enforces*
//! them: [`lint_crate`] scans every `.rs` file under `src/` and
//! `benches/` with the lexical scanner in [`scan`] and applies the six
//! rules in [`rules`].  Findings are machine-readable
//! ([`findings_json`]) and the CLI (`repro lint [--json]`) exits
//! nonzero when any survive, so CI can gate on a clean tree.
//!
//! Escape hatch: a comment containing `lint: allow(<rule>) — <reason>`
//! on the offending line or the line above suppresses one finding;
//! the reason is mandatory by convention and reviewed like any code.

pub mod rules;
pub mod scan;

use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule name (one of [`rules::RULES`]).
    pub rule: &'static str,
    /// Crate-relative path with forward slashes (e.g. `src/util/frame.rs`).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Lint the crate rooted at `crate_dir` (the directory holding `src/`
/// and `benches/`).  Files are visited in sorted order so output and
/// JSON are deterministic.
pub fn lint_crate(crate_dir: &Path) -> anyhow::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for sub in ["src", "benches"] {
        let dir = crate_dir.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    anyhow::ensure!(
        !files.is_empty(),
        "no .rs files under {} (src/, benches/) — wrong --root?",
        crate_dir.display()
    );
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(crate_dir)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let file = scan::SourceFile::parse(&rel, &text);
        rules::check_all(&file, &mut findings);
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir).map_err(|e| anyhow::anyhow!("read {}: {e}", dir.display()))?
    {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The crate dir `repro lint` scans when `--root` is not given: the
/// checkout's `rust/` when invoked from the repo root, the current dir
/// when invoked from inside `rust/`, else the build-time manifest dir.
pub fn default_crate_dir() -> PathBuf {
    if Path::new("rust/src").is_dir() {
        return PathBuf::from("rust");
    }
    if Path::new("src").is_dir() {
        return PathBuf::from(".");
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Machine-readable findings: a stable single-line JSON object
/// (`{"count":N,"findings":[{"rule":…,"path":…,"line":N,"message":…}]}`,
/// shape pinned by a test).
pub fn findings_json(findings: &[Finding]) -> String {
    let mut s = String::from("{\"count\":");
    s.push_str(&findings.len().to_string());
    s.push_str(",\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("{\"rule\":\"");
        s.push_str(&json_escape(f.rule));
        s.push_str("\",\"path\":\"");
        s.push_str(&json_escape(&f.path));
        s.push_str("\",\"line\":");
        s.push_str(&f.line.to_string());
        s.push_str(",\"message\":\"");
        s.push_str(&json_escape(&f.message));
        s.push_str("\"}");
    }
    s.push_str("]}");
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: the repo's own tree carries zero findings.
    /// Every invariant the rules encode is live — a regression anywhere
    /// in `src/` or `benches/` fails this test (and the CI analyze job,
    /// which runs the same scan through `repro lint`).
    #[test]
    fn repo_tree_is_lint_clean() {
        let crate_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_crate(crate_dir).expect("lint walks the tree");
        let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(
            findings.is_empty(),
            "repo tree has {} lint finding(s):\n{}",
            findings.len(),
            rendered.join("\n")
        );
    }

    #[test]
    fn json_shape_is_pinned() {
        let findings = vec![
            Finding {
                rule: "panic-free-net",
                path: "src/util/frame.rs".into(),
                line: 42,
                message: "`unwrap` on a wire-facing path".into(),
            },
            Finding {
                rule: "atomic-ordering",
                path: "src/coordinator/metrics.rs".into(),
                line: 7,
                message: "say \"why\"".into(),
            },
        ];
        assert_eq!(
            findings_json(&findings),
            "{\"count\":2,\"findings\":[\
             {\"rule\":\"panic-free-net\",\"path\":\"src/util/frame.rs\",\"line\":42,\
             \"message\":\"`unwrap` on a wire-facing path\"},\
             {\"rule\":\"atomic-ordering\",\"path\":\"src/coordinator/metrics.rs\",\"line\":7,\
             \"message\":\"say \\\"why\\\"\"}]}"
        );
        assert_eq!(findings_json(&[]), "{\"count\":0,\"findings\":[]}");
    }

    #[test]
    fn lint_crate_rejects_an_empty_root() {
        let err = lint_crate(Path::new("/nonexistent-lint-root")).unwrap_err();
        assert!(err.to_string().contains("wrong --root"));
    }

    #[test]
    fn findings_render_as_path_line_rule() {
        let f = Finding {
            rule: "hot-path-alloc",
            path: "src/infer/native.rs".into(),
            line: 3,
            message: "allocation".into(),
        };
        assert_eq!(f.to_string(), "src/infer/native.rs:3: [hot-path-alloc] allocation");
    }
}
