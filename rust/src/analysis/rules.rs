//! The six repo-invariant rules (DESIGN.md §6).
//!
//! Each rule is a pure function over a scanned [`SourceFile`] appending
//! [`Finding`]s; [`check_all`] is the driver's entry point.  Every rule
//! honours the allowlist escape hatch: a comment containing
//! `lint: allow(<rule>) — <reason>` on the offending line or the line
//! above suppresses that finding.

use super::scan::{fn_ranges, innermost_fn, SourceFile};
use super::Finding;

/// Rule names, in the order they run.
pub const RULES: &[&str] = &[
    "unsafe-confinement",
    "safety-comment",
    "release-vanishing-guard",
    "hot-path-alloc",
    "atomic-ordering",
    "panic-free-net",
];

/// Run every rule over one file.
pub fn check_all(file: &SourceFile, out: &mut Vec<Finding>) {
    unsafe_confinement(file, out);
    safety_comment(file, out);
    release_vanishing_guard(file, out);
    hot_path_alloc(file, out);
    atomic_ordering(file, out);
    panic_free_net(file, out);
}

/// `word` as a whole identifier token in the code view.
fn has_word(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    let ident = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let s = from + p;
        let e = s + word.len();
        let pre_ok = s == 0 || !ident(b[s - 1]);
        let post_ok = b.get(e).is_none_or(|&c| !ident(c));
        if pre_ok && post_ok {
            return true;
        }
        from = e;
    }
    false
}

/// The allowlist escape hatch: same line or the line above.
fn allowed(file: &SourceFile, i: usize, rule: &str) -> bool {
    let pat = format!("lint: allow({rule})");
    file.lines[i].comment.contains(&pat)
        || (i > 0 && file.lines[i - 1].comment.contains(&pat))
}

fn push(out: &mut Vec<Finding>, file: &SourceFile, i: usize, rule: &'static str, message: String) {
    if !allowed(file, i, rule) {
        out.push(Finding {
            rule,
            path: file.path.clone(),
            line: i + 1,
            message,
        });
    }
}

/// Files allowed to contain `unsafe` (the audited kernel seams).
const UNSAFE_FILES: &[&str] = &[
    "util/simd.rs",
    "util/workers.rs",
    "accel/fixed.rs",
    "infer/native.rs",
];

/// Rule 1 — `unsafe` appears only in the four audited kernel files.
pub fn unsafe_confinement(file: &SourceFile, out: &mut Vec<Finding>) {
    if UNSAFE_FILES.iter().any(|f| file.path.ends_with(f)) {
        return;
    }
    for (i, l) in file.lines.iter().enumerate() {
        if has_word(&l.code, "unsafe") {
            push(
                out,
                file,
                i,
                "unsafe-confinement",
                format!(
                    "`unsafe` outside the audited kernel files ({})",
                    UNSAFE_FILES.join(", ")
                ),
            );
        }
    }
}

/// Rule 2 — every `unsafe` site carries a `SAFETY:` comment (or a
/// `# Safety` doc section) in the contiguous comment/attribute block
/// above it.  Consecutive `unsafe impl` lines may share one comment.
pub fn safety_comment(file: &SourceFile, out: &mut Vec<Finding>) {
    for i in 0..file.lines.len() {
        if !has_word(&file.lines[i].code, "unsafe") {
            continue;
        }
        if has_safety_comment(file, i) {
            continue;
        }
        push(
            out,
            file,
            i,
            "safety-comment",
            "`unsafe` site without a `SAFETY:` justification in the comment block above".into(),
        );
    }
}

fn has_safety_comment(file: &SourceFile, site: usize) -> bool {
    let safety = |c: &str| c.to_ascii_lowercase().contains("safety");
    if safety(&file.lines[site].comment) {
        return true;
    }
    let mut i = site;
    while i > 0 {
        i -= 1;
        let l = &file.lines[i];
        if safety(&l.comment) {
            return true;
        }
        let t = l.code.trim();
        let comment_or_attr = t.is_empty() || t.starts_with("#[") || t.starts_with("#![");
        // `unsafe impl Send` / `unsafe impl Sync` pairs share one comment
        let shared_impl = t.starts_with("unsafe impl");
        if !comment_or_attr && !shared_impl {
            return false;
        }
    }
    false
}

/// Patterns whose presence in a fn body makes a `debug_assert` there a
/// release-mode hazard: the checked length/index feeds raw-pointer or
/// silently-truncating code once the assert compiles away (the PR 6
/// PU-kernel bug class).
const HAZARDS: &[&str] = &[
    "as_mut_ptr",
    ".as_ptr",
    ".add(",
    "from_raw_parts",
    "get_unchecked",
    "set_len(",
    ".zip(",
    "chunks_exact(",
];

/// Rule 3 — no `debug_assert` in a fn that also touches raw pointers or
/// truncating iteration.
pub fn release_vanishing_guard(file: &SourceFile, out: &mut Vec<Finding>) {
    let ranges = fn_ranges(file);
    for i in 0..file.lines.len() {
        if file.is_test(i) {
            continue;
        }
        if !file.lines[i].code.contains("debug_assert") {
            continue;
        }
        let Some((a, b)) = innermost_fn(&ranges, i) else {
            continue;
        };
        let hazard = (a..=b).find_map(|j| {
            HAZARDS
                .iter()
                .find(|h| file.lines[j].code.contains(*h))
                .map(|h| (j, *h))
        });
        if let Some((j, h)) = hazard {
            push(
                out,
                file,
                i,
                "release-vanishing-guard",
                format!(
                    "`debug_assert` vanishes in release builds but this fn touches `{h}` \
                     (line {}): use a hard assert or a typed error",
                    j + 1
                ),
            );
        }
    }
}

/// Allocation/copy patterns banned inside marked hot-path regions.
const ALLOC_PATTERNS: &[&str] = &["vec![", "Vec::new", ".to_vec(", ".clone(", ".collect("];

const HOT_MARK: &str = "hot-path:";

/// Rule 4 — no allocation inside explicitly marked hot-path regions
/// (opened by a `hot-path` comment marker, closed by its `end` form).
pub fn hot_path_alloc(file: &SourceFile, out: &mut Vec<Finding>) {
    let mut open: Option<usize> = None;
    for i in 0..file.lines.len() {
        let c = &file.lines[i].comment;
        if let Some(p) = c.find(HOT_MARK) {
            let rest = c[p + HOT_MARK.len()..].trim();
            if rest == "end" {
                if open.take().is_none() {
                    push(
                        out,
                        file,
                        i,
                        "hot-path-alloc",
                        "hot-path end marker without a matching open marker".into(),
                    );
                }
            } else if let Some(prev) = open {
                push(
                    out,
                    file,
                    i,
                    "hot-path-alloc",
                    format!("nested hot-path region (previous opened on line {})", prev + 1),
                );
            } else {
                open = Some(i);
            }
            continue;
        }
        if open.is_some() && !file.is_test(i) {
            if let Some(pat) = ALLOC_PATTERNS
                .iter()
                .find(|p| file.lines[i].code.contains(*p))
            {
                push(
                    out,
                    file,
                    i,
                    "hot-path-alloc",
                    format!("allocation/copy `{pat}` inside a marked hot-path region"),
                );
            }
        }
    }
    if let Some(i) = open {
        push(
            out,
            file,
            i,
            "hot-path-alloc",
            "hot-path region never closed (missing end marker)".into(),
        );
    }
}

/// Rule 5 — every `Ordering::Relaxed` is justified by a comment
/// containing `relaxed:` on its line or earlier in the enclosing fn.
pub fn atomic_ordering(file: &SourceFile, out: &mut Vec<Finding>) {
    let ranges = fn_ranges(file);
    for i in 0..file.lines.len() {
        if file.is_test(i) {
            continue;
        }
        if !file.lines[i].code.contains("Ordering::Relaxed") {
            continue;
        }
        let start = innermost_fn(&ranges, i)
            .map(|(a, _)| a)
            .unwrap_or_else(|| i.saturating_sub(1));
        let justified = (start..=i).any(|j| file.lines[j].comment.contains("relaxed:"));
        if !justified {
            push(
                out,
                file,
                i,
                "atomic-ordering",
                "`Ordering::Relaxed` without a `relaxed:` justification comment in the \
                 enclosing fn"
                    .into(),
            );
        }
    }
}

/// Wire-facing scope of rule 6.
fn net_scoped(path: &str) -> bool {
    path.contains("coordinator/net/") || path.ends_with("util/frame.rs")
}

/// Identifiers conventionally bound to wire-controlled data in the net
/// scope; single (non-range) bracket indexing on them is banned.
const WIRE_IDENTS: &[&str] = &["buf", "b", "bytes", "payload", "chunk", "frame", "wire"];

const PANIC_PATTERNS: &[&str] = &[
    ".unwrap(",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// Rule 6 — wire-facing code (`coordinator/net/`, `util/frame.rs`)
/// never panics on input: no unwrap/expect/panic-family macros, no
/// unchecked single-index on wire-named buffers (range slices are the
/// guarded idiom and stay allowed).  Test code is exempt.
pub fn panic_free_net(file: &SourceFile, out: &mut Vec<Finding>) {
    if !net_scoped(&file.path) {
        return;
    }
    for i in 0..file.lines.len() {
        if file.is_test(i) {
            continue;
        }
        let code = &file.lines[i].code;
        for pat in PANIC_PATTERNS {
            if code.contains(pat) {
                push(
                    out,
                    file,
                    i,
                    "panic-free-net",
                    format!(
                        "`{}` on a wire-facing path: return a typed error instead",
                        pat.trim_start_matches('.').trim_end_matches('(')
                    ),
                );
            }
        }
        for ident in wire_single_index(code) {
            push(
                out,
                file,
                i,
                "panic-free-net",
                format!(
                    "unchecked single-index on wire-controlled `{ident}`: use `get`, \
                     a range slice, or a length-checked helper"
                ),
            );
        }
    }
}

/// Wire-named identifiers indexed with a single (non-range) expression.
fn wire_single_index(code: &str) -> Vec<&'static str> {
    let b = code.as_bytes();
    let ident_ch = |c: u8| c == b'_' || c.is_ascii_alphanumeric();
    let mut hits = Vec::new();
    for ident in WIRE_IDENTS {
        let mut from = 0;
        while let Some(p) = code[from..].find(ident) {
            let s = from + p;
            let e = s + ident.len();
            from = e;
            if s > 0 && ident_ch(b[s - 1]) {
                continue;
            }
            if b.get(e) != Some(&b'[') {
                continue;
            }
            let mut depth = 0usize;
            let mut content = String::new();
            let mut closed = false;
            for &c in &b[e..] {
                match c {
                    b'[' => {
                        depth += 1;
                        if depth > 1 {
                            content.push(c as char);
                        }
                    }
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            closed = true;
                            break;
                        }
                        content.push(c as char);
                    }
                    _ => content.push(c as char),
                }
            }
            if closed && content.contains("..") {
                continue; // range slice — the guarded idiom
            }
            hits.push(*ident);
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::SourceFile;

    fn run(rule: fn(&SourceFile, &mut Vec<Finding>), path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::parse(path, src);
        let mut out = Vec::new();
        rule(&f, &mut out);
        out
    }

    // ---- rule 1: unsafe-confinement -------------------------------

    #[test]
    fn unsafe_confinement_triggers_outside_the_allowlist() {
        let bad = "fn f() {\n    unsafe { g() }\n}";
        let hits = run(unsafe_confinement, "src/bayes/pipeline.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "unsafe-confinement");
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn unsafe_confinement_passes_in_kernel_files_and_on_prose() {
        let ok = "fn f() {\n    // SAFETY: fine\n    unsafe { g() }\n}";
        assert!(run(unsafe_confinement, "src/util/simd.rs", ok).is_empty());
        // the word in a comment or string is not code
        let prose = "// unsafe is discussed here\nlet s = \"unsafe\";";
        assert!(run(unsafe_confinement, "src/foo.rs", prose).is_empty());
    }

    #[test]
    fn unsafe_confinement_honours_the_allowlist_marker() {
        let allowed = "fn f() {\n    // lint: allow(unsafe-confinement) — audited one-off\n    unsafe { g() }\n}";
        assert!(run(unsafe_confinement, "src/foo.rs", allowed).is_empty());
    }

    // ---- rule 2: safety-comment -----------------------------------

    #[test]
    fn safety_comment_triggers_on_a_bare_unsafe_block() {
        let bad = "fn f() {\n    unsafe { g() }\n}";
        let hits = run(safety_comment, "src/util/simd.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn safety_comment_accepts_comment_doc_and_shared_impl_blocks() {
        let ok = "fn f() {\n    // SAFETY: disjoint tiles\n    unsafe { g() }\n}";
        assert!(run(safety_comment, "src/util/simd.rs", ok).is_empty());
        let doc = "/// # Safety\n/// caller checks len\n#[target_feature(enable = \"avx2\")]\nunsafe fn g() {}";
        assert!(run(safety_comment, "src/util/simd.rs", doc).is_empty());
        let shared = "// SAFETY: lanes write disjoint tiles\nunsafe impl Send for P {}\nunsafe impl Sync for P {}";
        assert!(run(safety_comment, "src/infer/native.rs", shared).is_empty());
    }

    // ---- rule 3: release-vanishing-guard --------------------------

    #[test]
    fn release_vanishing_guard_triggers_next_to_raw_pointers() {
        let bad = "fn f(xs: &mut [f32]) {\n    debug_assert!(xs.len() >= 4);\n    let p = xs.as_mut_ptr();\n    h(p);\n}";
        let hits = run(release_vanishing_guard, "src/infer/native.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 2);
        assert!(hits[0].message.contains("as_mut_ptr"));
    }

    #[test]
    fn release_vanishing_guard_triggers_next_to_truncating_zip() {
        let bad = "fn f(a: &[f32], o: &mut [f32]) {\n    debug_assert_eq!(a.len(), o.len());\n    for (x, y) in o.iter_mut().zip(a.iter()) { *x = *y; }\n}";
        assert_eq!(run(release_vanishing_guard, "src/ivim/synth.rs", bad).len(), 1);
    }

    #[test]
    fn release_vanishing_guard_passes_on_plain_fns_and_tests() {
        let ok = "fn f(a: &[f32]) {\n    debug_assert!(a.len() > 1);\n    let s: f32 = a.iter().sum();\n    h(s);\n}";
        assert!(run(release_vanishing_guard, "src/x.rs", ok).is_empty());
        let test_only = "#[cfg(test)]\nmod tests {\n    fn f(xs: &mut [f32]) {\n        debug_assert!(xs.len() > 0);\n        let _ = xs.as_mut_ptr();\n    }\n}";
        assert!(run(release_vanishing_guard, "src/x.rs", test_only).is_empty());
    }

    // ---- rule 4: hot-path-alloc -----------------------------------

    // NOTE: fixture sources are built by joining lines so that this
    // file's own comment/string scan never sees a live region marker.
    fn hot(body: &str) -> String {
        [
            "fn f(data: &[f32]) {".to_string(),
            format!("    // {HOT_MARK} decode"),
            body.to_string(),
            format!("    // {HOT_MARK} end"),
            "}".to_string(),
        ]
        .join("\n")
    }

    #[test]
    fn hot_path_alloc_triggers_on_allocation_in_a_region() {
        let hits = run(hot_path_alloc, "src/util/frame.rs", &hot("    let v = data.to_vec();"));
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 3);
        assert!(hits[0].message.contains("to_vec"));
    }

    #[test]
    fn hot_path_alloc_passes_clean_regions_and_unmarked_code() {
        let ok = hot("    let s: f32 = data.iter().sum();");
        assert!(run(hot_path_alloc, "src/util/frame.rs", &ok).is_empty());
        // allocation outside any region is not this rule's business
        let free = "fn f() {\n    let v = vec![1, 2];\n    g(&v);\n}";
        assert!(run(hot_path_alloc, "src/util/frame.rs", free).is_empty());
    }

    #[test]
    fn hot_path_alloc_flags_unclosed_regions() {
        let src = format!("fn f() {{\n    // {HOT_MARK} decode\n    g();\n}}");
        let hits = run(hot_path_alloc, "src/x.rs", &src);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].message.contains("never closed"));
    }

    #[test]
    fn hot_path_alloc_honours_the_allowlist_marker() {
        let src = [
            "fn f(data: &[f32]) {".to_string(),
            format!("    // {HOT_MARK} decode"),
            "    // lint: allow(hot-path-alloc) — cold fallback branch".to_string(),
            "    let v = data.to_vec();".to_string(),
            format!("    // {HOT_MARK} end"),
            "}".to_string(),
        ]
        .join("\n");
        assert!(run(hot_path_alloc, "src/util/frame.rs", &src).is_empty());
    }

    // ---- rule 5: atomic-ordering ----------------------------------

    #[test]
    fn atomic_ordering_triggers_without_justification() {
        let bad = "fn f(c: &AtomicU64) {\n    c.fetch_add(1, Ordering::Relaxed);\n}";
        let hits = run(atomic_ordering, "src/coordinator/metrics.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn atomic_ordering_accepts_fn_level_justification() {
        let ok = "fn f(c: &AtomicU64) {\n    // relaxed: monotonic counter, no ordering needed\n    c.fetch_add(1, Ordering::Relaxed);\n    c.fetch_add(1, Ordering::Relaxed);\n}";
        assert!(run(atomic_ordering, "src/coordinator/metrics.rs", ok).is_empty());
    }

    // ---- rule 6: panic-free-net -----------------------------------

    #[test]
    fn panic_free_net_triggers_on_unwrap_and_single_index() {
        let bad = "fn f(buf: &[u8]) -> u8 {\n    let h = parse(buf).unwrap();\n    buf[0] + h\n}";
        let hits = run(panic_free_net, "src/coordinator/net/mod.rs", bad);
        assert_eq!(hits.len(), 2);
        assert!(hits[0].message.contains("unwrap"));
        assert!(hits[1].message.contains("`buf`"));
    }

    #[test]
    fn panic_free_net_allows_ranges_fallbacks_and_other_files() {
        let ok = "fn f(buf: &[u8]) -> &[u8] {\n    let w = buf.first().copied().unwrap_or(0);\n    g(w);\n    &buf[4..8]\n}";
        assert!(run(panic_free_net, "src/util/frame.rs", ok).is_empty());
        // identical code outside the net scope is not this rule's business
        let elsewhere = "fn f(buf: &[u8]) -> u8 { buf[0] }";
        assert!(run(panic_free_net, "src/infer/native.rs", elsewhere).is_empty());
    }

    #[test]
    fn panic_free_net_exempts_test_code() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g(buf: &[u8]) -> u8 {\n        parse(buf).unwrap();\n        buf[0]\n    }\n}";
        assert!(run(panic_free_net, "src/coordinator/net/mod.rs", src).is_empty());
    }

    // ---- driver ---------------------------------------------------

    #[test]
    fn check_all_runs_every_rule() {
        let bad = "fn f(c: &AtomicU64) {\n    c.fetch_add(1, Ordering::Relaxed);\n    unsafe { g() }\n}";
        let f = SourceFile::parse("src/volume/stream.rs", bad);
        let mut out = Vec::new();
        check_all(&f, &mut out);
        let rules: Vec<&str> = out.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"unsafe-confinement"));
        assert!(rules.contains(&"safety-comment"));
        assert!(rules.contains(&"atomic-ordering"));
    }
}
