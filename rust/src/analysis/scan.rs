//! Lexical scanner for the repo-invariant analyzer (DESIGN.md §6).
//!
//! Deliberately **not** a Rust parser: every rule in [`super::rules`]
//! works on two per-line views produced here — `code` (the line with
//! comment text and string/char-literal contents blanked to spaces) and
//! `comment` (the concatenated text of the line's comments).  Code
//! patterns are matched against `code`, so prose that mentions `unwrap`
//! or `unsafe` can never false-positive; markers are matched against
//! `comment`, so a pattern string in the analyzer's own source can
//! never open a region or grant an allowance.
//!
//! The scanner carries a small state machine across lines (block
//! comments, plain strings with escapes, raw strings with `#` fences)
//! and adds two structural helpers the rules share: trailing
//! `#[cfg(test)]` block detection and `fn` extents by brace counting.

/// One physical source line in both views.
pub struct Line {
    /// Comments and string/char-literal contents replaced by spaces
    /// (delimiters kept, so `.expect(` still matches as code).
    pub code: String,
    /// Text of every comment span overlapping this line.
    pub comment: String,
}

/// A scanned file: crate-relative path (forward slashes), per-line
/// views, and where the trailing `#[cfg(test)]` block starts.
pub struct SourceFile {
    pub path: String,
    pub lines: Vec<Line>,
    test_start: Option<usize>,
}

/// Scanner state carried across physical lines.
enum St {
    Code,
    /// Inside `/* */`, with nesting depth.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string, with its `#` fence count.
    RawStr(usize),
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> Self {
        let mut st = St::Code;
        let mut lines = Vec::new();
        for raw in text.lines() {
            let (code, comment, next) = scan_line(raw, st);
            st = next;
            lines.push(Line { code, comment });
        }
        // Every `#[cfg(test)]` module in this crate is tail-positioned
        // (enforced de facto by the meta-test: a mid-file test block
        // would exempt real code below it and the rules would miss
        // violations there, never invent them).
        let test_start = lines.iter().position(|l| {
            let t = l.code.trim_start();
            t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test")
        });
        SourceFile {
            path: path.replace('\\', "/"),
            lines,
            test_start,
        }
    }

    /// True when 0-based line `i` is inside the trailing test block.
    pub fn is_test(&self, i: usize) -> bool {
        self.test_start.is_some_and(|t| i >= t)
    }
}

/// Split one physical line into (code view, comment view, next state).
fn scan_line(raw: &str, mut st: St) -> (String, String, St) {
    let ch: Vec<char> = raw.chars().collect();
    let mut code = String::with_capacity(raw.len());
    let mut comment = String::new();
    let mut i = 0;
    while i < ch.len() {
        match st {
            St::Code => {
                let c = ch[i];
                if c == '/' && ch.get(i + 1) == Some(&'/') {
                    for &cc in &ch[i + 2..] {
                        comment.push(cc);
                    }
                    break;
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                    st = St::Block(1);
                } else if c == '"' {
                    st = match raw_fence(&code) {
                        Some(h) => St::RawStr(h),
                        None => St::Str,
                    };
                    code.push('"');
                    i += 1;
                } else if c == '\'' {
                    i = consume_quote(&ch, i, &mut code);
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::Block(depth) => {
                if ch[i] == '*' && ch.get(i + 1) == Some(&'/') {
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::Block(depth - 1)
                    };
                } else if ch[i] == '/' && ch.get(i + 1) == Some(&'*') {
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                    st = St::Block(depth + 1);
                } else {
                    comment.push(ch[i]);
                    code.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if ch[i] == '\\' {
                    code.push(' ');
                    i += 1;
                    if i < ch.len() {
                        code.push(' ');
                        i += 1;
                    }
                } else if ch[i] == '"' {
                    code.push('"');
                    i += 1;
                    st = St::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if ch[i] == '"' && (1..=h).all(|k| ch.get(i + k) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..h {
                        code.push('#');
                    }
                    i += 1 + h;
                    st = St::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    (code, comment, st)
}

/// When the code emitted so far ends in `r`/`br` plus `#` fences, the
/// `"` being looked at opens a raw string; returns the fence count.
fn raw_fence(code: &str) -> Option<usize> {
    let b: Vec<char> = code.chars().collect();
    let mut i = b.len();
    let mut fences = 0usize;
    while i > 0 && b[i - 1] == '#' {
        i -= 1;
        fences += 1;
    }
    if i == 0 || b[i - 1] != 'r' {
        return None;
    }
    let mut start = i - 1;
    if start > 0 && b[start - 1] == 'b' {
        start -= 1;
    }
    let ident = |c: char| c == '_' || c.is_alphanumeric();
    if start > 0 && ident(b[start - 1]) {
        return None; // identifier merely ending in r/br
    }
    Some(fences)
}

/// Handle a `'` in code position: blank a char literal, pass a
/// lifetime/label quote through.  Returns the next index.
fn consume_quote(ch: &[char], mut i: usize, code: &mut String) -> usize {
    if ch.get(i + 1) == Some(&'\\') {
        // escaped char literal: blank to the closing quote, consuming
        // backslash-escape pairs whole so `'\''` and `'\\'` close right
        code.push('\'');
        i += 1;
        while i < ch.len() {
            if ch[i] == '\\' {
                code.push(' ');
                i += 1;
                if i < ch.len() {
                    code.push(' ');
                    i += 1;
                }
            } else if ch[i] == '\'' {
                code.push('\'');
                i += 1;
                break;
            } else {
                code.push(' ');
                i += 1;
            }
        }
        i
    } else if ch.get(i + 2) == Some(&'\'') && ch.get(i + 1).is_some() {
        // plain char literal 'x'
        code.push('\'');
        code.push(' ');
        code.push('\'');
        i + 3
    } else {
        // lifetime or loop label
        code.push('\'');
        i + 1
    }
}

/// True when the code view of a line starts an `fn` item (visibility,
/// `const`, `unsafe`, `extern "…"` qualifiers allowed).  Closures and
/// `fn(..)` pointer types never match.
pub fn is_fn_header(code: &str) -> bool {
    for tok in code.split_whitespace() {
        match tok {
            "fn" => return true,
            "pub" | "const" | "unsafe" | "extern" | "async" => continue,
            t if t.starts_with("pub(") => continue,
            t if t.starts_with('"') => continue,
            _ => return false,
        }
    }
    false
}

/// `(header_line, last_body_line)` (0-based, inclusive) for every `fn`
/// with a body, nested fns included, by brace counting over the code
/// view.  Bodyless trait signatures (`;` before any `{`) are skipped.
pub fn fn_ranges(file: &SourceFile) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for i in 0..file.lines.len() {
        if !is_fn_header(&file.lines[i].code) {
            continue;
        }
        let mut depth = 0i32;
        let mut opened = false;
        'body: for j in i..file.lines.len() {
            for c in file.lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            out.push((i, j));
                            break 'body;
                        }
                    }
                    ';' if !opened => break 'body,
                    _ => {}
                }
            }
        }
    }
    out
}

/// The innermost fn range containing `line`, if any.
pub fn innermost_fn(ranges: &[(usize, usize)], line: usize) -> Option<(usize, usize)> {
    ranges
        .iter()
        .copied()
        .filter(|&(a, b)| a <= line && line <= b)
        .max_by_key(|&(a, _)| a)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(src: &str) -> SourceFile {
        SourceFile::parse("src/x.rs", src)
    }

    #[test]
    fn comments_leave_code_view() {
        let f = one("let a = 1; // unwrap the gift\n/* expect */ let b = 2;");
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].comment.contains("unwrap the gift"));
        assert!(!f.lines[1].code.contains("expect"));
        assert!(f.lines[1].code.contains("let b = 2;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let f = one("/* a /* b */\nstill comment */ let x = 9;");
        assert!(!f.lines[0].code.contains('a'));
        assert!(!f.lines[1].code.contains("still"));
        assert!(f.lines[1].code.contains("let x = 9;"));
        assert!(f.lines[1].comment.contains("still comment"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let f = one(r#"let m = "call .unwrap( now"; m.len();"#);
        assert!(!f.lines[0].code.contains(".unwrap("));
        assert!(f.lines[0].code.contains("m.len();"));
    }

    #[test]
    fn escaped_quotes_stay_inside_the_string() {
        let f = one(r#"let m = "a \" .expect( b"; real();"#);
        assert!(!f.lines[0].code.contains(".expect("));
        assert!(f.lines[0].code.contains("real();"));
    }

    #[test]
    fn raw_strings_with_fences_are_blanked() {
        let f = one("let m = r#\"one .unwrap( two\"# ; after();");
        assert!(!f.lines[0].code.contains(".unwrap("));
        assert!(f.lines[0].code.contains("after();"));
    }

    #[test]
    fn multiline_raw_string_is_blanked_to_its_fence() {
        let f = one("let m = r#\"\n.unwrap(\n\"#; done();");
        assert!(!f.lines[1].code.contains(".unwrap("));
        assert!(f.lines[2].code.contains("done();"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let f = one("let c = '\"'; let s: &'a str = x; let n = '\\n';");
        // the quote inside the char literal must not open a string
        assert!(f.lines[0].code.contains("let s: &'a str = x;"));
        // a backslash char literal must not swallow the rest of the line
        let g = one("if ch[i] == '\\\\' { x.after(); }");
        assert!(g.lines[0].code.contains("x.after();"));
    }

    #[test]
    fn test_block_detection() {
        let f = one("fn a() {}\n#[cfg(test)]\nmod tests { }");
        assert!(!f.is_test(0));
        assert!(f.is_test(1));
        assert!(f.is_test(2));
        let g = one("fn a() {}\n#[cfg(all(test, feature = \"simd\"))]\nmod tests { }");
        assert!(g.is_test(1));
    }

    #[test]
    fn fn_headers_and_ranges() {
        assert!(is_fn_header("fn f(x: usize) -> usize {"));
        assert!(is_fn_header("    pub unsafe fn g("));
        assert!(is_fn_header("pub(crate) const fn h() {"));
        assert!(!is_fn_header("let f = |x| x + 1;"));
        assert!(!is_fn_header("w3: fn(usize) -> f32,"));
        let f = one("fn outer() {\n    let a = 1;\n    fn inner() {\n        a;\n    }\n}");
        let r = fn_ranges(&f);
        assert_eq!(r, vec![(0, 5), (2, 4)]);
        assert_eq!(innermost_fn(&r, 3), Some((2, 4)));
        assert_eq!(innermost_fn(&r, 1), Some((0, 5)));
    }

    #[test]
    fn bodyless_signatures_are_skipped() {
        let f = one("trait T {\n    fn sig(&self) -> usize;\n}");
        assert!(fn_ranges(&f).is_empty());
    }
}
