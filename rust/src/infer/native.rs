//! Pure-Rust f32 inference engine — the measured CPU baseline (Table II)
//! and the numeric oracle for the accelerator simulator.
//!
//! Implements exactly the uIVIM-NET forward pass of
//! `python/compile/model.py::subnet_infer` (inference-mode BatchNorm,
//! fixed Masksembles masks), with the same op ordering so results agree
//! with the AOT executable to f32 round-off.

use super::{Engine, InferOutput};
use crate::ivim::Param;
use crate::masks::MaskSet;
use crate::model::{Manifest, SubnetWeights, Weights};

const EPS: f32 = 1e-5;

/// Pre-extracted per-subnet state (avoids re-slicing per batch).
struct SubnetState {
    param: Param,
    /// Output-major (transposed) weights: `w1t[o*nb + i]` — contiguous
    /// per-output rows so the PU dot product streams cache lines.
    w1: Vec<f32>,
    b1: Vec<f32>,
    bn1_scale: Vec<f32>, // gamma / sqrt(var + eps)
    bn1_shift: Vec<f32>, // beta - mean * scale
    w2: Vec<f32>,
    b2: Vec<f32>,
    bn2_scale: Vec<f32>,
    bn2_shift: Vec<f32>,
    w3: Vec<f32>,
    b3: f32,
    mask1: MaskSet,
    mask2: MaskSet,
    /// Precomputed kept-output index lists per sample (mask-zero
    /// skipping without a per-output branch in the hot loop).
    kept1: Vec<Vec<usize>>,
    kept2: Vec<Vec<usize>>,
}

/// The native engine.  One instance per (manifest, weights) pair; batch
/// size matches the manifest's `batch_infer` so comparisons with the PJRT
/// engine are apples-to-apples.
pub struct NativeEngine {
    nb: usize,
    n_samples: usize,
    batch: usize,
    subnets: Vec<SubnetState>,
    // scratch buffers reused across calls (hot path: no allocation)
    h1: Vec<f32>,
    h2: Vec<f32>,
}

/// Transpose an input-major `[nb_in][nb_out]` matrix into output-major
/// rows (perf: the hot dot product then reads contiguously).
fn transpose(w: &[f32], nb: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; w.len()];
    for i in 0..nb {
        for o in 0..nb {
            t[o * nb + i] = w[i * nb + o];
        }
    }
    t
}

fn fold_bn(g: &[f32], be: &[f32], m: &[f32], v: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let scale: Vec<f32> = g
        .iter()
        .zip(v)
        .map(|(&g, &v)| g / (v + EPS).sqrt())
        .collect();
    let shift: Vec<f32> = be
        .iter()
        .zip(m.iter().zip(&scale))
        .map(|(&be, (&m, &s))| be - m * s)
        .collect();
    (scale, shift)
}

impl NativeEngine {
    pub fn new(man: &Manifest, weights: &Weights) -> anyhow::Result<Self> {
        Self::with_batch(man, weights, man.batch_infer)
    }

    /// Engine with a custom batch size (the native path has no static
    /// shape constraint; used by the coordinator for tail batches).
    pub fn with_batch(man: &Manifest, weights: &Weights, batch: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let mut subnets = Vec::with_capacity(4);
        for p in Param::ALL {
            let sn = p.name();
            let sw: SubnetWeights = weights.subnet(man, sn);
            let (s1, sh1) = fold_bn(sw.g1, sw.be1, sw.m1, sw.v1);
            let (s2, sh2) = fold_bn(sw.g2, sw.be2, sw.m2, sw.v2);
            subnets.push(SubnetState {
                param: p,
                w1: transpose(sw.w1, man.nb),
                b1: sw.b1.to_vec(),
                bn1_scale: s1,
                bn1_shift: sh1,
                w2: transpose(sw.w2, man.nb),
                b2: sw.b2.to_vec(),
                bn2_scale: s2,
                bn2_shift: sh2,
                w3: sw.w3.to_vec(),
                b3: sw.b3[0],
                mask1: man
                    .mask(sn, 1)
                    .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.1"))?
                    .clone(),
                mask2: man
                    .mask(sn, 2)
                    .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.2"))?
                    .clone(),
                kept1: (0..man.n_samples)
                    .map(|s| man.mask(sn, 1).unwrap().kept_indices(s))
                    .collect(),
                kept2: (0..man.n_samples)
                    .map(|s| man.mask(sn, 2).unwrap().kept_indices(s))
                    .collect(),
            });
        }
        Ok(NativeEngine {
            nb: man.nb,
            n_samples: man.n_samples,
            batch,
            subnets,
            h1: vec![0.0; batch * man.nb],
            h2: vec![0.0; batch * man.nb],
        })
    }

    pub fn nb(&self) -> usize {
        self.nb
    }
    pub fn n_samples(&self) -> usize {
        self.n_samples
    }

    /// One masked hidden block over the whole batch for one mask sample:
    /// `out = relu(bn(x @ w + b)) * mask_row`, with BN folded to
    /// `scale/shift`.
    #[inline]
    fn hidden_block(
        nb: usize,
        batch: usize,
        x: &[f32],
        w: &[f32],
        b: &[f32],
        scale: &[f32],
        shift: &[f32],
        mask_row: &[u8],
        kept: &[usize],
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), batch * nb);
        debug_assert_eq!(out.len(), batch * nb);
        let _ = mask_row;
        for v in 0..batch {
            let xi = &x[v * nb..(v + 1) * nb];
            let oi = &mut out[v * nb..(v + 1) * nb];
            oi.fill(0.0);
            // mask-zero skipping: only kept outputs are scheduled (the
            // software analogue of not storing dropped weights)
            for &o in kept {
                let wo = &w[o * nb..(o + 1) * nb];
                // 4-way unrolled dot product: independent accumulators
                // break the FP dependency chain for ILP.
                let mut a0 = 0.0f32;
                let mut a1 = 0.0f32;
                let mut a2 = 0.0f32;
                let mut a3 = 0.0f32;
                let chunks = nb / 4 * 4;
                let mut i = 0;
                while i < chunks {
                    a0 += xi[i] * wo[i];
                    a1 += xi[i + 1] * wo[i + 1];
                    a2 += xi[i + 2] * wo[i + 2];
                    a3 += xi[i + 3] * wo[i + 3];
                    i += 4;
                }
                let mut acc = (a0 + a1) + (a2 + a3);
                for j in chunks..nb {
                    acc += xi[j] * wo[j];
                }
                let h = (acc + b[o]) * scale[o] + shift[o];
                oi[o] = if h > 0.0 { h } else { 0.0 };
            }
        }
    }

    /// Forward one subnet for all samples, writing into `out`.
    fn subnet_forward(&mut self, si: usize, signals: &[f32], out: &mut InferOutput) {
        let nb = self.nb;
        let batch = self.batch;
        let sn = &self.subnets[si];
        for s in 0..self.n_samples {
            Self::hidden_block(
                nb,
                batch,
                signals,
                &sn.w1,
                &sn.b1,
                &sn.bn1_scale,
                &sn.bn1_shift,
                sn.mask1.row(s),
                &sn.kept1[s],
                &mut self.h1,
            );
            Self::hidden_block(
                nb,
                batch,
                &self.h1,
                &sn.w2,
                &sn.b2,
                &sn.bn2_scale,
                &sn.bn2_shift,
                sn.mask2.row(s),
                &sn.kept2[s],
                &mut self.h2,
            );
            for v in 0..batch {
                let hi = &self.h2[v * nb..(v + 1) * nb];
                let mut logit = sn.b3;
                for i in 0..nb {
                    logit += hi[i] * sn.w3[i];
                }
                let sig = 1.0 / (1.0 + (-logit).exp());
                out.set(sn.param, s, v, sn.param.convert(sig as f64) as f32);
            }
        }
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &str {
        "native-f32"
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn infer_batch(&mut self, signals: &[f32]) -> anyhow::Result<InferOutput> {
        anyhow::ensure!(
            signals.len() == self.batch * self.nb,
            "expected {}x{} signals, got {}",
            self.batch,
            self.nb,
            signals.len()
        );
        let mut out = InferOutput::new(self.n_samples, self.batch);
        for si in 0..self.subnets.len() {
            self.subnet_forward(si, signals, &mut out);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn outputs_in_clinical_ranges() {
        let Some((man, w)) = setup() else { return };
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 0);
        let out = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            let (lo, hi) = p.range();
            for s in 0..out.n_samples {
                for v in 0..out.batch {
                    let x = out.get(p, s, v) as f64;
                    assert!(x >= lo && x <= hi, "{p:?} {x} outside [{lo},{hi}]");
                }
            }
        }
    }

    #[test]
    fn samples_differ_across_masks() {
        let Some((man, w)) = setup() else { return };
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
        let out = eng.infer_batch(&ds.signals).unwrap();
        let any_spread = (0..out.batch)
            .any(|v| Param::ALL.iter().any(|&p| out.std(p, v) > 0.0));
        assert!(any_spread, "masks produced identical predictions");
    }

    #[test]
    fn deterministic() {
        let Some((man, w)) = setup() else { return };
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 2);
        let a = eng.infer_batch(&ds.signals).unwrap();
        let b = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(a.samples[p.index()], b.samples[p.index()]);
        }
    }

    #[test]
    fn rejects_wrong_batch() {
        let Some((man, w)) = setup() else { return };
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        assert!(eng.infer_batch(&vec![0.0; 3]).is_err());
    }

    #[test]
    fn custom_batch_size_works() {
        let Some((man, w)) = setup() else { return };
        let mut eng = NativeEngine::with_batch(&man, &w, 3).unwrap();
        let ds = synth_dataset(3, &man.bvalues, 20.0, 3);
        let out = eng.infer_batch(&ds.signals).unwrap();
        assert_eq!(out.batch, 3);
    }

    /// Cross-check vs the python golden outputs: the native engine must
    /// match the AOT executable's numerics (which the goldens capture) to
    /// f32 tolerance.
    #[test]
    fn matches_python_golden() {
        let Some((man, w)) = setup() else { return };
        let gin = crate::util::read_f32_file(&man.file("golden_in").unwrap()).unwrap();
        let gout = crate::util::read_f32_file(&man.file("golden_out").unwrap()).unwrap();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let out = eng.infer_batch(&gin).unwrap();
        let plane = man.n_samples * man.batch_infer;
        // golden_out layout: d, dstar, f, s0 planes then recon
        for (pi, p) in Param::ALL.iter().enumerate() {
            let want = &gout[pi * plane..(pi + 1) * plane];
            let got = &out.samples[p.index()];
            let max_diff = got
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // tolerance scaled to the parameter range (D is ~1e-3)
            let (lo, hi) = p.range();
            let tol = ((hi - lo) as f32) * 1e-4 + 1e-6;
            assert!(max_diff < tol, "{p:?} max diff {max_diff} > {tol}");
        }
    }
}
