//! Pure-Rust f32 inference engine — the measured CPU baseline (Table II)
//! and the numeric oracle for the accelerator simulator.
//!
//! Implements exactly the uIVIM-NET forward pass of
//! `python/compile/model.py::subnet_infer` (inference-mode BatchNorm,
//! fixed Masksembles masks), with the same per-output arithmetic as the
//! original per-voxel scalar path so results stay **bit-identical** to it
//! (the scalar path survives as the `#[cfg(test)]` oracle below).
//!
//! ## Blocked masked-GEMM hot path
//!
//! The paper's two hardware ideas (§V) have direct software analogues
//! here:
//!
//! * **Mask-zero skipping, hoisted out of the hot loop** — at engine
//!   construction each masked layer packs the transposed weight rows of
//!   the *union* of kept outputs across the N mask samples into one
//!   contiguous block ([`BlockedMaskedLinear`]); dropped rows are never
//!   stored or scheduled, and per-sample iteration is an index list into
//!   the shared block (the fold-BN'd weight block is reused by all N
//!   samples instead of N private copies).
//! * **Operation reordering (batch-level)** — layer 1's input is the raw
//!   signal batch, which is identical for every mask sample, so its
//!   union activations are computed **once per batch** and each sample's
//!   masked view is a cheap scatter; the seed path recomputed them N
//!   times.  At the paper's p = 0.5 mask density this alone halves the
//!   layer-1 MACs (4 samples x ~nb/2 kept rows -> nb union rows).
//!
//! On top of that the kernels are register-blocked 4 output rows at a
//! time ([`kernels::dot_rows`]) so one voxel's signals feed four dot
//! products in flight — in the default [`DotMode::Exact`] each
//! individual dot product keeps the seed's exact 4-way unrolled
//! accumulation order (whether the scalar or the SSE2 backend runs it),
//! which is what makes the bit-for-bit golden test possible.  The
//! kernel implementations and their dispatch contract live in
//! [`super::kernels`]; [`NativeEngine::set_dot_mode`] opts into the
//! reordered (tolerance-tested) order.

use super::kernels::{self, DotMode};
use super::{Engine, InferOutput};
use crate::ivim::Param;
use crate::masks::{LayerPlan, MaskPlan, MaskSet};
use crate::model::{Manifest, SubnetWeights, Weights};
use crate::util::workers::{self, WorkerPool};

const EPS: f32 = 1e-5;

/// Raw output pointer shared by the worker lanes of a tiled kernel.
/// Lanes write **disjoint** voxel tiles (see [`workers::tile`]) through
/// raw-pointer stores, never through aliasing `&mut` slices, so the
/// parallel path is sound and — because every element is produced by the
/// same per-dot kernel on the same inputs — bit-exact vs single-threaded.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer targets a scratch buffer that outlives every lane
// (the pool joins before the call returns) and lanes write disjoint
// tiles, so concurrent sends/shares of the wrapper cannot race.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Transpose an input-major `[nb_in][nb_out]` matrix into output-major
/// rows (perf: the hot dot product then reads contiguously).
fn transpose(w: &[f32], nb: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; w.len()];
    for i in 0..nb {
        for o in 0..nb {
            t[o * nb + i] = w[i * nb + o];
        }
    }
    t
}

fn fold_bn(g: &[f32], be: &[f32], m: &[f32], v: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let scale: Vec<f32> = g
        .iter()
        .zip(v)
        .map(|(&g, &v)| g / (v + EPS).sqrt())
        .collect();
    let shift: Vec<f32> = be
        .iter()
        .zip(m.iter().zip(&scale))
        .map(|(&be, (&m, &s))| be - m * s)
        .collect();
    (scale, shift)
}

/// Folded-BN affine + ReLU, in the seed's exact operation order.
#[inline]
fn affine_relu(acc: f32, b: f32, scale: f32, shift: f32) -> f32 {
    let h = (acc + b) * scale + shift;
    if h > 0.0 {
        h
    } else {
        0.0
    }
}

/// The seed scalar masked-linear path, kept public as the reference for
/// the golden-equivalence test and the `micro_hotpaths` blocked-vs-scalar
/// comparison: one mask sample, per-voxel loop, per-output dot product.
///
/// `out = relu(bn(x @ w + b)) * mask_row` with BN folded to scale/shift;
/// only `kept` outputs are scheduled (mask-zero skipping), the rest stay
/// zero.
#[allow(clippy::too_many_arguments)]
pub fn masked_linear_reference(
    nb: usize,
    batch: usize,
    x: &[f32],
    w: &[f32],
    b: &[f32],
    scale: &[f32],
    shift: &[f32],
    kept: &[usize],
    out: &mut [f32],
) {
    assert_eq!(x.len(), batch * nb);
    assert_eq!(out.len(), batch * nb);
    for v in 0..batch {
        let xi = &x[v * nb..(v + 1) * nb];
        let oi = &mut out[v * nb..(v + 1) * nb];
        oi.fill(0.0);
        for &o in kept {
            let wo = &w[o * nb..(o + 1) * nb];
            // always the scalar oracle — the reference never dispatches
            let acc = kernels::dot_one_scalar(nb, xi, wo);
            oi[o] = affine_relu(acc, b[o], scale[o], shift[o]);
        }
    }
}

/// One masked layer, packed for the blocked path.
///
/// Storage is the union of kept outputs across all N mask samples — the
/// mask-zero-skipped "stored weights" of the paper's Fig. 4, shared by
/// every sample — plus per-sample index lists into that block.
///
/// The layer also retains the full folded-BN dense tensors so the union
/// block can be re-packed **in place** for a new mask plan
/// ([`BlockedMaskedLinear::swap_masks`]): every packed buffer is
/// reserved for the worst case (union = all `nb` outputs) at
/// construction, so a swap never allocates and the weights are read
/// from the retained dense copy, never re-derived.
pub struct BlockedMaskedLinear {
    nb: usize,
    /// Retained dense tensors (transposed weights, bias, folded BN) —
    /// the source every re-pack reads from.
    dense_w: Vec<f32>,
    dense_b: Vec<f32>,
    dense_scale: Vec<f32>,
    dense_shift: Vec<f32>,
    /// Output indices present in at least one sample's mask, ascending.
    union: Vec<usize>,
    /// Packed transposed weight rows: `w[p*nb..(p+1)*nb]` is the row of
    /// output `union[p]`.
    w: Vec<f32>,
    b: Vec<f32>,
    scale: Vec<f32>,
    shift: Vec<f32>,
    /// Per sample: positions into `union` of that sample's kept outputs.
    kept_pos: Vec<Vec<u32>>,
    /// Scratch: output index -> packed position (`u32::MAX` = dropped).
    pos_of: Vec<u32>,
    /// Accumulation-order contract for this layer's dot products
    /// (default [`DotMode::Exact`]; see [`super::kernels`]).
    mode: DotMode,
}

impl BlockedMaskedLinear {
    /// Pack a layer from transposed weights `w_t` (`[nb][nb]`,
    /// output-major rows), bias and folded-BN scale/shift, under `mask`.
    pub fn new(
        nb: usize,
        w_t: &[f32],
        b: &[f32],
        scale: &[f32],
        shift: &[f32],
        mask: &MaskSet,
    ) -> Self {
        assert_eq!(mask.width, nb, "mask width must match the layer");
        let union: Vec<u32> = (0..nb as u32)
            .filter(|&o| (0..mask.n).any(|s| mask.row(s)[o as usize] == 1))
            .collect();
        let kept: Vec<Vec<u32>> = (0..mask.n)
            .map(|s| {
                mask.kept_indices(s)
                    .into_iter()
                    .map(|o| o as u32)
                    .collect()
            })
            .collect();
        let mut layer = BlockedMaskedLinear {
            nb,
            dense_w: w_t.to_vec(),
            dense_b: b.to_vec(),
            dense_scale: scale.to_vec(),
            dense_shift: shift.to_vec(),
            union: Vec::with_capacity(nb),
            w: Vec::with_capacity(nb * nb),
            b: Vec::with_capacity(nb),
            scale: Vec::with_capacity(nb),
            shift: Vec::with_capacity(nb),
            kept_pos: (0..mask.n).map(|_| Vec::with_capacity(nb)).collect(),
            pos_of: vec![u32::MAX; nb],
            mode: DotMode::default(),
        };
        layer.apply_masks(&union, &kept);
        layer
    }

    /// Re-pack the union block and per-sample index lists for a new set
    /// of masks, entirely inside the capacity reserved at construction.
    /// Dense weights/bias/BN are untouched — only which rows are packed
    /// (and in which positions) changes.
    fn apply_masks(&mut self, union: &[u32], kept: &[Vec<u32>]) {
        let nb = self.nb;
        assert_eq!(kept.len(), self.kept_pos.len());
        self.union.clear();
        self.union.extend(union.iter().map(|&o| o as usize));
        self.pos_of.fill(u32::MAX);
        self.w.clear();
        self.b.clear();
        self.scale.clear();
        self.shift.clear();
        for (p, &o) in union.iter().enumerate() {
            let o = o as usize;
            self.pos_of[o] = p as u32;
            self.w.extend_from_slice(&self.dense_w[o * nb..(o + 1) * nb]);
            self.b.push(self.dense_b[o]);
            self.scale.push(self.dense_scale[o]);
            self.shift.push(self.dense_shift[o]);
        }
        for (s, ks) in kept.iter().enumerate() {
            let pos_of = &self.pos_of;
            let kp = &mut self.kept_pos[s];
            kp.clear();
            kp.extend(ks.iter().map(|&o| pos_of[o as usize]));
        }
    }

    /// Hot-swap this layer's masks from a [`LayerPlan`] (same width,
    /// same sample count).  Zero-allocation: see [`Self::apply_masks`].
    pub fn swap_masks(&mut self, layer: &LayerPlan) -> anyhow::Result<()> {
        anyhow::ensure!(
            layer.width() == self.nb,
            "plan width {} != layer width {}",
            layer.width(),
            self.nb
        );
        anyhow::ensure!(
            layer.n() == self.kept_pos.len(),
            "plan has {} samples, layer packed for {}",
            layer.n(),
            self.kept_pos.len()
        );
        self.apply_masks(layer.union(), layer.kept_lists());
        Ok(())
    }

    /// Capacities of every owned buffer — the no-allocation witness for
    /// the steady-state swap tests.
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = vec![
            self.w.capacity(),
            self.b.capacity(),
            self.scale.capacity(),
            self.shift.capacity(),
            self.union.capacity(),
            self.pos_of.capacity(),
        ];
        sig.extend(self.kept_pos.iter().map(|k| k.capacity()));
        sig
    }

    pub fn nb(&self) -> usize {
        self.nb
    }

    /// Select the accumulation-order contract for this layer's dot
    /// products.  [`DotMode::Exact`] (the default) is bit-for-bit the
    /// seed order on every backend; [`DotMode::Reordered`] trades that
    /// for wider vectors and is only tolerance-tested.
    pub fn set_dot_mode(&mut self, mode: DotMode) {
        self.mode = mode;
    }

    pub fn dot_mode(&self) -> DotMode {
        self.mode
    }

    /// Rows in the shared (union) weight block.
    pub fn union_len(&self) -> usize {
        self.union.len()
    }

    /// Kept outputs of sample `s`.
    pub fn kept_len(&self, s: usize) -> usize {
        self.kept_pos[s].len()
    }

    pub fn n_samples(&self) -> usize {
        self.kept_pos.len()
    }

    /// Evaluate every union output over the batch, output-major:
    /// `act[p * batch + v]` is output `union[p]` for voxel `v`.  Sample-
    /// independent — call once per batch and reuse for all N samples.
    pub fn forward_union(&self, batch: usize, x: &[f32], act: &mut [f32]) {
        // Hard asserts: these bounds license the raw-pointer stores in
        // `forward_union_range_raw`; a `debug_assert` would vanish in
        // release and turn a short buffer into an out-of-bounds write.
        assert_eq!(x.len(), batch * self.nb);
        assert!(act.len() >= self.union.len() * batch);
        // SAFETY: single caller-owned `act`, full voxel range.
        unsafe { self.forward_union_range_raw(batch, x, act.as_mut_ptr(), 0, batch) }
    }

    /// [`Self::forward_union`] split across a [`WorkerPool`]'s lanes by
    /// **voxel tile** — the row blocking and the per-element kernel
    /// calls are unchanged (lane `k` runs the identical loop restricted
    /// to voxels `tile(batch, threads, k)`), so the result is bit-exact
    /// vs the single-threaded path for every thread count.
    pub fn forward_union_tiled(&self, batch: usize, x: &[f32], act: &mut [f32], pool: &WorkerPool) {
        if pool.worker_threads() == 0 {
            self.forward_union(batch, x, act);
            return;
        }
        // Hard asserts for the same reason as in `forward_union`.
        assert_eq!(x.len(), batch * self.nb);
        assert!(act.len() >= self.union.len() * batch);
        let threads = pool.threads();
        let ptr = SendPtr(act.as_mut_ptr());
        pool.run(threads, |lane| {
            let (lo, hi) = workers::tile(batch, threads, lane);
            if lo < hi {
                // SAFETY: lane writes only `act[p * batch + v]` for
                // v in [lo, hi); tiles are disjoint across lanes and
                // `act` outlives the run's completion barrier.
                unsafe { self.forward_union_range_raw(batch, x, ptr.0, lo, hi) }
            }
        })
        .expect("forward_union worker lane panicked");
    }

    /// Inner loop of [`Self::forward_union`] over voxels `[v_lo, v_hi)`.
    ///
    /// # Safety
    /// `act` must be valid for `union_len * batch` elements and no other
    /// thread may concurrently touch indices `p * batch + v` with
    /// `v` in `[v_lo, v_hi)`.
    unsafe fn forward_union_range_raw(
        &self,
        batch: usize,
        x: &[f32],
        act: *mut f32,
        v_lo: usize,
        v_hi: usize,
    ) {
        let nb = self.nb;
        let rows = self.union.len();
        let mut r = 0;
        while r + 4 <= rows {
            let ws = [
                &self.w[r * nb..(r + 1) * nb],
                &self.w[(r + 1) * nb..(r + 2) * nb],
                &self.w[(r + 2) * nb..(r + 3) * nb],
                &self.w[(r + 3) * nb..(r + 4) * nb],
            ];
            for v in v_lo..v_hi {
                let xv = &x[v * nb..(v + 1) * nb];
                let d = kernels::dot_rows(self.mode, nb, xv, ws);
                for k in 0..4 {
                    *act.add((r + k) * batch + v) =
                        affine_relu(d[k], self.b[r + k], self.scale[r + k], self.shift[r + k]);
                }
            }
            r += 4;
        }
        while r < rows {
            let wr = &self.w[r * nb..(r + 1) * nb];
            for v in v_lo..v_hi {
                let xv = &x[v * nb..(v + 1) * nb];
                let acc = kernels::dot_one(self.mode, nb, xv, wr);
                *act.add(r * batch + v) = affine_relu(acc, self.b[r], self.scale[r], self.shift[r]);
            }
            r += 1;
        }
    }

    /// Scatter sample `s`'s kept union activations into a voxel-major
    /// `[batch][nb]` buffer (dropped outputs are zeroed — the mask).
    pub fn scatter_sample(&self, s: usize, batch: usize, act: &[f32], out: &mut [f32]) {
        // Hard assert: this bound licenses the raw stores in
        // `scatter_sample_range_raw`.
        assert_eq!(out.len(), batch * self.nb);
        // SAFETY: single caller-owned `out`, full voxel range.
        unsafe { self.scatter_sample_range_raw(s, batch, act, out.as_mut_ptr(), 0, batch) }
    }

    /// [`Self::scatter_sample`] split across a [`WorkerPool`]'s lanes by
    /// voxel tile; each lane zeroes and scatters only its own voxels'
    /// `[nb]` rows, so writes are disjoint and the result is bit-exact
    /// vs single-threaded (pure data movement, no arithmetic).
    pub fn scatter_sample_tiled(
        &self,
        s: usize,
        batch: usize,
        act: &[f32],
        out: &mut [f32],
        pool: &WorkerPool,
    ) {
        if pool.worker_threads() == 0 {
            self.scatter_sample(s, batch, act, out);
            return;
        }
        // Hard assert for the same reason as in `scatter_sample`.
        assert_eq!(out.len(), batch * self.nb);
        let threads = pool.threads();
        let ptr = SendPtr(out.as_mut_ptr());
        pool.run(threads, |lane| {
            let (lo, hi) = workers::tile(batch, threads, lane);
            if lo < hi {
                // SAFETY: lane writes only voxel rows [lo, hi) of `out`;
                // tiles are disjoint and `out` outlives the barrier.
                unsafe { self.scatter_sample_range_raw(s, batch, act, ptr.0, lo, hi) }
            }
        })
        .expect("scatter_sample worker lane panicked");
    }

    /// Inner loop of [`Self::scatter_sample`] over voxels `[v_lo, v_hi)`
    /// (zeroes those voxels' rows, then scatters the kept columns).
    ///
    /// # Safety
    /// `out` must be valid for `batch * nb` elements and no other thread
    /// may concurrently touch voxel rows `[v_lo, v_hi)`.
    unsafe fn scatter_sample_range_raw(
        &self,
        s: usize,
        batch: usize,
        act: &[f32],
        out: *mut f32,
        v_lo: usize,
        v_hi: usize,
    ) {
        let nb = self.nb;
        assert!(v_hi <= batch);
        for i in v_lo * nb..v_hi * nb {
            *out.add(i) = 0.0;
        }
        for &p in &self.kept_pos[s] {
            let p = p as usize;
            let o = self.union[p];
            let col = &act[p * batch..(p + 1) * batch];
            for v in v_lo..v_hi {
                *out.add(v * nb + o) = col[v];
            }
        }
    }

    /// Evaluate sample `s` directly into a voxel-major `[batch][nb]`
    /// buffer (used when the input differs per sample, i.e. layer 2).
    /// Only the sample's kept rows are scheduled.
    pub fn forward_sample(&self, s: usize, batch: usize, x: &[f32], out: &mut [f32]) {
        let nb = self.nb;
        assert_eq!(x.len(), batch * nb);
        assert_eq!(out.len(), batch * nb);
        out.fill(0.0);
        let pos = &self.kept_pos[s];
        let mut k = 0;
        while k + 4 <= pos.len() {
            let p = [
                pos[k] as usize,
                pos[k + 1] as usize,
                pos[k + 2] as usize,
                pos[k + 3] as usize,
            ];
            let ws = [
                &self.w[p[0] * nb..(p[0] + 1) * nb],
                &self.w[p[1] * nb..(p[1] + 1) * nb],
                &self.w[p[2] * nb..(p[2] + 1) * nb],
                &self.w[p[3] * nb..(p[3] + 1) * nb],
            ];
            for v in 0..batch {
                let xv = &x[v * nb..(v + 1) * nb];
                let d = kernels::dot_rows(self.mode, nb, xv, ws);
                let ov = &mut out[v * nb..(v + 1) * nb];
                for j in 0..4 {
                    ov[self.union[p[j]]] =
                        affine_relu(d[j], self.b[p[j]], self.scale[p[j]], self.shift[p[j]]);
                }
            }
            k += 4;
        }
        while k < pos.len() {
            let p = pos[k] as usize;
            let wr = &self.w[p * nb..(p + 1) * nb];
            let o = self.union[p];
            for v in 0..batch {
                let xv = &x[v * nb..(v + 1) * nb];
                let acc = kernels::dot_one(self.mode, nb, xv, wr);
                out[v * nb + o] = affine_relu(acc, self.b[p], self.scale[p], self.shift[p]);
            }
            k += 1;
        }
    }
}

/// Pre-packed per-subnet state for the blocked engine.
struct SubnetState {
    param: Param,
    l1: BlockedMaskedLinear,
    l2: BlockedMaskedLinear,
    w3: Vec<f32>,
    b3: f32,
}

fn build_subnets(man: &Manifest, weights: &Weights) -> anyhow::Result<Vec<SubnetState>> {
    let mut subnets = Vec::with_capacity(4);
    for p in Param::ALL {
        let sn = p.name();
        let sw: SubnetWeights = weights.subnet(man, sn);
        let (s1, sh1) = fold_bn(sw.g1, sw.be1, sw.m1, sw.v1);
        let (s2, sh2) = fold_bn(sw.g2, sw.be2, sw.m2, sw.v2);
        let mask1 = man
            .mask(sn, 1)
            .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.1"))?;
        let mask2 = man
            .mask(sn, 2)
            .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.2"))?;
        let w1t = transpose(sw.w1, man.nb);
        let w2t = transpose(sw.w2, man.nb);
        subnets.push(SubnetState {
            param: p,
            l1: BlockedMaskedLinear::new(man.nb, &w1t, sw.b1, &s1, &sh1, mask1),
            l2: BlockedMaskedLinear::new(man.nb, &w2t, sw.b2, &s2, &sh2, mask2),
            w3: sw.w3.to_vec(),
            b3: sw.b3[0],
        });
    }
    Ok(subnets)
}

/// The native engine.  One instance per (manifest, weights) pair; batch
/// size matches the manifest's `batch_infer` so comparisons with the PJRT
/// engine are apples-to-apples.
pub struct NativeEngine {
    nb: usize,
    n_samples: usize,
    batch: usize,
    subnets: Vec<SubnetState>,
    /// Persistent lanes for the tiled layer-1 kernels (built once; a
    /// 1-thread pool spawns nothing and keeps the exact inline path).
    workers: WorkerPool,
    // scratch buffers reused across calls (hot path: no allocation)
    act1: Vec<f32>,
    h1: Vec<f32>,
    h2: Vec<f32>,
}

impl NativeEngine {
    pub fn new(man: &Manifest, weights: &Weights) -> anyhow::Result<Self> {
        Self::with_batch(man, weights, man.batch_infer)
    }

    /// Engine with a custom batch size (the native path has no static
    /// shape constraint; used by the coordinator for tail batches).
    pub fn with_batch(man: &Manifest, weights: &Weights, batch: usize) -> anyhow::Result<Self> {
        Self::with_batch_threads(man, weights, batch, 1)
    }

    /// Engine with a custom batch size and a persistent worker pool of
    /// `threads` lanes splitting the batch dimension of the layer-1
    /// kernels into fixed voxel tiles.  Output is **bit-identical** to
    /// `threads = 1` for every thread count (deterministic tiles, no
    /// cross-tile reductions, unchanged per-dot kernels).
    pub fn with_batch_threads(
        man: &Manifest,
        weights: &Weights,
        batch: usize,
        threads: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let subnets = build_subnets(man, weights)?;
        Ok(NativeEngine {
            nb: man.nb,
            n_samples: man.n_samples,
            batch,
            subnets,
            workers: WorkerPool::new(threads),
            // Sized for the worst-case union (all nb outputs), not the
            // current masks': a later `swap_masks` may grow the union
            // and must never reallocate.
            act1: vec![0.0; man.nb * batch],
            h1: vec![0.0; batch * man.nb],
            h2: vec![0.0; batch * man.nb],
        })
    }

    /// Worker lanes serving the tiled kernels (1 = inline).
    pub fn threads(&self) -> usize {
        self.workers.threads()
    }

    /// Hot-swap the engine's masks from a [`MaskPlan`] without touching
    /// weights or scratch: each layer re-packs its union weight block in
    /// place from its retained dense tensors (zero allocation), and the
    /// per-sample index lists are rebuilt.  The plan must match the
    /// engine's shape (`nb`, `n_samples`) and subnet names.
    ///
    /// Contract: after a swap the engine behaves **bit-for-bit** like a
    /// freshly constructed engine whose manifest carried the plan's
    /// masks; batch size, weights and output layout all survive the
    /// swap unchanged.
    pub fn swap_masks(&mut self, plan: &MaskPlan) -> anyhow::Result<()> {
        anyhow::ensure!(
            plan.nb() == self.nb,
            "plan width {} != engine width {}",
            plan.nb(),
            self.nb
        );
        anyhow::ensure!(
            plan.n_samples() == self.n_samples,
            "plan has {} samples, engine runs {}",
            plan.n_samples(),
            self.n_samples
        );
        // Validate every lookup and layer shape BEFORE mutating
        // anything: a failed swap must leave the engine exactly as it
        // was, never half-swapped.
        for sn in &self.subnets {
            let name = sn.param.name();
            for layer in [1usize, 2] {
                let lp = plan
                    .layer_for(name, layer)
                    .ok_or_else(|| anyhow::anyhow!("plan has no subnet '{name}'"))?;
                anyhow::ensure!(
                    lp.width() == self.nb && lp.n() == self.n_samples,
                    "plan layer {name}.{layer} is {}x{}, engine needs {}x{}",
                    lp.n(),
                    lp.width(),
                    self.n_samples,
                    self.nb
                );
            }
        }
        for sn in &mut self.subnets {
            let name = sn.param.name();
            for (layer, l) in [(1usize, &mut sn.l1), (2usize, &mut sn.l2)] {
                let lp = plan.layer_for(name, layer).expect("validated above");
                l.swap_masks(lp)?;
            }
        }
        Ok(())
    }

    /// Capacities of every scratch/packed buffer (layers + activation
    /// scratch) — stable across `swap_masks`/`execute_into` calls in
    /// steady state.
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = vec![self.act1.capacity(), self.h1.capacity(), self.h2.capacity()];
        sig.extend(self.workers.alloc_signature());
        for sn in &self.subnets {
            sig.extend(sn.l1.alloc_signature());
            sig.extend(sn.l2.alloc_signature());
            sig.push(sn.w3.capacity());
        }
        sig
    }

    pub fn nb(&self) -> usize {
        self.nb
    }
    pub fn n_samples(&self) -> usize {
        self.n_samples
    }

    /// Select the dot-product accumulation order for every masked layer.
    /// [`DotMode::Exact`] (the default) keeps the engine bit-for-bit
    /// identical to the scalar oracle; [`DotMode::Reordered`] opts into
    /// the wider-vector order, which is only tolerance-tested.  The
    /// encoder's sequential logit loop is deliberately not dispatched —
    /// its order is part of the seed contract regardless of mode.
    pub fn set_dot_mode(&mut self, mode: DotMode) {
        for sn in &mut self.subnets {
            sn.l1.set_dot_mode(mode);
            sn.l2.set_dot_mode(mode);
        }
    }

    // hot-path: native execute — subnet_forward and execute_into are the
    // zero-alloc serving core; all scratch is sized at construction.

    /// Forward one subnet for all samples, writing into `out`.
    ///
    /// Layer 1's union activations are computed once (its input is the
    /// sample-independent signal batch) and re-masked per sample; layer 2
    /// runs per sample on the masked activations; the encoder matches the
    /// seed path term-for-term.
    fn subnet_forward(&mut self, si: usize, signals: &[f32], out: &mut InferOutput) {
        let nb = self.nb;
        let batch = self.batch;
        let sn = &self.subnets[si];
        let pool = &self.workers;
        let u1 = sn.l1.union_len();
        let act1 = &mut self.act1[..u1 * batch];
        sn.l1.forward_union_tiled(batch, signals, act1, pool);
        for s in 0..self.n_samples {
            sn.l1.scatter_sample_tiled(s, batch, act1, &mut self.h1, pool);
            sn.l2.forward_sample(s, batch, &self.h1, &mut self.h2);
            for v in 0..batch {
                let hi = &self.h2[v * nb..(v + 1) * nb];
                let mut logit = sn.b3;
                for i in 0..nb {
                    logit += hi[i] * sn.w3[i];
                }
                let sig = 1.0 / (1.0 + (-logit).exp());
                out.set(sn.param, s, v, sn.param.convert(sig as f64) as f32);
            }
        }
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &str {
        "native-f32"
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn n_samples(&self) -> usize {
        self.n_samples
    }

    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        anyhow::ensure!(
            signals.len() == self.batch * self.nb,
            "expected {}x{} signals, got {}",
            self.batch,
            self.nb,
            signals.len()
        );
        out.reset(self.n_samples, self.batch);
        for si in 0..self.subnets.len() {
            self.subnet_forward(si, signals, out);
        }
        Ok(())
    }
}

// hot-path: end

/// The seed per-voxel scalar engine, preserved verbatim as the numeric
/// oracle for the blocked path (golden-equivalence test).  Test-only: the
/// production engine is [`NativeEngine`].
#[cfg(test)]
pub mod oracle {
    use super::*;

    struct ScalarSubnet {
        param: Param,
        w1: Vec<f32>,
        b1: Vec<f32>,
        bn1_scale: Vec<f32>,
        bn1_shift: Vec<f32>,
        w2: Vec<f32>,
        b2: Vec<f32>,
        bn2_scale: Vec<f32>,
        bn2_shift: Vec<f32>,
        w3: Vec<f32>,
        b3: f32,
        kept1: Vec<Vec<usize>>,
        kept2: Vec<Vec<usize>>,
    }

    /// Scalar per-voxel engine (the seed hot path).
    pub struct ScalarEngine {
        nb: usize,
        n_samples: usize,
        batch: usize,
        subnets: Vec<ScalarSubnet>,
        h1: Vec<f32>,
        h2: Vec<f32>,
    }

    impl ScalarEngine {
        pub fn with_batch(
            man: &Manifest,
            weights: &Weights,
            batch: usize,
        ) -> anyhow::Result<Self> {
            anyhow::ensure!(batch > 0, "batch must be positive");
            let mut subnets = Vec::with_capacity(4);
            for p in Param::ALL {
                let sn = p.name();
                let sw: SubnetWeights = weights.subnet(man, sn);
                let (s1, sh1) = fold_bn(sw.g1, sw.be1, sw.m1, sw.v1);
                let (s2, sh2) = fold_bn(sw.g2, sw.be2, sw.m2, sw.v2);
                let m1 = man
                    .mask(sn, 1)
                    .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.1"))?;
                let m2 = man
                    .mask(sn, 2)
                    .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.2"))?;
                subnets.push(ScalarSubnet {
                    param: p,
                    w1: transpose(sw.w1, man.nb),
                    b1: sw.b1.to_vec(),
                    bn1_scale: s1,
                    bn1_shift: sh1,
                    w2: transpose(sw.w2, man.nb),
                    b2: sw.b2.to_vec(),
                    bn2_scale: s2,
                    bn2_shift: sh2,
                    w3: sw.w3.to_vec(),
                    b3: sw.b3[0],
                    kept1: (0..man.n_samples).map(|s| m1.kept_indices(s)).collect(),
                    kept2: (0..man.n_samples).map(|s| m2.kept_indices(s)).collect(),
                });
            }
            Ok(ScalarEngine {
                nb: man.nb,
                n_samples: man.n_samples,
                batch,
                subnets,
                h1: vec![0.0; batch * man.nb],
                h2: vec![0.0; batch * man.nb],
            })
        }
    }

    impl Engine for ScalarEngine {
        fn name(&self) -> &str {
            "native-f32-scalar-oracle"
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn n_samples(&self) -> usize {
            self.n_samples
        }
        fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
            anyhow::ensure!(
                signals.len() == self.batch * self.nb,
                "expected {}x{} signals, got {}",
                self.batch,
                self.nb,
                signals.len()
            );
            let nb = self.nb;
            let batch = self.batch;
            out.reset(self.n_samples, batch);
            for sn in &self.subnets {
                for s in 0..self.n_samples {
                    masked_linear_reference(
                        nb,
                        batch,
                        signals,
                        &sn.w1,
                        &sn.b1,
                        &sn.bn1_scale,
                        &sn.bn1_shift,
                        &sn.kept1[s],
                        &mut self.h1,
                    );
                    masked_linear_reference(
                        nb,
                        batch,
                        &self.h1,
                        &sn.w2,
                        &sn.b2,
                        &sn.bn2_scale,
                        &sn.bn2_shift,
                        &sn.kept2[s],
                        &mut self.h2,
                    );
                    for v in 0..batch {
                        let hi = &self.h2[v * nb..(v + 1) * nb];
                        let mut logit = sn.b3;
                        for i in 0..nb {
                            logit += hi[i] * sn.w3[i];
                        }
                        let sig = 1.0 / (1.0 + (-logit).exp());
                        out.set(sn.param, s, v, sn.param.convert(sig as f64) as f32);
                    }
                }
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;
    use crate::testing::fixture;

    fn setup() -> (Manifest, Weights) {
        fixture::tiny_fixture()
    }

    /// Artifact-backed manifest when present (for the python golden test).
    fn artifact_setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn outputs_in_clinical_ranges() {
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 0);
        let out = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            let (lo, hi) = p.range();
            for s in 0..out.n_samples {
                for v in 0..out.batch {
                    let x = out.get(p, s, v) as f64;
                    assert!(x >= lo && x <= hi, "{p:?} {x} outside [{lo},{hi}]");
                }
            }
        }
    }

    #[test]
    fn samples_differ_across_masks() {
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
        let out = eng.infer_batch(&ds.signals).unwrap();
        let any_spread = (0..out.batch)
            .any(|v| Param::ALL.iter().any(|&p| out.std(p, v) > 0.0));
        assert!(any_spread, "masks produced identical predictions");
    }

    #[test]
    fn deterministic() {
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 2);
        let a = eng.infer_batch(&ds.signals).unwrap();
        let b = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(a.samples[p.index()], b.samples[p.index()]);
        }
    }

    #[test]
    fn rejects_wrong_batch() {
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        assert!(eng.infer_batch(&vec![0.0; 3]).is_err());
    }

    #[test]
    fn execute_into_reuses_buffers_across_calls() {
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 14);
        let mut out = InferOutput::new(man.n_samples, man.batch_infer);
        eng.execute_into(&ds.signals, &mut out).unwrap();
        let before: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        eng.execute_into(&ds.signals, &mut out).unwrap();
        let after: Vec<*const f32> = out.samples.iter().map(|p| p.as_ptr()).collect();
        assert_eq!(before, after, "steady-state execute_into must not reallocate");
    }

    #[test]
    fn custom_batch_size_works() {
        let (man, w) = setup();
        let mut eng = NativeEngine::with_batch(&man, &w, 3).unwrap();
        let ds = synth_dataset(3, &man.bvalues, 20.0, 3);
        let out = eng.infer_batch(&ds.signals).unwrap();
        assert_eq!(out.batch, 3);
    }

    /// Golden-vector regression: the blocked engine must be bit-for-bit
    /// identical to the seed scalar oracle on a fixed manifest — the
    /// blocking/reordering may change nothing but wall-clock.  Runs
    /// through the two-phase `execute_into` hot path with output buffers
    /// *reused across shapes*, so buffer recycling is covered by the
    /// golden gate too.
    #[test]
    fn blocked_matches_scalar_oracle_bit_for_bit() {
        let mut a = InferOutput::new(1, 1);
        let mut b = InferOutput::new(1, 1);
        for (tag, (man, w)) in [
            ("fixture", fixture::tiny_fixture()),
            (
                "fixture-nb17",
                fixture::build(&fixture::FixtureConfig {
                    nb: 17,
                    n_samples: 6,
                    batch_infer: 9,
                    weight_seed: 12,
                    ..Default::default()
                }),
            ),
        ] {
            let mut blocked = NativeEngine::new(&man, &w).unwrap();
            let mut scalar = oracle::ScalarEngine::with_batch(&man, &w, man.batch_infer).unwrap();
            let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 11);
            blocked.execute_into(&ds.signals, &mut a).unwrap();
            scalar.execute_into(&ds.signals, &mut b).unwrap();
            assert_eq!(a.n_samples, man.n_samples, "{tag}: reset reshaped the output");
            assert_eq!(a.batch, man.batch_infer);
            for p in Param::ALL {
                assert_eq!(
                    a.samples[p.index()],
                    b.samples[p.index()],
                    "{tag}: blocked != scalar for {p:?}"
                );
            }
        }
    }

    /// The blocked engine must also be bit-for-bit identical to the seed
    /// path on the real artifacts when they are present.
    #[test]
    fn blocked_matches_scalar_oracle_on_artifacts() {
        let Some((man, w)) = artifact_setup() else { return };
        let mut blocked = NativeEngine::new(&man, &w).unwrap();
        let mut scalar = oracle::ScalarEngine::with_batch(&man, &w, man.batch_infer).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 12);
        let a = blocked.infer_batch(&ds.signals).unwrap();
        let b = scalar.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(a.samples[p.index()], b.samples[p.index()]);
        }
    }

    /// And it must agree with the fixed-point accelerator simulator to
    /// the tolerance asserted in tests/accel_validation.rs.
    #[test]
    fn blocked_matches_accel_sim_within_tolerance() {
        use crate::accel::{AccelConfig, AccelSimulator, Scheme};
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let mut sim = AccelSimulator::new(
            &man,
            &w,
            AccelConfig {
                batch: man.batch_infer,
                ..Default::default()
            },
            Scheme::BatchLevel,
        )
        .unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 13);
        let a = eng.infer_batch(&ds.signals).unwrap();
        let b = sim.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            let (lo, hi) = p.range();
            let tol = (hi - lo) * 0.06; // same bound as tests/accel_validation.rs
            for s in 0..a.n_samples {
                for v in 0..a.batch {
                    let d = (a.get(p, s, v) - b.get(p, s, v)).abs() as f64;
                    assert!(d <= tol, "{p:?} s{s} v{v}: diff {d} > {tol}");
                }
            }
        }
    }

    /// Tentpole golden gate (ISSUE #3): a hot mask swap must be
    /// **bit-for-bit** indistinguishable from tearing the engine down
    /// and rebuilding it with the new masks baked into the manifest —
    /// across several resamples, on two fixture shapes.
    #[test]
    fn swap_masks_matches_fresh_engine_bit_for_bit() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let mut a = InferOutput::new(1, 1);
        let mut b = InferOutput::new(1, 1);
        for (tag, (man, w)) in [
            ("fixture", fixture::tiny_fixture()),
            (
                "fixture-nb17",
                fixture::build(&fixture::FixtureConfig {
                    nb: 17,
                    n_samples: 6,
                    batch_infer: 9,
                    weight_seed: 12,
                    ..Default::default()
                }),
            ),
        ] {
            let mut eng = NativeEngine::new(&man, &w).unwrap();
            let mut plan = MaskPlan::from_manifest(&man).unwrap();
            let mut rng = Pcg32::new(77);
            let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 21);
            for round in 0..4 {
                plan.resample(&mut rng);
                eng.swap_masks(&plan).unwrap();
                eng.execute_into(&ds.signals, &mut a).unwrap();
                let mut man2 = man.clone();
                plan.apply_to_manifest(&mut man2);
                let mut fresh = NativeEngine::new(&man2, &w).unwrap();
                fresh.execute_into(&ds.signals, &mut b).unwrap();
                for p in Param::ALL {
                    assert_eq!(
                        a.samples[p.index()],
                        b.samples[p.index()],
                        "{tag} round {round}: swap != fresh for {p:?}"
                    );
                }
            }
        }
    }

    /// Swapping back to the manifest's own masks restores the original
    /// outputs exactly (nothing beyond the index lists mutated).
    #[test]
    fn swap_masks_roundtrips_to_original() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let (man, w) = fixture::tiny_fixture();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 22);
        let original = eng.infer_batch(&ds.signals).unwrap();
        let mut plan = MaskPlan::from_manifest(&man).unwrap();
        let mut rng = Pcg32::new(5);
        plan.resample(&mut rng);
        eng.swap_masks(&plan).unwrap();
        let perturbed = eng.infer_batch(&ds.signals).unwrap();
        assert_ne!(
            original.samples[Param::F.index()],
            perturbed.samples[Param::F.index()],
            "resampled masks should change predictions"
        );
        let baked = MaskPlan::from_manifest(&man).unwrap();
        eng.swap_masks(&baked).unwrap();
        let restored = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(original.samples[p.index()], restored.samples[p.index()]);
        }
    }

    /// The swap path must stay inside the capacity reserved at
    /// construction — no allocation in steady state, even when the
    /// resampled union grows past the manifest masks' union.
    #[test]
    fn swap_masks_never_reallocates() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let (man, w) = fixture::tiny_fixture();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let mut plan = MaskPlan::from_manifest(&man).unwrap();
        let mut rng = Pcg32::new(9);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 23);
        let mut out = InferOutput::new(man.n_samples, man.batch_infer);
        let sig = eng.alloc_signature();
        for _ in 0..25 {
            plan.resample(&mut rng);
            eng.swap_masks(&plan).unwrap();
            eng.execute_into(&ds.signals, &mut out).unwrap();
            assert_eq!(eng.alloc_signature(), sig, "swap or execute reallocated");
        }
    }

    /// Tentpole gate (ISSUE #8): the tiled worker-pool path must be
    /// **bit-identical** to `threads = 1` for every thread count — the
    /// tile partition is deterministic, lanes share no written element,
    /// and every element is produced by the unchanged per-dot kernel.
    /// Exercised end-to-end (engine outputs) and through hot swaps, on
    /// two fixture shapes including a batch that doesn't divide evenly.
    #[test]
    fn tiled_engine_matches_single_thread_bit_for_bit() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let mut a = InferOutput::new(1, 1);
        let mut b = InferOutput::new(1, 1);
        for (tag, (man, w)) in [
            ("fixture", fixture::tiny_fixture()),
            (
                "fixture-nb17",
                fixture::build(&fixture::FixtureConfig {
                    nb: 17,
                    n_samples: 6,
                    batch_infer: 9,
                    weight_seed: 12,
                    ..Default::default()
                }),
            ),
        ] {
            let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 42);
            let mut serial = NativeEngine::with_batch(&man, &w, man.batch_infer).unwrap();
            for threads in [2usize, 4, 8] {
                let mut tiled =
                    NativeEngine::with_batch_threads(&man, &w, man.batch_infer, threads).unwrap();
                assert_eq!(tiled.threads(), threads);
                let mut plan = MaskPlan::from_manifest(&man).unwrap();
                let mut rng = Pcg32::new(77);
                for round in 0..3 {
                    plan.resample(&mut rng);
                    serial.swap_masks(&plan).unwrap();
                    tiled.swap_masks(&plan).unwrap();
                    serial.execute_into(&ds.signals, &mut a).unwrap();
                    tiled.execute_into(&ds.signals, &mut b).unwrap();
                    for p in Param::ALL {
                        assert_eq!(
                            a.samples[p.index()],
                            b.samples[p.index()],
                            "{tag} t{threads} round {round}: tiled != serial for {p:?}"
                        );
                    }
                }
            }
        }
    }

    /// The bare tiled kernels agree bit-for-bit with their serial
    /// counterparts on ragged shapes (batch < threads included).
    #[test]
    fn tiled_kernels_match_serial_on_ragged_shapes() {
        let (man, w) = fixture::tiny_fixture();
        let eng = NativeEngine::new(&man, &w).unwrap();
        let sn = &eng.subnets[0];
        for threads in [2usize, 4, 8] {
            let pool = WorkerPool::new(threads);
            for batch in [1usize, 3, threads, 13] {
                let ds = synth_dataset(batch, &man.bvalues, 20.0, 51);
                let rows = sn.l1.union_len();
                let mut act_s = vec![0.0f32; rows * batch];
                let mut act_t = vec![7.0f32; rows * batch];
                sn.l1.forward_union(batch, &ds.signals, &mut act_s);
                sn.l1.forward_union_tiled(batch, &ds.signals, &mut act_t, &pool);
                assert_eq!(act_s, act_t, "forward_union t{threads} batch{batch}");
                for s in 0..man.n_samples {
                    let mut out_s = vec![1.0f32; batch * man.nb];
                    let mut out_t = vec![2.0f32; batch * man.nb];
                    sn.l1.scatter_sample(s, batch, &act_s, &mut out_s);
                    sn.l1.scatter_sample_tiled(s, batch, &act_t, &mut out_t, &pool);
                    assert_eq!(out_s, out_t, "scatter s{s} t{threads} batch{batch}");
                }
            }
        }
    }

    /// The pool is part of the engine's steady-state no-allocation
    /// contract: swap + execute at threads=4 never changes the
    /// capacity signature (which now includes the pool's).
    #[test]
    fn tiled_engine_never_reallocates_in_steady_state() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let (man, w) = fixture::tiny_fixture();
        let mut eng = NativeEngine::with_batch_threads(&man, &w, man.batch_infer, 4).unwrap();
        let mut plan = MaskPlan::from_manifest(&man).unwrap();
        let mut rng = Pcg32::new(19);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 24);
        let mut out = InferOutput::new(man.n_samples, man.batch_infer);
        let sig = eng.alloc_signature();
        for _ in 0..20 {
            plan.resample(&mut rng);
            eng.swap_masks(&plan).unwrap();
            eng.execute_into(&ds.signals, &mut out).unwrap();
            assert_eq!(eng.alloc_signature(), sig, "tiled swap or execute reallocated");
        }
    }

    /// The opt-in reordered accumulation mode only changes summation
    /// order inside the masked layers, so end-to-end predictions must
    /// stay within a tight tolerance of the exact mode (and revert
    /// bit-for-bit when switched back).
    #[test]
    fn reordered_mode_stays_within_tolerance_and_reverts() {
        let (man, w) = setup();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 31);
        let exact = eng.infer_batch(&ds.signals).unwrap();
        eng.set_dot_mode(DotMode::Reordered);
        let reordered = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            let (lo, hi) = p.range();
            let tol = ((hi - lo) as f32) * 1e-4 + 1e-6;
            for (a, b) in exact.samples[p.index()]
                .iter()
                .zip(&reordered.samples[p.index()])
            {
                assert!((a - b).abs() <= tol, "{p:?}: |{a} - {b}| > {tol}");
            }
        }
        eng.set_dot_mode(DotMode::Exact);
        let back = eng.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(exact.samples[p.index()], back.samples[p.index()]);
        }
    }

    #[test]
    fn swap_masks_rejects_mismatched_plans() {
        use crate::masks::MaskPlan;
        let (man, w) = fixture::tiny_fixture();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        // wrong width
        let (other, _) = fixture::build(&fixture::FixtureConfig {
            nb: 17,
            ..Default::default()
        });
        assert!(eng.swap_masks(&MaskPlan::from_manifest(&other).unwrap()).is_err());
        // wrong sample count
        assert!(eng.swap_masks(&MaskPlan::all_ones(&man, man.n_samples + 1)).is_err());
    }

    #[test]
    fn union_packing_covers_every_kept_output() {
        let (man, _) = setup();
        for sn in &man.subnets {
            for layer in 1..=2usize {
                let mask = man.mask(sn, layer).unwrap();
                let w_t = vec![0.0f32; man.nb * man.nb];
                let zeros = vec![0.0f32; man.nb];
                let ones = vec![1.0f32; man.nb];
                let l =
                    BlockedMaskedLinear::new(man.nb, &w_t, &zeros, &ones, &zeros, mask);
                assert!(l.union_len() <= man.nb);
                for s in 0..mask.n {
                    assert_eq!(l.kept_len(s), mask.ones(s));
                }
            }
        }
    }

    /// Cross-check vs the python golden outputs: the native engine must
    /// match the AOT executable's numerics (which the goldens capture) to
    /// f32 tolerance.  Needs the python-exported artifacts.
    #[test]
    fn matches_python_golden() {
        let Some((man, w)) = artifact_setup() else { return };
        let gin = crate::util::read_f32_file(&man.file("golden_in").unwrap()).unwrap();
        let gout = crate::util::read_f32_file(&man.file("golden_out").unwrap()).unwrap();
        let mut eng = NativeEngine::new(&man, &w).unwrap();
        let out = eng.infer_batch(&gin).unwrap();
        let plane = man.n_samples * man.batch_infer;
        // golden_out layout: d, dstar, f, s0 planes then recon
        for (pi, p) in Param::ALL.iter().enumerate() {
            let want = &gout[pi * plane..(pi + 1) * plane];
            let got = &out.samples[p.index()];
            let max_diff = got
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // tolerance scaled to the parameter range (D is ~1e-3)
            let (lo, hi) = p.range();
            let tol = ((hi - lo) as f32) * 1e-4 + 1e-6;
            assert!(max_diff < tol, "{p:?} max diff {max_diff} > {tol}");
        }
    }
}
