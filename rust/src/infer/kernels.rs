//! Dot-product kernel dispatch for the f32 blocked masked-GEMM.
//!
//! The scalar bodies here are the **oracles**: [`dot_one_scalar`] /
//! [`dot_rows_scalar`] define the canonical accumulation order the
//! engine has carried since the seed (4 unrolled chains, pairwise
//! combine, scalar tail), and every default-mode backend must reproduce
//! their bits exactly — that is what keeps the engine-level
//! blocked-vs-scalar golden test meaningful under the `simd` feature.
//!
//! Dispatch table (resolved per call, no global state):
//!
//! | mode                  | `simd` + x86_64                   | otherwise        |
//! |-----------------------|-----------------------------------|------------------|
//! | [`DotMode::Exact`]    | SSE2 (bit-exact with the oracle)  | scalar oracle    |
//! | [`DotMode::Reordered`]| AVX2 if detected, else reordered scalar | reordered scalar |
//!
//! `Exact` is the default everywhere.  `Reordered` is the opt-in 8-chain
//! order (`NativeEngine::set_dot_mode`): different bits, golden-tested
//! at a tolerance at the engine level, and bit-identical between its
//! AVX2 and portable implementations (same chain structure, same final
//! reduction — see `util::simd`).

/// Accumulation-order contract for the f32 kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DotMode {
    /// The seed's canonical 4-chain order — bit-exact across backends.
    #[default]
    Exact,
    /// 8-chain order: wider vectors on AVX2, different bits
    /// (tolerance-tested, never dispatched unless opted into).
    Reordered,
}

/// The implementation [`dot_one`]/[`dot_rows`] will run for a mode on
/// this build + CPU — introspection for the runtime-dispatch tests and
/// bench labels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Sse2,
    Avx2,
}

/// Which backend a mode resolves to right now.
pub fn backend(mode: DotMode) -> Backend {
    match mode {
        DotMode::Exact => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            {
                Backend::Sse2
            }
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            {
                Backend::Scalar
            }
        }
        DotMode::Reordered => {
            if crate::util::simd::avx2_available() {
                Backend::Avx2
            } else {
                Backend::Scalar
            }
        }
    }
}

/// The canonical dot-product accumulation order shared by every exact
/// path: 4 independent accumulators over the unrolled body,
/// pairwise-combined, then a scalar tail.  Changing this changes the
/// bits — it is the oracle the SSE2 kernel is golden-tested against.
#[inline]
pub fn dot_one_scalar(nb: usize, x: &[f32], w: &[f32]) -> f32 {
    let mut a0 = 0.0f32;
    let mut a1 = 0.0f32;
    let mut a2 = 0.0f32;
    let mut a3 = 0.0f32;
    let chunks = nb / 4 * 4;
    let mut i = 0;
    while i < chunks {
        a0 += x[i] * w[i];
        a1 += x[i + 1] * w[i + 1];
        a2 += x[i + 2] * w[i + 2];
        a3 += x[i + 3] * w[i + 3];
        i += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for j in chunks..nb {
        acc += x[j] * w[j];
    }
    acc
}

/// Four dot products against one input row, interleaved for ILP.  Each
/// row's accumulation order is identical to [`dot_one_scalar`]
/// (bit-exact); the interleaving only shares the `x` loads across rows.
#[inline]
pub fn dot_rows_scalar(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    let mut a = [[0.0f32; 4]; 4]; // a[row][accumulator]
    let chunks = nb / 4 * 4;
    let mut i = 0;
    while i < chunks {
        let x0 = x[i];
        let x1 = x[i + 1];
        let x2 = x[i + 2];
        let x3 = x[i + 3];
        for r in 0..4 {
            let w = ws[r];
            a[r][0] += x0 * w[i];
            a[r][1] += x1 * w[i + 1];
            a[r][2] += x2 * w[i + 2];
            a[r][3] += x3 * w[i + 3];
        }
        i += 4;
    }
    let mut out = [0.0f32; 4];
    for r in 0..4 {
        let mut acc = (a[r][0] + a[r][1]) + (a[r][2] + a[r][3]);
        for j in chunks..nb {
            acc += x[j] * ws[r][j];
        }
        out[r] = acc;
    }
    out
}

/// Portable reference for the reordered (8-chain) accumulation order.
/// Chain `l` sums `x[8i+l] * w[8i+l]`; the final reduction pairs lanes
/// exactly like the AVX2 kernel's horizontal sum, so the two are
/// bit-identical — keep both in sync or the reordered dispatch test
/// breaks.
pub fn dot_one_reordered_scalar(nb: usize, x: &[f32], w: &[f32]) -> f32 {
    let mut a = [0.0f32; 8];
    let chunks = nb / 8 * 8;
    let mut i = 0;
    while i < chunks {
        for (l, al) in a.iter_mut().enumerate() {
            *al += x[i + l] * w[i + l];
        }
        i += 8;
    }
    let mut acc =
        ((a[0] + a[4]) + (a[1] + a[5])) + ((a[2] + a[6]) + (a[3] + a[7]));
    for j in chunks..nb {
        acc += x[j] * w[j];
    }
    acc
}

/// Four-row variant of [`dot_one_reordered_scalar`] (rows independent).
pub fn dot_rows_reordered_scalar(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    let mut out = [0.0f32; 4];
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot_one_reordered_scalar(nb, x, ws[r]);
    }
    out
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn dot_one_exact(nb: usize, x: &[f32], w: &[f32]) -> f32 {
    crate::util::simd::dot_one_f32(nb, x, w)
}
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
fn dot_one_exact(nb: usize, x: &[f32], w: &[f32]) -> f32 {
    dot_one_scalar(nb, x, w)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn dot_rows_exact(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    crate::util::simd::dot_rows_f32(nb, x, ws)
}
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
fn dot_rows_exact(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    dot_rows_scalar(nb, x, ws)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn dot_one_reordered(nb: usize, x: &[f32], w: &[f32]) -> f32 {
    if crate::util::simd::avx2_available() {
        crate::util::simd::dot_one_f32_reordered(nb, x, w)
    } else {
        dot_one_reordered_scalar(nb, x, w)
    }
}
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
fn dot_one_reordered(nb: usize, x: &[f32], w: &[f32]) -> f32 {
    dot_one_reordered_scalar(nb, x, w)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn dot_rows_reordered(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    if crate::util::simd::avx2_available() {
        crate::util::simd::dot_rows_f32_reordered(nb, x, ws)
    } else {
        dot_rows_reordered_scalar(nb, x, ws)
    }
}
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
fn dot_rows_reordered(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    dot_rows_reordered_scalar(nb, x, ws)
}

/// One dot product under `mode` — the hot-path entry point.
#[inline]
pub fn dot_one(mode: DotMode, nb: usize, x: &[f32], w: &[f32]) -> f32 {
    match mode {
        DotMode::Exact => dot_one_exact(nb, x, w),
        DotMode::Reordered => dot_one_reordered(nb, x, w),
    }
}

/// Four dot products against one input row under `mode`.
#[inline]
pub fn dot_rows(mode: DotMode, nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
    match mode {
        DotMode::Exact => dot_rows_exact(nb, x, ws),
        DotMode::Reordered => dot_rows_reordered(nb, x, ws),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed);
        let x = (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let w = (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        (x, w)
    }

    /// Sizes chosen to exercise remainder tails of both the 4-wide and
    /// 8-wide bodies, plus the empty and single-element edge cases.
    const SIZES: [usize; 12] = [0, 1, 2, 3, 4, 5, 7, 8, 17, 33, 104, 300];

    #[test]
    fn exact_dispatch_is_bit_exact_vs_scalar_oracle() {
        for nb in SIZES {
            let (x, w) = vecs(nb, 10 + nb as u64);
            let got = dot_one(DotMode::Exact, nb, &x, &w);
            let want = dot_one_scalar(nb, &x, &w);
            assert_eq!(got.to_bits(), want.to_bits(), "nb={nb}: {got} vs {want}");
        }
    }

    #[test]
    fn exact_rows_dispatch_is_bit_exact_vs_scalar_oracle() {
        for nb in SIZES {
            let (x, _) = vecs(nb, 20 + nb as u64);
            let (wflat, _) = vecs(nb * 4, 30 + nb as u64);
            let ws = [
                &wflat[..nb],
                &wflat[nb..2 * nb],
                &wflat[2 * nb..3 * nb],
                &wflat[3 * nb..4 * nb],
            ];
            let got = dot_rows(DotMode::Exact, nb, &x, ws);
            let want = dot_rows_scalar(nb, &x, ws);
            for r in 0..4 {
                assert_eq!(got[r].to_bits(), want[r].to_bits(), "nb={nb} row {r}");
            }
        }
    }

    /// The reordered dispatch must be bit-identical to the *reordered
    /// scalar* reference on every backend (the AVX2 kernel mirrors its
    /// chain structure exactly) — so this holds whether or not AVX2 is
    /// present, which is what makes the mode deterministic per input.
    #[test]
    fn reordered_dispatch_is_bit_exact_vs_reordered_scalar() {
        for nb in SIZES {
            let (x, w) = vecs(nb, 40 + nb as u64);
            let got = dot_one(DotMode::Reordered, nb, &x, &w);
            let want = dot_one_reordered_scalar(nb, &x, &w);
            assert_eq!(got.to_bits(), want.to_bits(), "nb={nb}: {got} vs {want}");
            let (wflat, _) = vecs(nb * 4, 50 + nb as u64);
            let ws = [
                &wflat[..nb],
                &wflat[nb..2 * nb],
                &wflat[2 * nb..3 * nb],
                &wflat[3 * nb..4 * nb],
            ];
            let gr = dot_rows(DotMode::Reordered, nb, &x, ws);
            let wr = dot_rows_reordered_scalar(nb, &x, ws);
            for r in 0..4 {
                assert_eq!(gr[r].to_bits(), wr[r].to_bits(), "nb={nb} row {r}");
            }
        }
    }

    /// Reordered vs exact differ only by summation order: same value to
    /// within a few ulps of the accumulated magnitude.
    #[test]
    fn reordered_mode_within_tolerance_of_exact() {
        for nb in SIZES {
            let (x, w) = vecs(nb, 60 + nb as u64);
            let a = dot_one(DotMode::Exact, nb, &x, &w);
            let b = dot_one(DotMode::Reordered, nb, &x, &w);
            let mag: f32 = x.iter().zip(&w).map(|(&p, &q)| (p * q).abs()).sum();
            let tol = 1e-5 * mag + 1e-6;
            assert!((a - b).abs() <= tol, "nb={nb}: |{a} - {b}| > {tol}");
        }
    }

    #[test]
    fn empty_and_single_element_edge_cases() {
        for mode in [DotMode::Exact, DotMode::Reordered] {
            assert_eq!(dot_one(mode, 0, &[], &[]), 0.0);
            assert_eq!(dot_one(mode, 1, &[3.0], &[-0.5]), -1.5);
        }
    }

    /// Runtime-dispatch pin: without the `simd` feature (or off x86_64)
    /// every mode must resolve to the scalar fallback; with it, Exact is
    /// the SSE2 kernel and Reordered follows CPU detection.
    #[test]
    fn dispatch_selects_expected_backend() {
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        {
            assert_eq!(backend(DotMode::Exact), Backend::Scalar);
            assert_eq!(backend(DotMode::Reordered), Backend::Scalar);
            assert!(!crate::util::simd::avx2_available());
        }
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            assert_eq!(backend(DotMode::Exact), Backend::Sse2);
            let want = if crate::util::simd::avx2_available() {
                Backend::Avx2
            } else {
                Backend::Scalar
            };
            assert_eq!(backend(DotMode::Reordered), want);
        }
    }

    #[test]
    fn default_mode_is_exact() {
        assert_eq!(DotMode::default(), DotMode::Exact);
    }
}
