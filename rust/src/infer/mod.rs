//! Inference engines.
//!
//! Two implementations of the same uIVIM-NET forward pass:
//!
//! * [`native`] — pure-Rust f32 engine.  This is the measured "CPU"
//!   baseline of Table II and the numeric oracle the accelerator
//!   simulator is validated against.
//! * `runtime::InferExecutable` — the AOT XLA executable (L2-lowered
//!   model incl. the Pallas kernel) driven through PJRT.
//!
//! Both produce [`InferOutput`]: per-mask-sample parameter predictions,
//! from which the coordinator computes mean (prediction) and std/mean
//! (relative uncertainty).

pub mod native;

use crate::ivim::Param;

/// Raw per-sample inference output for one batch of voxels.
///
/// `samples[p][s * batch + v]` is parameter `p`'s prediction for voxel `v`
/// under mask sample `s` (row-major `[n_samples][batch]`, one plane per
/// IVIM parameter in `Param::ALL` order).
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub n_samples: usize,
    pub batch: usize,
    pub samples: [Vec<f32>; 4],
}

impl InferOutput {
    pub fn new(n_samples: usize, batch: usize) -> Self {
        let plane = vec![0.0f32; n_samples * batch];
        InferOutput {
            n_samples,
            batch,
            samples: [plane.clone(), plane.clone(), plane.clone(), plane],
        }
    }

    #[inline]
    pub fn get(&self, p: Param, sample: usize, voxel: usize) -> f32 {
        self.samples[p.index()][sample * self.batch + voxel]
    }

    #[inline]
    pub fn set(&mut self, p: Param, sample: usize, voxel: usize, v: f32) {
        self.samples[p.index()][sample * self.batch + voxel] = v;
    }

    /// Sample mean for one voxel/parameter — the prediction.
    pub fn mean(&self, p: Param, voxel: usize) -> f64 {
        let plane = &self.samples[p.index()];
        (0..self.n_samples)
            .map(|s| plane[s * self.batch + voxel] as f64)
            .sum::<f64>()
            / self.n_samples as f64
    }

    /// Sample std for one voxel/parameter.
    pub fn std(&self, p: Param, voxel: usize) -> f64 {
        let m = self.mean(p, voxel);
        let plane = &self.samples[p.index()];
        let var = (0..self.n_samples)
            .map(|s| {
                let d = plane[s * self.batch + voxel] as f64 - m;
                d * d
            })
            .sum::<f64>()
            / self.n_samples as f64;
        var.sqrt()
    }

    /// The paper's uncertainty metric: std / mean (relative variation).
    pub fn relative_uncertainty(&self, p: Param, voxel: usize) -> f64 {
        let m = self.mean(p, voxel);
        if m.abs() < 1e-12 {
            0.0
        } else {
            self.std(p, voxel) / m
        }
    }
}

/// Common interface over inference engines so the coordinator, benches
/// and examples can swap CPU / PJRT / accelerator-sim backends.
///
/// NOT `Send`: the xla crate's PJRT handles are `Rc`-based, so engines
/// live on the thread that created them.  The coordinator accordingly
/// takes an engine *factory* and constructs the engine inside its worker
/// thread.
pub trait Engine {
    /// Engine display name (used in reports).
    fn name(&self) -> &str;
    /// Fixed batch size the engine processes per call (PJRT executables
    /// have a static batch; native engines adopt the same for fairness).
    fn batch_size(&self) -> usize;
    /// Run one batch: `signals` is row-major `[batch][nb]`.  Implementors
    /// must accept exactly `batch_size()` voxels.
    fn infer_batch(&mut self, signals: &[f32]) -> anyhow::Result<InferOutput>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_output_stats() {
        let mut out = InferOutput::new(4, 2);
        for (s, v) in [(0usize, 1.0f32), (1, 2.0), (2, 3.0), (3, 4.0)] {
            out.set(Param::F, s, 0, v);
        }
        assert!((out.mean(Param::F, 0) - 2.5).abs() < 1e-9);
        assert!((out.std(Param::F, 0) - (1.25f64).sqrt()).abs() < 1e-9);
        assert!(
            (out.relative_uncertainty(Param::F, 0) - (1.25f64).sqrt() / 2.5).abs() < 1e-9
        );
        // untouched voxel 1 is all zeros -> relative uncertainty defined as 0
        assert_eq!(out.relative_uncertainty(Param::F, 1), 0.0);
    }
}
