//! Inference engines.
//!
//! Five implementations of the same uIVIM-NET forward pass, all behind
//! the [`Engine`] trait and constructed through [`registry`]:
//!
//! * [`native`] — pure-Rust f32 engine.  This is the measured "CPU"
//!   baseline of Table II and the numeric oracle the accelerator
//!   simulator is validated against.
//! * `accel::AccelSimulator` — the Q4.12 cycle-level FPGA simulator.
//! * `bayes::{McDropout, DeepEnsemble}` — uncertainty-method baselines.
//! * `runtime::InferExecutable` — the AOT XLA executable (L2-lowered
//!   model incl. the Pallas kernel) driven through PJRT.
//!
//! All produce [`InferOutput`]: per-mask-sample parameter predictions,
//! from which the coordinator computes mean (prediction) and std/mean
//! (relative uncertainty).  The hot path is two-phase: engines size all
//! internal scratch at construction (the *plan* step) and
//! [`Engine::execute_into`] writes into a caller-provided, recyclable
//! [`InferOutput`] — zero steady-state allocations.

pub mod kernels;
pub mod native;
pub mod registry;

use std::sync::Mutex;

use crate::ivim::Param;

/// Raw per-sample inference output for one batch of voxels.
///
/// `samples[p][s * batch + v]` is parameter `p`'s prediction for voxel `v`
/// under mask sample `s` (row-major `[n_samples][batch]`, one plane per
/// IVIM parameter in `Param::ALL` order).
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub n_samples: usize,
    pub batch: usize,
    pub samples: [Vec<f32>; 4],
}

impl InferOutput {
    pub fn new(n_samples: usize, batch: usize) -> Self {
        let plane = vec![0.0f32; n_samples * batch];
        InferOutput {
            n_samples,
            batch,
            samples: [plane.clone(), plane.clone(), plane.clone(), plane],
        }
    }

    /// Re-shape the buffer to `[n_samples][batch]` reusing its existing
    /// allocations (a no-op beyond zeroing when the shape is unchanged).
    /// This is what lets the coordinator's buffer pool recycle outputs
    /// across batches without allocating on the hot path.
    pub fn reset(&mut self, n_samples: usize, batch: usize) {
        let len = n_samples * batch;
        self.n_samples = n_samples;
        self.batch = batch;
        for plane in &mut self.samples {
            plane.clear();
            plane.resize(len, 0.0);
        }
    }

    #[inline]
    pub fn get(&self, p: Param, sample: usize, voxel: usize) -> f32 {
        self.samples[p.index()][sample * self.batch + voxel]
    }

    #[inline]
    pub fn set(&mut self, p: Param, sample: usize, voxel: usize, v: f32) {
        self.samples[p.index()][sample * self.batch + voxel] = v;
    }

    /// Sample mean for one voxel/parameter — the prediction.
    pub fn mean(&self, p: Param, voxel: usize) -> f64 {
        let plane = &self.samples[p.index()];
        (0..self.n_samples)
            .map(|s| plane[s * self.batch + voxel] as f64)
            .sum::<f64>()
            / self.n_samples as f64
    }

    /// Sample std for one voxel/parameter.
    pub fn std(&self, p: Param, voxel: usize) -> f64 {
        let m = self.mean(p, voxel);
        let plane = &self.samples[p.index()];
        let var = (0..self.n_samples)
            .map(|s| {
                let d = plane[s * self.batch + voxel] as f64 - m;
                d * d
            })
            .sum::<f64>()
            / self.n_samples as f64;
        var.sqrt()
    }

    /// The paper's uncertainty metric: std / mean (relative variation).
    pub fn relative_uncertainty(&self, p: Param, voxel: usize) -> f64 {
        let m = self.mean(p, voxel);
        if m.abs() < 1e-12 {
            0.0
        } else {
            self.std(p, voxel) / m
        }
    }
}

/// Common interface over inference engines so the coordinator, benches
/// and examples can swap CPU / PJRT / accelerator-sim backends.
///
/// The contract is two-phase: construction (via [`registry`]) sizes all
/// internal scratch for a fixed batch shape, and [`Engine::execute_into`]
/// is the steady-state hot path — it writes into a caller-provided
/// [`InferOutput`] and allocates nothing.  [`Engine::infer_batch`] is the
/// allocating convenience wrapper for cold paths and tests.
///
/// NOT `Send`: the xla crate's PJRT handles are `Rc`-based, so engines
/// live on the thread that created them.  The coordinator accordingly
/// takes an engine *factory* and constructs the engine inside its worker
/// thread.
pub trait Engine {
    /// Engine display name (used in reports).
    fn name(&self) -> &str;
    /// Fixed batch size the engine processes per call (PJRT executables
    /// have a static batch; native engines adopt the same for fairness).
    fn batch_size(&self) -> usize;
    /// Mask/ensemble samples per voxel in this engine's output (the N of
    /// the `[N][batch]` output planes) — lets callers size buffers.
    fn n_samples(&self) -> usize;
    /// Run one batch into `out`: `signals` is row-major `[batch][nb]`.
    /// Implementors must accept exactly `batch_size()` voxels, call
    /// `out.reset(self.n_samples(), self.batch_size())` (which reuses the
    /// buffer's allocations), and perform no other steady-state
    /// allocation.
    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()>;
    /// Allocating wrapper over [`Engine::execute_into`] for cold paths.
    fn infer_batch(&mut self, signals: &[f32]) -> anyhow::Result<InferOutput> {
        let mut out = InferOutput::new(self.n_samples(), self.batch_size());
        self.execute_into(signals, &mut out)?;
        Ok(out)
    }
}

/// Recycling pool of [`InferOutput`] buffers.
///
/// The coordinator's shards pull batches from a shared queue, execute
/// into a pooled buffer and return it once the responses are aggregated,
/// so steady-state serving performs no output allocation.  Bounded so a
/// burst cannot hoard memory forever.
pub struct OutputPool {
    slots: Mutex<Vec<InferOutput>>,
    cap: usize,
    /// Fresh `InferOutput` allocations (high-water signature — stable
    /// once serving recycles in steady state; see `VecPool::created`).
    created: std::sync::atomic::AtomicUsize,
}

impl OutputPool {
    /// Pool keeping at most `cap` idle buffers (min 1).
    pub fn new(cap: usize) -> Self {
        OutputPool {
            slots: Mutex::new(Vec::new()),
            cap: cap.max(1),
            created: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// Take a buffer, recycling a returned one when available.  Recycled
    /// buffers come back with **stale shape and contents**: the
    /// [`Engine::execute_into`] contract already reshapes and re-zeroes
    /// via [`InferOutput::reset`], and doing it here too would pay a
    /// second full-plane fill per batch on the hot path.
    pub fn take(&self, n_samples: usize, batch: usize) -> InferOutput {
        let recycled = self.slots.lock().expect("pool lock").pop();
        recycled.unwrap_or_else(|| {
            // relaxed: monotonic high-water counter, telemetry only
            self.created
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            InferOutput::new(n_samples, batch)
        })
    }

    /// Return a buffer to the pool (dropped when the pool is full).
    pub fn put(&self, out: InferOutput) {
        let mut slots = self.slots.lock().expect("pool lock");
        if slots.len() < self.cap {
            slots.push(out);
        }
    }

    /// Idle buffers currently pooled.
    pub fn idle(&self) -> usize {
        self.slots.lock().expect("pool lock").len()
    }

    /// Total fresh allocations so far (high-water mark of buffers in
    /// circulation).
    pub fn created(&self) -> usize {
        // relaxed: telemetry snapshot read, no ordering needed
        self.created.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_output_stats() {
        let mut out = InferOutput::new(4, 2);
        for (s, v) in [(0usize, 1.0f32), (1, 2.0), (2, 3.0), (3, 4.0)] {
            out.set(Param::F, s, 0, v);
        }
        assert!((out.mean(Param::F, 0) - 2.5).abs() < 1e-9);
        assert!((out.std(Param::F, 0) - (1.25f64).sqrt()).abs() < 1e-9);
        assert!(
            (out.relative_uncertainty(Param::F, 0) - (1.25f64).sqrt() / 2.5).abs() < 1e-9
        );
        // untouched voxel 1 is all zeros -> relative uncertainty defined as 0
        assert_eq!(out.relative_uncertainty(Param::F, 1), 0.0);
    }

    #[test]
    fn reset_reshapes_and_zeroes_without_losing_capacity() {
        let mut out = InferOutput::new(4, 8);
        out.set(Param::D, 3, 7, 1.5);
        let cap_before = out.samples[0].capacity();
        out.reset(2, 4);
        assert_eq!(out.n_samples, 2);
        assert_eq!(out.batch, 4);
        for p in Param::ALL {
            assert_eq!(out.samples[p.index()].len(), 8);
            assert!(out.samples[p.index()].iter().all(|&v| v == 0.0));
        }
        // shrinking never reallocates
        assert_eq!(out.samples[0].capacity(), cap_before);
    }

    #[test]
    fn pool_recycles_and_bounds_idle_buffers() {
        let pool = OutputPool::new(2);
        let a = pool.take(4, 8);
        let b = pool.take(4, 8);
        let c = pool.take(4, 8);
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.created(), 3, "three fresh buffers so far");
        pool.put(a);
        pool.put(b);
        pool.put(c); // beyond cap: dropped
        assert_eq!(pool.idle(), 2);
        // recycled buffers keep their stale shape (engines reset them);
        // a single reset reshapes, re-zeroes, and keeps the allocation
        let mut d = pool.take(2, 2);
        assert_eq!(pool.idle(), 1);
        assert_eq!(d.n_samples, 4, "take() must not pay a redundant reset");
        let cap = d.samples[0].capacity();
        d.reset(2, 2);
        assert_eq!((d.n_samples, d.batch), (2, 2));
        assert_eq!(d.samples[0].capacity(), cap);
        d.set(Param::F, 0, 0, 3.0);
        pool.put(d);
        let mut e = pool.take(2, 2);
        e.reset(2, 2);
        assert_eq!(e.get(Param::F, 0, 0), 0.0, "reset() re-zeroes recycled buffers");
        assert_eq!(pool.created(), 3, "recycled takes never move the high-water mark");
    }
}
