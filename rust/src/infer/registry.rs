//! Engine registry — the single construction path for every inference
//! backend.
//!
//! CLI (`repro serve --engine accel`), coordinator shards, experiments
//! and benches all resolve engines by name here instead of hand-rolling
//! their own construction:
//!
//! | name         | backend                                        |
//! |--------------|------------------------------------------------|
//! | `native`     | [`crate::infer::native::NativeEngine`]         |
//! | `accel`      | [`crate::accel::AccelSimulator`] (batch-level) |
//! | `mc-dropout` | [`crate::bayes::McDropout`]                    |
//! | `ensemble`   | [`crate::bayes::DeepEnsemble`]                 |
//! | `pjrt`       | `runtime::InferExecutable` (needs the `pjrt`   |
//! |              | feature; errors cleanly on the stub build)     |
//!
//! Construction is the *plan* phase of the two-phase execution API: the
//! returned engine has all scratch sized for its batch shape, and
//! [`super::Engine::execute_into`] is the zero-allocation hot path.
//!
//! Engines are not `Send` (PJRT handles are `Rc`-based), so the
//! coordinator takes [`factory`], which captures owned manifest/weights
//! and builds the engine inside each shard's own thread.

use super::Engine;
use crate::model::{Manifest, Weights};

/// A backend name resolvable by the registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineName {
    Native,
    Accel,
    McDropout,
    Ensemble,
    Pjrt,
}

impl EngineName {
    /// Every registered backend, in help-text order.
    pub const ALL: [EngineName; 5] = [
        EngineName::Native,
        EngineName::Accel,
        EngineName::McDropout,
        EngineName::Ensemble,
        EngineName::Pjrt,
    ];

    /// The registry name (what `--engine` accepts).
    pub fn as_str(&self) -> &'static str {
        match self {
            EngineName::Native => "native",
            EngineName::Accel => "accel",
            EngineName::McDropout => "mc-dropout",
            EngineName::Ensemble => "ensemble",
            EngineName::Pjrt => "pjrt",
        }
    }

    /// Parse a registry name.
    pub fn parse(s: &str) -> anyhow::Result<EngineName> {
        EngineName::ALL
            .into_iter()
            .find(|n| n.as_str() == s)
            .ok_or_else(|| {
                anyhow::anyhow!("unknown engine '{s}' (expected one of: {})", names_help())
            })
    }
}

/// `"native|accel|mc-dropout|ensemble|pjrt"` — for CLI help text.
pub fn names_help() -> String {
    EngineName::ALL
        .iter()
        .map(|n| n.as_str())
        .collect::<Vec<_>>()
        .join("|")
}

/// Construction options shared by every backend.  `Default` follows the
/// manifest: batch = `batch_infer`, ensemble members = `n_samples`.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// Batch-size override (`None` = the manifest's `batch_infer`).  The
    /// PJRT executable has a static batch and rejects overrides.
    pub batch: Option<usize>,
    /// Seed for the stochastic backends (mc-dropout mask stream,
    /// ensemble member initialisation).
    pub seed: u64,
    /// Ensemble member count (`None` = the manifest's `n_samples`).
    pub members: Option<usize>,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            batch: None,
            seed: 42,
            members: None,
        }
    }
}

/// Build an engine by registry name.  This is the only construction path
/// for backends — everything else (CLI, coordinator, experiments,
/// benches) goes through here.
pub fn build(
    name: EngineName,
    man: &Manifest,
    weights: &Weights,
    opts: &EngineOpts,
) -> anyhow::Result<Box<dyn Engine>> {
    let batch = opts.batch.unwrap_or(man.batch_infer);
    anyhow::ensure!(batch > 0, "engine batch must be positive");
    Ok(match name {
        EngineName::Native => Box::new(crate::infer::native::NativeEngine::with_batch(
            man, weights, batch,
        )?),
        EngineName::Accel => Box::new(crate::accel::AccelSimulator::new(
            man,
            weights,
            crate::accel::AccelConfig {
                batch,
                ..Default::default()
            },
            crate::accel::Scheme::BatchLevel,
        )?),
        EngineName::McDropout => Box::new(crate::bayes::McDropout::with_batch(
            man, weights, batch, opts.seed,
        )),
        EngineName::Ensemble => Box::new(crate::bayes::DeepEnsemble::init_random_with_batch(
            man,
            opts.members.unwrap_or(man.n_samples),
            opts.seed,
            batch,
        )?),
        EngineName::Pjrt => {
            anyhow::ensure!(
                batch == man.batch_infer,
                "pjrt executable has a static batch of {} (asked for {batch})",
                man.batch_infer
            );
            let rt = crate::runtime::Runtime::cpu()?;
            Box::new(crate::runtime::InferExecutable::load(&rt, man, weights)?)
        }
    })
}

/// A `Send + Sync` engine factory for the coordinator's shards: captures
/// owned manifest/weights and constructs the engine inside the calling
/// thread (engines themselves are not `Send`).
pub fn factory(
    name: EngineName,
    man: Manifest,
    weights: Weights,
    opts: EngineOpts,
) -> impl Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync + 'static {
    move || build(name, &man, &weights, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::testing::fixture;

    #[test]
    fn parse_roundtrips_every_name() {
        for n in EngineName::ALL {
            assert_eq!(EngineName::parse(n.as_str()).unwrap(), n);
        }
        assert!(EngineName::parse("gpu").is_err());
        assert!(names_help().contains("mc-dropout"));
    }

    #[test]
    fn builds_every_non_pjrt_backend_on_the_fixture() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 23);
        for name in [
            EngineName::Native,
            EngineName::Accel,
            EngineName::McDropout,
            EngineName::Ensemble,
        ] {
            let mut eng = build(name, &man, &w, &EngineOpts::default()).unwrap();
            assert_eq!(eng.batch_size(), man.batch_infer, "{name:?}");
            assert!(eng.n_samples() >= 1, "{name:?}");
            let out = eng.infer_batch(&ds.signals).unwrap();
            assert_eq!(out.batch, man.batch_infer, "{name:?}");
            assert_eq!(out.n_samples, eng.n_samples(), "{name:?}");
        }
    }

    #[test]
    fn batch_override_applies() {
        let (man, w) = fixture::tiny_fixture();
        let opts = EngineOpts {
            batch: Some(3),
            ..Default::default()
        };
        let mut eng = build(EngineName::Native, &man, &w, &opts).unwrap();
        assert_eq!(eng.batch_size(), 3);
        let ds = synth_dataset(3, &man.bvalues, 20.0, 24);
        assert!(eng.infer_batch(&ds.signals).is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_unavailable_errors_cleanly() {
        let (man, w) = fixture::tiny_fixture();
        let e = build(EngineName::Pjrt, &man, &w, &EngineOpts::default()).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    #[test]
    fn factory_is_send_and_builds() {
        let (man, w) = fixture::tiny_fixture();
        let f = factory(EngineName::Native, man, w, EngineOpts::default());
        let handle = std::thread::spawn(move || f().map(|e| e.batch_size()));
        let batch = handle.join().unwrap().unwrap();
        assert!(batch > 0);
    }
}
