//! Engine registry — the single construction path for every inference
//! backend.
//!
//! A [`Registry`] is a *value* holding named engine factories.  The
//! built-in table ([`Registry::builtin`]) covers the five in-tree
//! backends; downstream code can [`Registry::register`] its own
//! factories without editing this file (ROADMAP: user-registerable
//! engines).  The process-wide default instance
//! ([`default_registry`]) backs the module-level [`build`] /
//! [`factory`] conveniences the CLI, coordinator, experiments and
//! benches use:
//!
//! | name         | backend                                        |
//! |--------------|------------------------------------------------|
//! | `native`     | [`crate::infer::native::NativeEngine`]         |
//! | `accel`      | [`crate::accel::AccelSimulator`] (batch-level) |
//! | `accel-mc`   | [`crate::bayes::AccelMcDropout`] (random masks |
//! |              | per pass over the Q4.12 simulator's mask swap) |
//! | `mc-dropout` | [`crate::bayes::McDropout`]                    |
//! | `mc-dropout-ll` | [`crate::bayes::McDropout`] last-layer-only |
//! |              | head (only layer-2 masks redrawn per pass)     |
//! | `ensemble`   | [`crate::bayes::DeepEnsemble`]                 |
//! | `pjrt`       | `runtime::InferExecutable` (needs the `pjrt`   |
//! |              | feature; errors cleanly on the stub build)     |
//!
//! The MC heads (`mc-dropout`, `mc-dropout-ll`, `accel-mc`) honour
//! [`EngineOpts::overlap`]: when set they are wrapped in
//! [`crate::bayes::pipeline::Pipelined`], which prepares pass *i+1*'s
//! mask plan on a background worker while pass *i* executes —
//! bit-identical outputs, swap-only critical path.  `native` and the
//! f32 MC heads honour [`EngineOpts::threads`] for batch-tiled GEMM
//! lanes (also bit-exact vs one thread).
//!
//! Construction is the *plan* phase of the two-phase execution API: the
//! returned engine has all scratch sized for its batch shape, and
//! [`super::Engine::execute_into`] is the zero-allocation hot path.
//!
//! Engines are not `Send` (PJRT handles are `Rc`-based), so the
//! coordinator takes [`factory`], which captures owned manifest/weights
//! and builds the engine inside each shard's own thread.

use std::sync::{Arc, OnceLock};

use super::Engine;
use crate::model::{Manifest, Weights};

/// Construction options shared by every backend.  `Default` follows the
/// manifest: batch = `batch_infer`, ensemble members = `n_samples`.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// Batch-size override (`None` = the manifest's `batch_infer`).  The
    /// PJRT executable has a static batch and rejects overrides.
    pub batch: Option<usize>,
    /// Seed for the stochastic backends (mc-dropout mask stream,
    /// ensemble member initialisation).
    pub seed: u64,
    /// Ensemble member count (`None` = the manifest's `n_samples`).
    pub members: Option<usize>,
    /// Worker lanes for the batch-tiled f32 kernels (`native`,
    /// `mc-dropout`, `mc-dropout-ll`).  Clamped to >= 1; 1 spawns no
    /// threads and is the exact serial path.  Outputs are bit-identical
    /// for every value (the tiling contract).
    pub threads: usize,
    /// Overlap mask preparation with execution on the MC heads
    /// (`mc-dropout`, `mc-dropout-ll`, `accel-mc`): a persistent
    /// background worker redraws pass *i+1*'s plan while pass *i*
    /// executes.  Bit-identical to the serial heads.
    pub overlap: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            batch: None,
            seed: 42,
            members: None,
            threads: 1,
            overlap: false,
        }
    }
}

/// A named engine factory: manifest + weights + options in, boxed engine
/// out.  `Send + Sync` so coordinator shards can build in-thread.
pub type BuildFn =
    dyn Fn(&Manifest, &Weights, &EngineOpts) -> anyhow::Result<Box<dyn Engine>> + Send + Sync;

struct Entry {
    name: String,
    build: Arc<BuildFn>,
}

/// A registry of named engine factories.  Insertion order is preserved
/// (it is the `--engine` help order); names are unique.
#[derive(Default)]
pub struct Registry {
    entries: Vec<Entry>,
}

impl Registry {
    /// An empty registry (register your own factories).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The built-in backend table (see the module docs).
    pub fn builtin() -> Registry {
        let mut r = Registry::new();
        r.register("native", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            Ok(Box::new(crate::infer::native::NativeEngine::with_batch_threads(
                man,
                weights,
                batch,
                opts.threads.max(1),
            )?))
        })
        .expect("builtin name");
        r.register("accel", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            Ok(Box::new(crate::accel::AccelSimulator::new(
                man,
                weights,
                crate::accel::AccelConfig {
                    batch,
                    ..Default::default()
                },
                crate::accel::Scheme::BatchLevel,
            )?))
        })
        .expect("builtin name");
        r.register("accel-mc", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            if opts.overlap {
                return Ok(Box::new(crate::bayes::pipeline::accel_mc(
                    man, weights, batch, opts.seed,
                )?));
            }
            Ok(Box::new(crate::bayes::AccelMcDropout::with_batch(
                man, weights, batch, opts.seed,
            )?))
        })
        .expect("builtin name");
        r.register("mc-dropout", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            let threads = opts.threads.max(1);
            if opts.overlap {
                return Ok(Box::new(crate::bayes::pipeline::mc_dropout(
                    man, weights, batch, opts.seed, threads,
                )?));
            }
            Ok(Box::new(crate::bayes::McDropout::with_batch_threads(
                man, weights, batch, opts.seed, threads,
            )?))
        })
        .expect("builtin name");
        r.register("mc-dropout-ll", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            let threads = opts.threads.max(1);
            if opts.overlap {
                return Ok(Box::new(crate::bayes::pipeline::mc_dropout_last_layer(
                    man, weights, batch, opts.seed, threads,
                )?));
            }
            Ok(Box::new(crate::bayes::McDropout::last_layer_with_batch(
                man, weights, batch, opts.seed, threads,
            )?))
        })
        .expect("builtin name");
        r.register("ensemble", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            Ok(Box::new(crate::bayes::DeepEnsemble::init_random_with_batch(
                man,
                opts.members.unwrap_or(man.n_samples),
                opts.seed,
                batch,
            )?))
        })
        .expect("builtin name");
        r.register("pjrt", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer);
            anyhow::ensure!(
                batch == man.batch_infer,
                "pjrt executable has a static batch of {} (asked for {batch})",
                man.batch_infer
            );
            // one cached PJRT client shared across builds (per process
            // on the stub, per thread under the real feature — see
            // `runtime::shared_cpu`): repeated builds (one engine per
            // SNR level in `snr_sweep`, one per coordinator shard's
            // thread) stop re-loading the plugin each time (ROADMAP)
            let rt = crate::runtime::shared_cpu()?;
            Ok(Box::new(crate::runtime::InferExecutable::load(
                &rt, man, weights,
            )?))
        })
        .expect("builtin name");
        r
    }

    /// Register a factory under `name`.  Errors on an empty or duplicate
    /// name (names are the CLI/config contract; silent overrides would
    /// make `--engine` ambiguous).
    pub fn register<F>(&mut self, name: &str, build: F) -> anyhow::Result<()>
    where
        F: Fn(&Manifest, &Weights, &EngineOpts) -> anyhow::Result<Box<dyn Engine>>
            + Send
            + Sync
            + 'static,
    {
        anyhow::ensure!(!name.is_empty(), "engine name must be non-empty");
        anyhow::ensure!(!self.contains(name), "engine '{name}' is already registered");
        self.entries.push(Entry {
            name: name.to_string(),
            build: Arc::new(build),
        });
        Ok(())
    }

    /// Is `name` registered?
    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    /// Validate a name without building — same error (and name list) as
    /// [`Registry::build`], for callers that want to fail fast before
    /// doing expensive work (e.g. resolving weights).
    pub fn validate(&self, name: &str) -> anyhow::Result<()> {
        self.resolve(name).map(|_| ())
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// `"native|accel|…"` — for CLI help text.
    pub fn names_help(&self) -> String {
        self.names().join("|")
    }

    fn resolve(&self, name: &str) -> anyhow::Result<Arc<BuildFn>> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| Arc::clone(&e.build))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown engine '{name}' (expected one of: {})",
                    self.names_help()
                )
            })
    }

    /// Build an engine by name.
    pub fn build(
        &self,
        name: &str,
        man: &Manifest,
        weights: &Weights,
        opts: &EngineOpts,
    ) -> anyhow::Result<Box<dyn Engine>> {
        let batch = opts.batch.unwrap_or(man.batch_infer);
        anyhow::ensure!(batch > 0, "engine batch must be positive");
        let build = self.resolve(name)?;
        build.as_ref()(man, weights, opts)
    }

    /// A `Send + Sync` engine factory for the coordinator's shards:
    /// resolves `name` eagerly (unknown names fail here, not inside a
    /// worker thread), captures owned manifest/weights, and constructs
    /// the engine inside the calling thread (engines are not `Send`).
    pub fn factory(
        &self,
        name: &str,
        man: Manifest,
        weights: Weights,
        opts: EngineOpts,
    ) -> anyhow::Result<impl Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync + 'static> {
        let batch = opts.batch.unwrap_or(man.batch_infer);
        anyhow::ensure!(batch > 0, "engine batch must be positive");
        let build = self.resolve(name)?;
        Ok(move || build.as_ref()(&man, &weights, &opts))
    }
}

/// The process-wide default registry (the built-in table).  Code that
/// wants additional engines builds its own [`Registry`] value and
/// registers into it.
pub fn default_registry() -> &'static Registry {
    static DEFAULT: OnceLock<Registry> = OnceLock::new();
    DEFAULT.get_or_init(Registry::builtin)
}

/// Build an engine from the default registry (the common path for CLI,
/// experiments and benches).
pub fn build(
    name: &str,
    man: &Manifest,
    weights: &Weights,
    opts: &EngineOpts,
) -> anyhow::Result<Box<dyn Engine>> {
    default_registry().build(name, man, weights, opts)
}

/// Shard factory from the default registry (see [`Registry::factory`]).
pub fn factory(
    name: &str,
    man: Manifest,
    weights: Weights,
    opts: EngineOpts,
) -> anyhow::Result<impl Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync + 'static> {
    default_registry().factory(name, man, weights, opts)
}

/// `"native|accel|accel-mc|mc-dropout|ensemble|pjrt"` — for CLI help text.
pub fn names_help() -> String {
    default_registry().names_help()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::testing::fixture;

    #[test]
    fn builtin_registers_every_backend_name() {
        let r = Registry::builtin();
        assert_eq!(
            r.names(),
            vec![
                "native",
                "accel",
                "accel-mc",
                "mc-dropout",
                "mc-dropout-ll",
                "ensemble",
                "pjrt"
            ]
        );
        assert!(r.contains("native") && !r.contains("gpu"));
        assert!(names_help().contains("mc-dropout-ll"));
        assert!(names_help().contains("accel-mc"));
    }

    #[test]
    fn unknown_engine_error_lists_names() {
        let (man, w) = fixture::tiny_fixture();
        let e = build("gpu", &man, &w, &EngineOpts::default()).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("unknown engine 'gpu'"), "{msg}");
        assert!(msg.contains("native") && msg.contains("ensemble"), "{msg}");
        assert!(default_registry().factory("gpu", man, w, EngineOpts::default()).is_err());
    }

    #[test]
    fn builds_every_non_pjrt_backend_on_the_fixture() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 23);
        for name in [
            "native",
            "accel",
            "accel-mc",
            "mc-dropout",
            "mc-dropout-ll",
            "ensemble",
        ] {
            let mut eng = build(name, &man, &w, &EngineOpts::default()).unwrap();
            assert_eq!(eng.batch_size(), man.batch_infer, "{name}");
            assert!(eng.n_samples() >= 1, "{name}");
            let out = eng.infer_batch(&ds.signals).unwrap();
            assert_eq!(out.batch, man.batch_infer, "{name}");
            assert_eq!(out.n_samples, eng.n_samples(), "{name}");
        }
    }

    #[test]
    fn batch_override_applies() {
        let (man, w) = fixture::tiny_fixture();
        let opts = EngineOpts {
            batch: Some(3),
            ..Default::default()
        };
        let mut eng = build("native", &man, &w, &opts).unwrap();
        assert_eq!(eng.batch_size(), 3);
        let ds = synth_dataset(3, &man.bvalues, 20.0, 24);
        assert!(eng.infer_batch(&ds.signals).is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_unavailable_errors_cleanly() {
        let (man, w) = fixture::tiny_fixture();
        let e = build("pjrt", &man, &w, &EngineOpts::default()).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    /// The ROADMAP per-call client churn fix: two `build("pjrt")` calls
    /// share **one** client construction through the
    /// `runtime::shared_cpu()` cache (process-wide on this stub build;
    /// per-thread success-only under the real feature).  On the stub
    /// runtime both builds fail (cleanly), but the cache still records
    /// exactly one construction attempt — the sharing contract itself.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_builds_share_one_cached_client_construction() {
        let (man, w) = fixture::tiny_fixture();
        assert!(build("pjrt", &man, &w, &EngineOpts::default()).is_err());
        let after_first = crate::runtime::shared_cpu_attempts();
        assert_eq!(after_first, 1, "first build constructs the client once");
        assert!(build("pjrt", &man, &w, &EngineOpts::default()).is_err());
        assert_eq!(
            crate::runtime::shared_cpu_attempts(),
            1,
            "second build reuses the cached client (slot), constructing nothing"
        );
    }

    /// `threads`/`overlap` route through the registry and stay
    /// bit-identical to the default serial build (the ISSUE #8 CLI
    /// contract: the flags are pure perf knobs).
    #[test]
    fn threads_and_overlap_opts_are_bit_exact_through_the_registry() {
        let (man, w) = fixture::tiny_fixture();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 25);
        for name in ["mc-dropout", "mc-dropout-ll", "accel-mc"] {
            let mut serial = build(name, &man, &w, &EngineOpts::default()).unwrap();
            let opts = EngineOpts {
                threads: if name == "accel-mc" { 1 } else { 4 },
                overlap: true,
                ..Default::default()
            };
            let mut piped = build(name, &man, &w, &opts).unwrap();
            assert!(piped.name().contains("overlap"), "{name} -> {}", piped.name());
            for pass in 0..3 {
                let a = serial.infer_batch(&ds.signals).unwrap();
                let b = piped.infer_batch(&ds.signals).unwrap();
                assert_eq!(a.samples, b.samples, "{name} pass {pass}");
            }
        }
    }

    #[test]
    fn factory_is_send_and_builds() {
        let (man, w) = fixture::tiny_fixture();
        let f = factory("native", man, w, EngineOpts::default()).unwrap();
        let handle = std::thread::spawn(move || f().map(|e| e.batch_size()));
        let batch = handle.join().unwrap().unwrap();
        assert!(batch > 0);
    }

    /// The ROADMAP item this registry closes: downstream code plugs an
    /// engine in by value, without editing this file.
    #[test]
    fn user_registered_factory_builds_and_rejects_duplicates() {
        let mut r = Registry::builtin();
        r.register("native-half-batch", |man: &Manifest, weights: &Weights, opts: &EngineOpts| {
            let batch = opts.batch.unwrap_or(man.batch_infer).div_ceil(2);
            Ok(Box::new(crate::infer::native::NativeEngine::with_batch(
                man, weights, batch,
            )?))
        })
        .unwrap();
        assert!(r.contains("native-half-batch"));
        assert!(
            r.register("native", |_, _, _| anyhow::bail!("dup")).is_err(),
            "duplicate names must be rejected"
        );
        let (man, w) = fixture::tiny_fixture();
        let eng = r.build("native-half-batch", &man, &w, &EngineOpts::default()).unwrap();
        assert_eq!(eng.batch_size(), man.batch_infer.div_ceil(2));
        // the default registry is unaffected by the private value
        assert!(!default_registry().contains("native-half-batch"));
    }
}
