//! Levenberg–Marquardt nonlinear least squares on the IVIM equation —
//! the full classical fit (slow but accurate on clean data).
//!
//! Minimises `sum_i (S0*(f*e^{-b_i D*} + (1-f)*e^{-b_i D}) - s_i)^2` over
//! (D, D*, f, S0) with the analytic Jacobian, damping `lambda` adapted by
//! the standard gain-ratio rule, and parameters clamped to the clinical
//! ranges after each accepted step.

use super::{clamp_to_ranges, segmented_fit, FitResult};
use crate::ivim::{signal, IvimParams};

const MAX_ITERS: usize = 200;
const GTOL: f64 = 1e-12;

fn residuals(bvals: &[f64], sig: &[f64], p: &IvimParams, out: &mut [f64]) {
    for (i, (&b, &s)) in bvals.iter().zip(sig).enumerate() {
        out[i] = signal(b, p) - s;
    }
}

/// Jacobian row for one b-value: d(model)/d(D, D*, f, S0).
fn jac_row(b: f64, p: &IvimParams) -> [f64; 4] {
    let ed = (-b * p.d).exp();
    let eds = (-b * p.dstar).exp();
    [
        p.s0 * (1.0 - p.f) * (-b) * ed,  // dD
        p.s0 * p.f * (-b) * eds,         // dD*
        p.s0 * (eds - ed),               // df
        p.f * eds + (1.0 - p.f) * ed,    // dS0
    ]
}

fn ssr(r: &[f64]) -> f64 {
    r.iter().map(|x| x * x).sum()
}

/// Solve the 4x4 system `(JtJ + lambda diag(JtJ)) dx = -Jtr` by Gaussian
/// elimination with partial pivoting.  Returns None if singular.
fn solve4(mut a: [[f64; 4]; 4], mut b: [f64; 4]) -> Option<[f64; 4]> {
    for col in 0..4 {
        // pivot
        let mut piv = col;
        for r in (col + 1)..4 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-300 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let inv = 1.0 / a[col][col];
        for r in 0..4 {
            if r == col {
                continue;
            }
            let factor = a[r][col] * inv;
            for c in col..4 {
                a[r][c] -= factor * a[col][c];
            }
            b[r] -= factor * b[col];
        }
    }
    Some([
        b[0] / a[0][0],
        b[1] / a[1][1],
        b[2] / a[2][2],
        b[3] / a[3][3],
    ])
}

/// Full LM fit, seeded by the segmented fit.
pub fn levenberg_marquardt(bvals: &[f64], sig: &[f64]) -> FitResult {
    assert_eq!(bvals.len(), sig.len());
    let n = bvals.len();
    let seed = segmented_fit(bvals, sig, 200.0);
    let mut p = seed.params;
    let mut r = vec![0.0; n];
    residuals(bvals, sig, &p, &mut r);
    let mut cur_ssr = ssr(&r);
    let mut lambda = 1e-3;
    let mut converged = false;
    let mut iters = 0;

    for it in 0..MAX_ITERS {
        iters = it + 1;
        // Build JtJ and Jtr.
        let mut jtj = [[0.0f64; 4]; 4];
        let mut jtr = [0.0f64; 4];
        for (i, &b) in bvals.iter().enumerate() {
            let row = jac_row(b, &p);
            for x in 0..4 {
                jtr[x] += row[x] * r[i];
                for y in 0..4 {
                    jtj[x][y] += row[x] * row[y];
                }
            }
        }
        let gmax = jtr.iter().map(|v| v.abs()).fold(0.0, f64::max);
        if gmax < GTOL {
            converged = true;
            break;
        }
        // Damped normal equations.
        let mut a = jtj;
        for x in 0..4 {
            a[x][x] += lambda * jtj[x][x].max(1e-12);
        }
        let neg_jtr = [-jtr[0], -jtr[1], -jtr[2], -jtr[3]];
        let Some(dx) = solve4(a, neg_jtr) else {
            lambda *= 10.0;
            continue;
        };
        let cand = clamp_to_ranges(IvimParams {
            d: p.d + dx[0],
            dstar: p.dstar + dx[1],
            f: p.f + dx[2],
            s0: p.s0 + dx[3],
        });
        let mut r_cand = vec![0.0; n];
        residuals(bvals, sig, &cand, &mut r_cand);
        let cand_ssr = ssr(&r_cand);
        if cand_ssr < cur_ssr {
            // accept
            let improvement = (cur_ssr - cand_ssr) / cur_ssr.max(1e-300);
            p = cand;
            r = r_cand;
            cur_ssr = cand_ssr;
            lambda = (lambda * 0.3).max(1e-12);
            if improvement < 1e-10 {
                converged = true;
                break;
            }
        } else {
            lambda = (lambda * 10.0).min(1e12);
            if lambda >= 1e12 {
                converged = true; // stuck at a (possibly local) minimum
                break;
            }
        }
    }

    FitResult {
        params: p,
        ssr: cur_ssr,
        iterations: iters,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::{bvalues_tiny, signal_curve};
    use crate::util::rng::Pcg32;

    #[test]
    fn recovers_noiseless_parameters_tightly() {
        let truth = IvimParams {
            d: 0.0012,
            dstar: 0.07,
            f: 0.3,
            s0: 1.05,
        };
        let b = bvalues_tiny();
        let sig = signal_curve(&b, &truth);
        let fit = levenberg_marquardt(&b, &sig);
        assert!(fit.ssr < 1e-10, "ssr {}", fit.ssr);
        assert!((fit.params.d - truth.d).abs() < 5e-5, "{:?}", fit.params);
        assert!((fit.params.dstar - truth.dstar).abs() < 5e-3);
        assert!((fit.params.f - truth.f).abs() < 0.01);
        assert!((fit.params.s0 - truth.s0).abs() < 0.01);
    }

    #[test]
    fn beats_or_matches_segmented_ssr() {
        let b = bvalues_tiny();
        let mut rng = Pcg32::new(4);
        for _ in 0..20 {
            let truth = crate::ivim::synth::draw_params(&mut rng);
            let mut sig = signal_curve(&b, &truth);
            // mild noise
            for s in sig.iter_mut() {
                *s += 0.01 * rng.normal();
            }
            let seg = segmented_fit(&b, &sig, 200.0);
            let lm = levenberg_marquardt(&b, &sig);
            assert!(
                lm.ssr <= seg.ssr + 1e-9,
                "LM ssr {} worse than segmented {}",
                lm.ssr,
                seg.ssr
            );
        }
    }

    #[test]
    fn solve4_inverts_identity() {
        let a = [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 2.0, 0.0, 0.0],
            [0.0, 0.0, 4.0, 0.0],
            [0.0, 0.0, 0.0, 8.0],
        ];
        let x = solve4(a, [1.0, 2.0, 4.0, 8.0]).unwrap();
        for v in x {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn solve4_rejects_singular() {
        let a = [[0.0; 4]; 4];
        assert!(solve4(a, [1.0; 4]).is_none());
    }

    #[test]
    fn jacobian_matches_finite_difference() {
        let p = IvimParams {
            d: 0.002,
            dstar: 0.05,
            f: 0.3,
            s0: 1.0,
        };
        let b = 120.0;
        let row = jac_row(b, &p);
        let eps = 1e-7;
        let base = signal(b, &p);
        let fd = [
            (signal(b, &IvimParams { d: p.d + eps, ..p }) - base) / eps,
            (signal(b, &IvimParams { dstar: p.dstar + eps, ..p }) - base) / eps,
            (signal(b, &IvimParams { f: p.f + eps, ..p }) - base) / eps,
            (signal(b, &IvimParams { s0: p.s0 + eps, ..p }) - base) / eps,
        ];
        for (a, n) in row.iter().zip(fd) {
            // relative tolerance: forward differences truncate at
            // ~eps/2 * f'' which is large for the steep dD direction
            let tol = 1e-4 + 1e-5 * a.abs();
            assert!((a - n).abs() < tol, "{a} vs {n}");
        }
    }
}
