//! Segmented (two-step) IVIM fit — the fastest classical baseline.
//!
//! Standard protocol (e.g. Gurney-Champion et al. 2018 [43]):
//!
//! 1. **Diffusion regime**: for b >= `b_thresh` (default 200 s/mm^2), the
//!    perfusion term has decayed, so `ln S = ln(S0*(1-f)) - b*D` — a
//!    log-linear least-squares line gives D and the intercept `A`.
//! 2. **Perfusion fraction**: `f = 1 - A / S(0)` using the measured b=0
//!    signal (here the normalised signal ≈ 1).
//! 3. **Pseudo-diffusion**: fit D* by 1-D golden-section search on the
//!    residual SSR of the full model with D, f, S0 fixed.

use super::{clamp_to_ranges, FitResult};
use crate::ivim::{signal, IvimParams};

/// Log-linear least squares of `ln s = a + b x`; returns (a, b).
fn loglin(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (sy / n, 0.0);
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    (intercept, slope)
}

fn ssr_of(bvals: &[f64], sig: &[f64], p: &IvimParams) -> f64 {
    bvals
        .iter()
        .zip(sig)
        .map(|(&b, &s)| {
            let r = signal(b, p) - s;
            r * r
        })
        .sum()
}

/// Two-step segmented fit on a normalised voxel (`sig[i] = S(b_i)/S(0)`).
pub fn segmented_fit(bvals: &[f64], sig: &[f64], b_thresh: f64) -> FitResult {
    assert_eq!(bvals.len(), sig.len());

    // Step 1: high-b log-linear fit for D.
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (&b, &s) in bvals.iter().zip(sig) {
        if b >= b_thresh && s > 1e-6 {
            xs.push(b);
            ys.push(s.ln());
        }
    }
    let (mut d, mut a) = (1.0e-3, (1.0f64 - 0.2).ln());
    if xs.len() >= 2 {
        let (intercept, slope) = loglin(&xs, &ys);
        d = (-slope).max(0.0);
        a = intercept;
    }

    // Step 2: f from the b->0 intercept of the diffusion line.
    let s0_meas = sig
        .iter()
        .zip(bvals)
        .filter(|(_, &b)| b == 0.0)
        .map(|(&s, _)| s)
        .fold(0.0, f64::max)
        .max(1e-6);
    let f = (1.0 - a.exp() / s0_meas).clamp(0.0, 0.7);

    // Step 3: golden-section search for D* on the full-model SSR.
    let base = IvimParams {
        d,
        dstar: 0.05,
        f,
        s0: s0_meas,
    };
    let mut lo = 0.005;
    let mut hi = 0.2;
    let phi = 0.5 * (5.0f64.sqrt() - 1.0);
    let mut iters = 0;
    let eval = |dstar: f64| {
        ssr_of(
            bvals,
            sig,
            &IvimParams {
                dstar,
                ..base
            },
        )
    };
    let mut c = hi - phi * (hi - lo);
    let mut dd = lo + phi * (hi - lo);
    let mut fc = eval(c);
    let mut fd = eval(dd);
    while (hi - lo) > 1e-5 && iters < 200 {
        if fc < fd {
            hi = dd;
            dd = c;
            fd = fc;
            c = hi - phi * (hi - lo);
            fc = eval(c);
        } else {
            lo = c;
            c = dd;
            fc = fd;
            dd = lo + phi * (hi - lo);
            fd = eval(dd);
        }
        iters += 1;
    }
    let dstar = 0.5 * (lo + hi);

    let params = clamp_to_ranges(IvimParams {
        d,
        dstar,
        f,
        s0: s0_meas,
    });
    FitResult {
        params,
        ssr: ssr_of(bvals, sig, &params),
        iterations: iters,
        converged: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::{bvalues_tiny, signal_curve};

    #[test]
    fn recovers_noiseless_parameters() {
        let truth = IvimParams {
            d: 0.0015,
            dstar: 0.06,
            f: 0.25,
            s0: 1.0,
        };
        let b = bvalues_tiny();
        let sig = signal_curve(&b, &truth);
        let fit = segmented_fit(&b, &sig, 200.0);
        assert!((fit.params.d - truth.d).abs() < 3e-4, "D {:?}", fit.params);
        assert!((fit.params.f - truth.f).abs() < 0.08, "f {:?}", fit.params);
        assert!(
            (fit.params.dstar - truth.dstar).abs() < 0.04,
            "D* {:?}",
            fit.params
        );
    }

    #[test]
    fn handles_pure_diffusion() {
        let truth = IvimParams {
            d: 0.002,
            dstar: 0.05,
            f: 0.0,
            s0: 1.0,
        };
        let b = bvalues_tiny();
        let sig = signal_curve(&b, &truth);
        let fit = segmented_fit(&b, &sig, 200.0);
        assert!((fit.params.d - truth.d).abs() < 2e-4);
        assert!(fit.params.f < 0.05);
    }

    #[test]
    fn loglin_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 - 0.5 * x).collect();
        let (a, b) = loglin(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((b + 0.5).abs() < 1e-12);
    }

    #[test]
    fn ssr_is_zero_on_truth() {
        let truth = IvimParams {
            d: 0.001,
            dstar: 0.08,
            f: 0.3,
            s0: 1.1,
        };
        let b = bvalues_tiny();
        let sig = signal_curve(&b, &truth);
        assert!(ssr_of(&b, &sig, &truth) < 1e-20);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let b = [0.0, 10.0];
        let sig = [1.0, 0.9];
        let fit = segmented_fit(&b, &sig, 200.0); // no high-b points at all
        assert!(fit.params.d >= 0.0);
        let zeros = [0.0, 0.0];
        let _ = segmented_fit(&b, &zeros, 0.0);
    }
}
