//! Classical IVIM fitting baselines (paper §II-B: "least squares method
//! and Bayesian inference … suffer from long fitting times and poor
//! repeatability").
//!
//! Two fitters from the IVIM literature:
//!
//! * [`segmented`] — the standard two-step fit: estimate D from the
//!   high-b regime (mono-exponential tail, log-linear least squares),
//!   then f from the b→0 intercept, then D* from the residual
//!   low-b signal.
//! * [`levenberg_marquardt`] — full nonlinear least squares on eq. (1)
//!   with analytic Jacobian, seeded by the segmented fit.
//!
//! These are the "long fitting time" baselines the neural approach is
//! compared against in fitting-speed benches, and a sanity oracle on
//! noiseless data.

pub mod lm;
pub mod segmented;

pub use lm::levenberg_marquardt;
pub use segmented::segmented_fit;

use crate::ivim::IvimParams;

/// Result of a classical fit.
#[derive(Debug, Clone, Copy)]
pub struct FitResult {
    pub params: IvimParams,
    /// Final sum of squared residuals.
    pub ssr: f64,
    /// Iterations used (0 for closed-form stages).
    pub iterations: usize,
    pub converged: bool,
}

/// Clamp fitted parameters into the clinical ranges (fits on noisy voxels
/// can wander; the network's sigmoid conversion enforces the same bounds).
pub fn clamp_to_ranges(p: IvimParams) -> IvimParams {
    use crate::ivim::Param;
    IvimParams {
        d: p.d.clamp(Param::D.range().0, Param::D.range().1),
        dstar: p.dstar.clamp(Param::DStar.range().0, Param::DStar.range().1),
        f: p.f.clamp(Param::F.range().0, Param::F.range().1),
        s0: p.s0.clamp(Param::S0.range().0, Param::S0.range().1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::Param;

    #[test]
    fn clamp_bounds() {
        let wild = IvimParams {
            d: 1.0,
            dstar: -5.0,
            f: 2.0,
            s0: 0.0,
        };
        let c = clamp_to_ranges(wild);
        for p in Param::ALL {
            let (lo, hi) = p.range();
            assert!(c.get(p) >= lo && c.get(p) <= hi);
        }
    }
}
