//! The algorithm-hardware co-optimization flow (paper Fig. 1, §III) as a
//! first-class, runnable driver:
//!
//! * **Phase 1 — Preparation**: uncertainty requirements + synthetic
//!   scenario set (SNR levels).
//! * **Phase 2 — Algorithm**: convert to a mask-based BayesNN (the
//!   Masksembles hyper-parameters), train on the synthetic scenarios,
//!   evaluate the uncertainty requirements; iterate if unsatisfied.
//!   Includes the paper's grid search over dropout rate (0.1–0.9 →
//!   Masksembles scale) and sampling number {4, 8, 16, 32, 64}.
//! * **Phase 3 — Hardware**: latency/resource modelling (eq. 2 + VU13P
//!   budgets) and selection of the PE parallelism meeting the real-time
//!   requirement.
//!
//! The flow runs entirely on the `tiny`/`paper` artifacts (Phase-2
//! training uses the AOT train-step; candidate mask configurations that
//! differ from the baked ones are evaluated on the native engine, which
//! accepts any `MaskSet`).

pub mod gridsearch;

use crate::accel::dse::{best_fitting, sweep};
use crate::accel::Scheme;
use crate::experiments::fig67::{run_batches, snr_sweep, SnrRow, SweepConfig};
use crate::ivim::{Param, PAPER_SNRS};
use crate::model::{Manifest, Weights};
use crate::runtime::Runtime;
use crate::train::{train, TrainConfig};

/// Phase-1 uncertainty requirements: per-parameter caps on the mean
/// relative uncertainty at a reference SNR, plus the monotonicity
/// requirement ("output uncertainty shrinks with less noise", §IV).
#[derive(Debug, Clone)]
pub struct UncertaintyRequirements {
    /// (SNR at which the caps apply, cap per parameter in Param order).
    pub reference_snr: f64,
    pub max_relative: [f64; 4],
    /// Require uncertainty to be non-increasing from the noisiest to the
    /// cleanest scenario.
    pub monotone_in_snr: bool,
}

impl Default for UncertaintyRequirements {
    fn default() -> Self {
        UncertaintyRequirements {
            reference_snr: 20.0,
            // generous defaults shaped like Fig. 7's measured ranges
            max_relative: [0.5, 0.6, 0.5, 0.1],
            monotone_in_snr: true,
        }
    }
}

/// Result of the Phase-2 evaluation against the requirements.
#[derive(Debug, Clone)]
pub struct Phase2Report {
    pub rows: Vec<SnrRow>,
    pub satisfied: bool,
    pub violations: Vec<String>,
    pub final_loss: f32,
}

/// Result of the Phase-3 hardware mapping.
#[derive(Debug, Clone)]
pub struct Phase3Report {
    pub chosen_pe: usize,
    pub batch_ms: f64,
    pub power_w: f64,
    pub meets_realtime: bool,
    pub dsp_pct: f64,
}

/// Full-flow outcome.
#[derive(Debug, Clone)]
pub struct FlowReport {
    pub phase2: Phase2Report,
    pub phase3: Option<Phase3Report>,
}

/// Evaluate the trained model against the Phase-1 requirements.
pub fn evaluate_requirements(
    man: &Manifest,
    weights: &Weights,
    req: &UncertaintyRequirements,
    n_voxels: usize,
) -> anyhow::Result<Phase2Report> {
    let cfg = SweepConfig {
        n_voxels,
        snrs: PAPER_SNRS.to_vec(),
        engine: "native".into(),
        seed: 23,
    };
    let rows = snr_sweep(man, weights, &cfg)?;
    let mut violations = Vec::new();

    // caps at the reference SNR
    if let Some(r) = rows.iter().find(|r| r.snr == req.reference_snr) {
        for p in Param::ALL {
            let got = r.uncertainty[p.index()];
            let cap = req.max_relative[p.index()];
            if got > cap {
                violations.push(format!(
                    "{} relative uncertainty {:.3} exceeds cap {:.3} at SNR {}",
                    p.name(),
                    got,
                    cap,
                    req.reference_snr
                ));
            }
        }
    } else {
        violations.push(format!("reference SNR {} not evaluated", req.reference_snr));
    }

    // monotonicity over the SNR grid (averaged over parameters; per-point
    // noise tolerance 5%)
    if req.monotone_in_snr {
        let mean_unc: Vec<f64> = rows
            .iter()
            .map(|r| r.uncertainty.iter().sum::<f64>() / 4.0)
            .collect();
        for w in mean_unc.windows(2) {
            if w[1] > w[0] * 1.05 {
                violations.push(format!(
                    "uncertainty not monotone in SNR: {:.4} -> {:.4}",
                    w[0], w[1]
                ));
                break;
            }
        }
    }

    Ok(Phase2Report {
        satisfied: violations.is_empty(),
        violations,
        rows,
        final_loss: f32::NAN,
    })
}

/// Run the whole Fig.-1 flow on a variant: Phase-2 training + evaluation,
/// then Phase-3 hardware mapping if the requirements hold.
pub fn run_flow(
    man: &Manifest,
    rt: &Runtime,
    req: &UncertaintyRequirements,
    train_steps: usize,
    realtime_ms: f64,
) -> anyhow::Result<FlowReport> {
    // Phase 2: train on the synthetic scenarios.
    let trained = train(
        rt,
        man,
        &TrainConfig {
            steps: train_steps,
            snr: req.reference_snr,
            seed: 1,
            log_every: 0,
            early_stop_rel: 0.0,
        },
        None,
    )?;
    let mut phase2 = evaluate_requirements(man, &trained.final_weights, req, 800)?;
    phase2.final_loss = trained.final_loss();

    // Phase 3 only proceeds when Phase 2 is satisfied (Fig. 1's decision
    // diamond; otherwise the caller iterates with new hyper-parameters).
    let phase3 = if phase2.satisfied {
        let ds = crate::ivim::synth::synth_dataset(man.batch_infer, &man.bvalues, 20.0, 29);
        let points = sweep(
            man,
            &trained.final_weights,
            &[4, 8, 16, 32, 64],
            Scheme::BatchLevel,
            &ds.signals,
        )?;
        best_fitting(&points).map(|best| Phase3Report {
            chosen_pe: best.n_pe,
            batch_ms: best.batch_ms,
            power_w: best.power.watts,
            meets_realtime: best.batch_ms <= realtime_ms,
            dsp_pct: best.usage.dsp_pct(),
        })
    } else {
        None
    };

    Ok(FlowReport { phase2, phase3 })
}

/// Quick uncertainty-quality score used by the grid search: mean
/// calibration correlation across parameters minus a penalty for
/// violating monotonicity (higher is better).
pub fn uncertainty_quality(rows: &[SnrRow]) -> f64 {
    let cal: f64 = rows
        .iter()
        .flat_map(|r| r.calibration.iter())
        .sum::<f64>()
        / (rows.len() * 4) as f64;
    let mean_unc: Vec<f64> = rows
        .iter()
        .map(|r| r.uncertainty.iter().sum::<f64>() / 4.0)
        .collect();
    let mono_violation = mean_unc
        .windows(2)
        .filter(|w| w[1] > w[0] * 1.05)
        .count() as f64;
    cal - 0.25 * mono_violation
}

/// Helper shared with the grid search: evaluate a weights/mask setup on
/// one dataset, returning mean relative uncertainty across parameters.
pub fn quick_uncertainty(
    man: &Manifest,
    weights: &Weights,
    snr: f64,
    n_voxels: usize,
) -> anyhow::Result<f64> {
    let ds = crate::ivim::synth::synth_dataset(n_voxels, &man.bvalues, snr, 31);
    let mut eng = crate::infer::registry::build(
        "native",
        man,
        weights,
        &crate::infer::registry::EngineOpts::default(),
    )?;
    let outs = run_batches(eng.as_mut(), &ds)?;
    Ok(crate::metrics::mean_relative_uncertainty_all(&outs, ds.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    #[test]
    fn flow_runs_end_to_end_tiny() {
        let Ok(man) = load_manifest("tiny") else { return };
        let Ok(rt) = Runtime::cpu() else { return };
        let req = UncertaintyRequirements::default();
        let rep = run_flow(&man, &rt, &req, 150, 0.8).unwrap();
        assert_eq!(rep.phase2.rows.len(), 5);
        assert!(rep.phase2.final_loss.is_finite());
        if rep.phase2.satisfied {
            let p3 = rep.phase3.expect("phase 3 runs when phase 2 passes");
            assert!(p3.chosen_pe >= 4);
            assert!(p3.batch_ms > 0.0);
        } else {
            assert!(!rep.phase2.violations.is_empty());
            assert!(rep.phase3.is_none());
        }
    }

    #[test]
    fn impossible_requirements_are_flagged() {
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let req = UncertaintyRequirements {
            max_relative: [1e-6; 4], // unattainable caps
            ..Default::default()
        };
        let rep = evaluate_requirements(&man, &w, &req, 200).unwrap();
        assert!(!rep.satisfied);
        assert!(!rep.violations.is_empty());
    }

    #[test]
    fn quality_score_penalises_non_monotone() {
        let mk = |unc: [f64; 3]| -> Vec<SnrRow> {
            unc.iter()
                .enumerate()
                .map(|(i, &u)| SnrRow {
                    snr: [5.0, 20.0, 50.0][i],
                    rmse: [0.0; 4],
                    uncertainty: [u; 4],
                    calibration: [0.5; 4],
                })
                .collect()
        };
        let good = uncertainty_quality(&mk([0.5, 0.3, 0.2]));
        let bad = uncertainty_quality(&mk([0.2, 0.5, 0.3]));
        assert!(good > bad);
    }
}
