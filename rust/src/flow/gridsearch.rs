//! Phase-2 hyper-parameter grid search (paper §III: "A grid search is
//! conducted for the dropout rate ranging from 0.1 to 0.9 … and the
//! sampling number is varied among 4, 8, 16, 32, 64").
//!
//! Masksembles' dropout rate maps to the scale: keep fraction ≈ 1/scale,
//! so rate r → scale 1/(1−r).  Candidate mask configurations are
//! evaluated on the **native engine** (which accepts arbitrary mask
//! sets — the AOT artifacts bake one configuration, so the search runs
//! on the substrate and the winner is what `aot.py` would be re-run
//! with).  Hardware cost comes from the accelerator models, giving the
//! algorithm/hardware trade-off table the co-design flow picks from.

use crate::accel::latency::predict_batch_ms;
use crate::accel::resource::AccelConfig;
use crate::accel::Scheme;
use crate::masks::for_width;
use crate::model::{Manifest, Weights};

/// One grid-search candidate's evaluation.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub dropout_rate: f64,
    pub scale: f64,
    pub n_samples: usize,
    /// Mean relative uncertainty on the reference scenario.
    pub mean_uncertainty: f64,
    /// Mask-zero-skipped weight memory (words, all masked layers).
    pub weight_words: usize,
    /// Predicted batch latency on the default accelerator (ms).
    pub batch_ms: f64,
    pub mask_overlap: f64,
}

/// The paper's grid (a trimmed default; pass custom grids for the full
/// 9 x 5 sweep).
pub fn paper_grid() -> (Vec<f64>, Vec<usize>) {
    (
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
        vec![4, 8, 16, 32, 64],
    )
}

/// Build a manifest clone whose masks follow a (rate, n) candidate.
pub fn candidate_manifest(
    man: &Manifest,
    rate: f64,
    n_samples: usize,
    seed: u64,
) -> anyhow::Result<Manifest> {
    anyhow::ensure!((0.0..1.0).contains(&rate), "rate must be in [0,1)");
    let scale = 1.0 / (1.0 - rate);
    let mut cand = man.clone();
    cand.n_samples = n_samples;
    for (si, sn) in man.subnets.iter().enumerate() {
        for layer in 1..=2usize {
            let m = for_width(
                man.nb,
                n_samples,
                scale,
                seed + 1000 * si as u64 + layer as u64,
            )?;
            cand.masks.insert(format!("{sn}.mask{layer}"), m);
        }
    }
    Ok(cand)
}

/// Run the grid search against one weights set and reference SNR.
pub fn grid_search(
    man: &Manifest,
    weights: &Weights,
    rates: &[f64],
    sample_counts: &[usize],
    snr: f64,
    n_voxels: usize,
) -> anyhow::Result<Vec<GridPoint>> {
    let mut out = Vec::with_capacity(rates.len() * sample_counts.len());
    for &rate in rates {
        for &n in sample_counts {
            let cand = candidate_manifest(man, rate, n, 4242)?;
            let unc = super::quick_uncertainty(&cand, weights, snr, n_voxels)?;
            let weight_words: usize = cand
                .masks
                .values()
                .map(|m| {
                    crate::accel::memory::WeightStore::from_mask(cand.nb, m)
                        .total_skipped_words()
                })
                .sum();
            let cfg = AccelConfig {
                batch: cand.batch_infer,
                ..Default::default()
            };
            let batch_ms = predict_batch_ms(&cand, &cfg, Scheme::BatchLevel);
            let overlap = cand
                .masks
                .values()
                .map(|m| m.overlap())
                .sum::<f64>()
                / cand.masks.len() as f64;
            out.push(GridPoint {
                dropout_rate: rate,
                scale: 1.0 / (1.0 - rate),
                n_samples: n,
                mean_uncertainty: unc,
                weight_words,
                batch_ms,
                mask_overlap: overlap,
            });
        }
    }
    Ok(out)
}

/// Render the search as a table.
pub fn render(points: &[GridPoint]) -> String {
    use crate::metrics::report::Table;
    let mut t = Table::new(&[
        "rate", "scale", "N", "mean unc", "overlap", "weight words", "ms/batch",
    ]);
    for p in points {
        t.row(&[
            format!("{:.1}", p.dropout_rate),
            format!("{:.2}", p.scale),
            p.n_samples.to_string(),
            format!("{:.4}", p.mean_uncertainty),
            format!("{:.3}", p.mask_overlap),
            p.weight_words.to_string(),
            format!("{:.4}", p.batch_ms),
        ]);
    }
    t.to_text()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    #[test]
    fn candidate_masks_follow_rate_and_n() {
        let Ok(man) = load_manifest("tiny") else { return };
        let cand = candidate_manifest(&man, 0.5, 8, 1).unwrap();
        assert_eq!(cand.n_samples, 8);
        let m = cand.mask("d", 1).unwrap();
        assert_eq!(m.n, 8);
        // rate 0.5 -> ~half the neurons kept
        let keep = m.ones(0) as f64 / man.nb as f64;
        assert!(keep > 0.3 && keep < 0.75, "keep {keep}");
        assert!(candidate_manifest(&man, 1.5, 4, 1).is_err());
    }

    #[test]
    fn grid_trends_hold() {
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let pts = grid_search(&man, &w, &[0.2, 0.7], &[4], 20.0, 128).unwrap();
        assert_eq!(pts.len(), 2);
        // heavier dropout -> fewer stored weights, more mask diversity
        let (lo, hi) = (&pts[0], &pts[1]);
        assert!(hi.weight_words < lo.weight_words);
        assert!(hi.mask_overlap < lo.mask_overlap + 1e-9);
        // latency falls with fewer kept outputs (mask-zero skipping)
        assert!(hi.batch_ms <= lo.batch_ms + 1e-9);
    }

    #[test]
    fn more_samples_cost_latency() {
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let pts = grid_search(&man, &w, &[0.5], &[4, 8], 20.0, 64).unwrap();
        assert!(pts[1].batch_ms > pts[0].batch_ms);
    }
}
