//! # uIVIM-NET — mask-based Bayesian MRI uncertainty estimation
//!
//! Production reproduction of *"Accelerating MRI Uncertainty Estimation
//! with Mask-based Bayesian Neural Network"* (Zhang et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas masked-linear kernel.
//! * **L2** (`python/compile/model.py`) — uIVIM-NET forward/train-step in
//!   JAX, AOT-lowered to HLO text once at build time.
//! * **L3** (this crate) — the serving coordinator, PJRT runtime, cycle-
//!   level FPGA accelerator simulator, classical baselines, metrics, CLI.
//!
//! See [rust/DESIGN.md](../DESIGN.md) for the system inventory — the
//! L1/L2/L3 layering, the [`infer::Engine`] trait contract, the sharded
//! coordinator architecture — and the experiment index that maps every
//! table/figure of the paper onto modules and bench targets.

pub mod accel;
pub mod analysis;
pub mod bayes;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fit;
pub mod flow;
pub mod infer;
pub mod ivim;
pub mod masks;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod testing;
pub mod train;
pub mod util;
pub mod volume;
