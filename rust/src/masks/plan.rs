//! Mask *lifecycle* state — the hot-swappable side of the Masksembles
//! machinery.
//!
//! `generate_masks`/`for_width` (mod.rs) answer "which masks exist"; a
//! [`MaskPlan`] answers "which masks is the engine running **right
//! now**".  The plan owns, per (subnet, layer), the mask bits plus the
//! precomputed index lists the blocked engine consumes (per-sample kept
//! lists and the ascending union of kept columns), and can regenerate
//! all of it **in place**:
//!
//! * [`MaskPlan::resample`] redraws every row as an independent
//!   Bernoulli mask (the MC-Dropout sampler) without allocating — every
//!   `Vec` is cleared and refilled inside capacity reserved at
//!   construction, and the union is maintained *incrementally* via
//!   per-column use counts (only flipped bits touch the counts).
//! * `NativeEngine::swap_masks(&plan)` (infer/native.rs) then re-packs
//!   its union weight block from the plan, again in place — masks become
//!   runtime state instead of construction-time configuration, which is
//!   exactly the economy the paper's fixed-mask hardware exploits and
//!   what makes the runtime-sampler overhead measurable in isolation.
//!
//! Everything here is deterministic in the caller-supplied [`Pcg32`].

use super::MaskSet;
use crate::model::Manifest;
use crate::util::rng::Pcg32;

/// One layer's live mask state: bits plus the derived index lists, all
/// resampleable in place.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    width: usize,
    n: usize,
    /// Row-major `[n][width]`, values 0/1.
    bits: Vec<u8>,
    /// Per sample: ascending kept column indices.
    kept: Vec<Vec<u32>>,
    /// Ascending column indices kept by at least one sample.
    union: Vec<u32>,
    /// Per column: number of samples keeping it (incremental union —
    /// membership is `use_count[c] > 0`).
    use_count: Vec<u32>,
}

impl LayerPlan {
    /// Plan seeded from an existing mask set (capacity reserved for any
    /// later resample: kept/union can grow up to `width`).
    pub fn from_mask_set(m: &MaskSet) -> LayerPlan {
        let mut p = LayerPlan {
            width: m.width,
            n: m.n,
            bits: m.bits.clone(),
            kept: (0..m.n).map(|_| Vec::with_capacity(m.width)).collect(),
            union: Vec::with_capacity(m.width),
            use_count: vec![0u32; m.width],
        };
        p.rebuild_from_bits();
        p
    }

    /// All-ones (dense) plan: every sample keeps every column.
    pub fn all_ones(width: usize, n: usize) -> LayerPlan {
        LayerPlan::from_mask_set(&MaskSet {
            n,
            width,
            bits: vec![1u8; n * width],
        })
    }

    pub fn width(&self) -> usize {
        self.width
    }
    pub fn n(&self) -> usize {
        self.n
    }
    /// Ascending union of kept columns.
    pub fn union(&self) -> &[u32] {
        &self.union
    }
    /// Sample `s`'s ascending kept columns.
    pub fn kept(&self, s: usize) -> &[u32] {
        &self.kept[s]
    }
    /// All per-sample kept lists (`[n]` slices of column indices).
    pub fn kept_lists(&self) -> &[Vec<u32>] {
        &self.kept
    }

    /// Snapshot the bits as a standalone [`MaskSet`] (allocates — cold
    /// path: manifest round-trips, golden tests).
    pub fn to_mask_set(&self) -> MaskSet {
        MaskSet {
            n: self.n,
            width: self.width,
            bits: self.bits.clone(),
        }
    }

    /// Recompute counts, kept lists and union from `bits` (construction
    /// path; `resample` maintains the counts incrementally instead).
    fn rebuild_from_bits(&mut self) {
        self.use_count.fill(0);
        for s in 0..self.n {
            let row = &self.bits[s * self.width..(s + 1) * self.width];
            for (c, &b) in row.iter().enumerate() {
                self.use_count[c] += b as u32;
            }
        }
        self.refresh_index_lists();
    }

    /// Refill kept/union in place from bits + counts (no allocation:
    /// capacities were reserved at construction).
    fn refresh_index_lists(&mut self) {
        for s in 0..self.n {
            let row = &self.bits[s * self.width..(s + 1) * self.width];
            let ks = &mut self.kept[s];
            ks.clear();
            ks.extend(
                row.iter()
                    .enumerate()
                    .filter(|(_, &b)| b == 1)
                    .map(|(c, _)| c as u32),
            );
        }
        self.union.clear();
        self.union.extend(
            self.use_count
                .iter()
                .enumerate()
                .filter(|(_, &cnt)| cnt > 0)
                .map(|(c, _)| c as u32),
        );
    }

    /// Redraw every row as an independent Bernoulli(`keep_prob`) mask,
    /// in place.  All-zero rows are redrawn (a dead layer would silently
    /// zero the subnet); the union's use counts are updated only for the
    /// bits that actually flipped.  Redraws are bounded: a degenerate
    /// `keep_prob` (~0) falls back to forcing one uniformly-drawn kept
    /// column instead of looping forever.
    fn resample(&mut self, keep_prob: f64, rng: &mut Pcg32) {
        const MAX_REDRAWS: usize = 64;
        for s in 0..self.n {
            for attempt in 0.. {
                let row = &mut self.bits[s * self.width..(s + 1) * self.width];
                let mut ones = 0usize;
                for (c, bit) in row.iter_mut().enumerate() {
                    let new = u8::from(rng.next_f64() < keep_prob);
                    ones += new as usize;
                    if new != *bit {
                        // incremental union update: only flipped bits
                        // touch the per-column counts
                        if new == 1 {
                            self.use_count[c] += 1;
                        } else {
                            self.use_count[c] -= 1;
                        }
                        *bit = new;
                    }
                }
                if ones > 0 {
                    break;
                }
                if attempt >= MAX_REDRAWS {
                    let c = rng.below(self.width as u32) as usize;
                    self.bits[s * self.width + c] = 1;
                    self.use_count[c] += 1;
                    break;
                }
            }
        }
        self.refresh_index_lists();
    }

    /// Capacities of every owned buffer — the no-allocation witness for
    /// the steady-state tests (stable across `resample` calls).
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = vec![self.bits.capacity(), self.union.capacity(), self.use_count.capacity()];
        sig.extend(self.kept.iter().map(|k| k.capacity()));
        sig
    }
}

/// The full model's live mask state: one [`LayerPlan`] per
/// (subnet, masked layer), in manifest subnet order.
///
/// Layer keys follow the manifest convention: subnets are indexed in
/// `Manifest::subnets` order and masked layers are `1` and `2`.
#[derive(Debug, Clone)]
pub struct MaskPlan {
    nb: usize,
    n_samples: usize,
    keep_prob: f64,
    subnets: Vec<String>,
    /// `layers[si * 2 + (layer - 1)]`.
    layers: Vec<LayerPlan>,
}

impl MaskPlan {
    /// Plan seeded with the manifest's fixed Masksembles masks
    /// (`keep_prob` defaults to the Masksembles keep fraction
    /// `1 / scale`, so a later `resample` matches the paper's density).
    pub fn from_manifest(man: &Manifest) -> anyhow::Result<MaskPlan> {
        let mut layers = Vec::with_capacity(man.subnets.len() * 2);
        for sn in &man.subnets {
            for layer in 1..=2usize {
                let m = man
                    .mask(sn, layer)
                    .ok_or_else(|| anyhow::anyhow!("manifest missing mask {sn}.mask{layer}"))?;
                layers.push(LayerPlan::from_mask_set(m));
            }
        }
        Ok(MaskPlan {
            nb: man.nb,
            n_samples: man.n_samples,
            keep_prob: (1.0 / man.scale).min(1.0),
            subnets: man.subnets.clone(),
            layers,
        })
    }

    /// Dense plan: `n_samples` all-ones masks per layer (Deep-Ensemble
    /// members run every neuron).
    pub fn all_ones(man: &Manifest, n_samples: usize) -> MaskPlan {
        MaskPlan {
            nb: man.nb,
            n_samples,
            keep_prob: 1.0,
            subnets: man.subnets.clone(),
            layers: (0..man.subnets.len() * 2)
                .map(|_| LayerPlan::all_ones(man.nb, n_samples))
                .collect(),
        }
    }

    /// Random Bernoulli plan at `keep_prob` (the MC-Dropout sampler's
    /// initial draw) — `all_ones` shape plus one `resample`.
    pub fn bernoulli(man: &Manifest, keep_prob: f64, rng: &mut Pcg32) -> MaskPlan {
        let mut p = MaskPlan::all_ones(man, man.n_samples);
        p.keep_prob = keep_prob.clamp(0.0, 1.0);
        p.resample(rng);
        p
    }

    pub fn nb(&self) -> usize {
        self.nb
    }
    pub fn n_samples(&self) -> usize {
        self.n_samples
    }
    pub fn keep_prob(&self) -> f64 {
        self.keep_prob
    }

    /// Re-target the Bernoulli keep rate used by subsequent
    /// [`MaskPlan::resample`] calls (clamped to [0, 1]) — how a DSE
    /// mask-rate sweep walks the density axis on one live plan.
    pub fn set_keep_prob(&mut self, p: f64) {
        self.keep_prob = p.clamp(0.0, 1.0);
    }
    pub fn subnets(&self) -> &[String] {
        &self.subnets
    }

    /// Layer plan for subnet index `si`, masked layer `layer` (1 or 2).
    pub fn layer(&self, si: usize, layer: usize) -> &LayerPlan {
        assert!(layer == 1 || layer == 2, "masked layers are 1 and 2");
        &self.layers[si * 2 + (layer - 1)]
    }

    /// Layer plan looked up by subnet *name* (what the engine uses —
    /// robust to subnet ordering).
    pub fn layer_for(&self, subnet: &str, layer: usize) -> Option<&LayerPlan> {
        let si = self.subnets.iter().position(|s| s == subnet)?;
        Some(self.layer(si, layer))
    }

    /// Redraw every layer's masks in place (no allocation).
    pub fn resample(&mut self, rng: &mut Pcg32) {
        self.resample_layer_range(1, 2, rng);
    }

    /// Redraw only masked layers `first_layer..=last_layer` (each in
    /// {1, 2}) across every subnet, in place.  RNG draws happen in the
    /// same (subnet-major, layer-minor) order as [`MaskPlan::resample`],
    /// so `resample_layer_range(1, 2, rng)` consumes the stream
    /// identically to a full resample — full-range callers stay
    /// bit-compatible.  The narrow ranges are what the last-layer-only
    /// MC sampler and the pipeline's partial-redraw path use: untouched
    /// layers keep their bits, index lists and counts bit-identical.
    pub fn resample_layer_range(&mut self, first_layer: usize, last_layer: usize, rng: &mut Pcg32) {
        assert!(
            (1..=2).contains(&first_layer) && first_layer <= last_layer && last_layer <= 2,
            "masked layers are 1 and 2 (got {first_layer}..={last_layer})"
        );
        let kp = self.keep_prob;
        for si in 0..self.subnets.len() {
            for layer in first_layer..=last_layer {
                self.layers[si * 2 + (layer - 1)].resample(kp, rng);
            }
        }
    }

    /// Redraw a *shadow* plan in place, using `self` only as the shape
    /// and keep-rate template — the double-buffering primitive.
    ///
    /// Because [`LayerPlan::resample`] overwrites every bit from fresh
    /// Bernoulli draws and its RNG consumption depends only on the
    /// drawn bits (never the prior mask state), the result is a pure
    /// function of `rng`'s incoming state: resampling a stale shadow
    /// clone yields masks bit-identical to resampling the live plan
    /// (see `resample_is_independent_of_prior_bits`).  That is what
    /// lets a background worker prepare pass *i+1*'s plan while pass
    /// *i* executes, with the serial engine as a bit-exact oracle.
    pub fn resample_into(&self, target: &mut MaskPlan, rng: &mut Pcg32) -> anyhow::Result<()> {
        anyhow::ensure!(
            target.nb == self.nb && target.n_samples == self.n_samples,
            "shadow plan is {}x{}, live plan is {}x{}",
            target.n_samples,
            target.nb,
            self.n_samples,
            self.nb
        );
        anyhow::ensure!(
            target.subnets == self.subnets,
            "shadow plan subnets {:?} != live plan subnets {:?}",
            target.subnets,
            self.subnets
        );
        target.keep_prob = self.keep_prob;
        target.resample(rng);
        Ok(())
    }

    /// Write this plan's masks (and sample count) into a manifest — the
    /// construction-time path the hot swap replaces, kept for fresh
    /// engine builds (golden tests, the ablation's fresh-build column).
    pub fn apply_to_manifest(&self, man: &mut Manifest) {
        man.n_samples = self.n_samples;
        for (si, sn) in self.subnets.iter().enumerate() {
            for layer in 1..=2usize {
                man.masks.insert(
                    format!("{sn}.mask{layer}"),
                    self.layer(si, layer).to_mask_set(),
                );
            }
        }
    }

    /// Concatenated buffer capacities of every layer (no-alloc witness).
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = Vec::new();
        self.alloc_signature_into(&mut sig);
        sig
    }

    /// Append the capacity signature to a caller-owned buffer — the
    /// allocation-free variant for steady-state witnesses that must not
    /// themselves allocate per pass (the pipeline's shadow-plan check).
    pub fn alloc_signature_into(&self, out: &mut Vec<usize>) {
        for l in &self.layers {
            out.push(l.bits.capacity());
            out.push(l.union.capacity());
            out.push(l.use_count.capacity());
            out.extend(l.kept.iter().map(|k| k.capacity()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixture;

    fn plan() -> MaskPlan {
        let (man, _) = fixture::tiny_fixture();
        MaskPlan::from_manifest(&man).unwrap()
    }

    fn layer_invariants(l: &LayerPlan) {
        // kept lists match bits, ascending
        for s in 0..l.n() {
            let row = &l.bits[s * l.width()..(s + 1) * l.width()];
            let want: Vec<u32> = row
                .iter()
                .enumerate()
                .filter(|(_, &b)| b == 1)
                .map(|(c, _)| c as u32)
                .collect();
            assert_eq!(l.kept(s), want.as_slice());
            assert!(!want.is_empty(), "all-zero mask row survived");
        }
        // union == columns kept by any sample, and counts agree
        let want_union: Vec<u32> = (0..l.width())
            .filter(|&c| (0..l.n()).any(|s| l.bits[s * l.width() + c] == 1))
            .map(|c| c as u32)
            .collect();
        assert_eq!(l.union(), want_union.as_slice());
        for (c, &got) in l.use_count.iter().enumerate() {
            let cnt = (0..l.n())
                .filter(|&s| l.bits[s * l.width() + c] == 1)
                .count() as u32;
            assert_eq!(got, cnt, "incremental count drifted at col {c}");
        }
    }

    #[test]
    fn from_manifest_matches_mask_sets() {
        let (man, _) = fixture::tiny_fixture();
        let p = MaskPlan::from_manifest(&man).unwrap();
        assert_eq!(p.n_samples(), man.n_samples);
        for (si, sn) in man.subnets.iter().enumerate() {
            for layer in 1..=2usize {
                let m = man.mask(sn, layer).unwrap();
                let l = p.layer(si, layer);
                assert_eq!(l.to_mask_set(), *m);
                assert_eq!(p.layer_for(sn, layer).unwrap().to_mask_set(), *m);
                for s in 0..m.n {
                    let want: Vec<u32> = m.kept_indices(s).into_iter().map(|c| c as u32).collect();
                    assert_eq!(l.kept(s), want.as_slice());
                }
                layer_invariants(l);
            }
        }
    }

    #[test]
    fn resample_changes_masks_and_keeps_invariants() {
        let mut p = plan();
        let before: Vec<MaskSet> = (0..4).map(|si| p.layer(si, 1).to_mask_set()).collect();
        let mut rng = Pcg32::new(99);
        p.resample(&mut rng);
        let after: Vec<MaskSet> = (0..4).map(|si| p.layer(si, 1).to_mask_set()).collect();
        assert_ne!(before, after, "resample left the masks unchanged");
        for si in 0..4 {
            layer_invariants(p.layer(si, 1));
            layer_invariants(p.layer(si, 2));
        }
    }

    #[test]
    fn resample_is_deterministic_in_seed() {
        let mut a = plan();
        let mut b = plan();
        let mut ra = Pcg32::new(5);
        let mut rb = Pcg32::new(5);
        for _ in 0..3 {
            a.resample(&mut ra);
            b.resample(&mut rb);
        }
        for si in 0..4 {
            for layer in 1..=2 {
                assert_eq!(a.layer(si, layer).to_mask_set(), b.layer(si, layer).to_mask_set());
            }
        }
    }

    #[test]
    fn resample_never_allocates_in_steady_state() {
        let mut p = plan();
        let mut rng = Pcg32::new(3);
        p.resample(&mut rng); // first call may touch nothing either
        let sig = p.alloc_signature();
        for _ in 0..50 {
            p.resample(&mut rng);
            assert_eq!(p.alloc_signature(), sig, "resample reallocated");
        }
    }

    #[test]
    fn tiny_keep_prob_still_yields_nonempty_rows() {
        let (man, _) = fixture::tiny_fixture();
        let mut rng = Pcg32::new(1);
        let mut p = MaskPlan::bernoulli(&man, 0.01, &mut rng);
        for _ in 0..5 {
            p.resample(&mut rng);
            for si in 0..4 {
                for layer in 1..=2 {
                    let l = p.layer(si, layer);
                    for s in 0..l.n() {
                        assert!(!l.kept(s).is_empty());
                    }
                }
            }
        }
    }

    /// keep_prob = 0 is degenerate: the bounded-redraw fallback must
    /// still terminate with exactly one forced kept column per row.
    #[test]
    fn zero_keep_prob_terminates_with_forced_column() {
        let (man, _) = fixture::tiny_fixture();
        let mut rng = Pcg32::new(2);
        let mut p = MaskPlan::bernoulli(&man, 0.0, &mut rng);
        p.resample(&mut rng);
        for si in 0..4 {
            for layer in 1..=2 {
                let l = p.layer(si, layer);
                for s in 0..l.n() {
                    assert_eq!(l.kept(s).len(), 1, "exactly the forced column survives");
                }
                layer_invariants(l);
            }
        }
    }

    #[test]
    fn all_ones_and_apply_roundtrip() {
        let (man, _) = fixture::tiny_fixture();
        let p = MaskPlan::all_ones(&man, 2);
        assert_eq!(p.n_samples(), 2);
        for si in 0..4 {
            let l = p.layer(si, 1);
            assert_eq!(l.union().len(), man.nb);
            assert_eq!(l.kept(0).len(), man.nb);
        }
        let mut m2 = man.clone();
        p.apply_to_manifest(&mut m2);
        assert_eq!(m2.n_samples, 2);
        let m = m2.mask("d", 1).unwrap();
        assert!(m.bits.iter().all(|&b| b == 1));
        assert_eq!((m.n, m.width), (2, man.nb));
    }

    #[test]
    fn set_keep_prob_retargets_resample_density() {
        let (man, _) = fixture::paper_fixture(); // nb = 104: enough columns
        let mut rng = Pcg32::new(8);
        let mut p = MaskPlan::bernoulli(&man, 0.9, &mut rng);
        p.set_keep_prob(0.2);
        assert_eq!(p.keep_prob(), 0.2);
        p.resample(&mut rng);
        let l = p.layer(0, 1);
        let rate = l.kept(0).len() as f64 / l.width() as f64;
        assert!(rate < 0.5, "resample did not follow the new rate: {rate}");
        p.set_keep_prob(7.0); // clamped
        assert_eq!(p.keep_prob(), 1.0);
    }

    /// The pipeline's correctness lemma: a resample's output (and its
    /// RNG consumption) is a pure function of the incoming RNG state,
    /// never of the prior mask bits — so redrawing a stale shadow clone
    /// matches redrawing the live plan bit-for-bit.
    #[test]
    fn resample_is_independent_of_prior_bits() {
        let (man, _) = fixture::tiny_fixture();
        let mut warm = Pcg32::new(17);
        // two plans in very different prior states...
        let mut live = MaskPlan::bernoulli(&man, 0.5, &mut warm);
        let mut stale = live.clone();
        for _ in 0..3 {
            stale.resample(&mut warm); // diverge the shadow's bits
        }
        // ...resampled from identical RNG states:
        let mut ra = Pcg32::new(23);
        let mut rb = ra.clone();
        live.resample(&mut ra);
        stale.resample(&mut rb);
        for si in 0..4 {
            for layer in 1..=2 {
                assert_eq!(
                    live.layer(si, layer).to_mask_set(),
                    stale.layer(si, layer).to_mask_set(),
                    "prior bits leaked into the resample"
                );
                assert_eq!(
                    live.layer(si, layer).kept_lists(),
                    stale.layer(si, layer).kept_lists()
                );
                assert_eq!(live.layer(si, layer).union(), stale.layer(si, layer).union());
            }
        }
        // ...and both consumed the stream identically:
        assert_eq!(ra.next_u32(), rb.next_u32());
    }

    #[test]
    fn full_layer_range_is_bit_identical_to_resample() {
        let mut a = plan();
        let mut b = plan();
        let mut ra = Pcg32::new(31);
        let mut rb = Pcg32::new(31);
        a.resample(&mut ra);
        b.resample_layer_range(1, 2, &mut rb);
        for si in 0..4 {
            for layer in 1..=2 {
                assert_eq!(a.layer(si, layer).to_mask_set(), b.layer(si, layer).to_mask_set());
            }
        }
        assert_eq!(ra.next_u32(), rb.next_u32());
    }

    #[test]
    fn layer_range_resample_leaves_other_layers_untouched() {
        let mut p = plan();
        let mut rng = Pcg32::new(41);
        p.resample(&mut rng);
        let l1_before: Vec<MaskSet> = (0..4).map(|si| p.layer(si, 1).to_mask_set()).collect();
        let kept_before: Vec<Vec<Vec<u32>>> =
            (0..4).map(|si| p.layer(si, 1).kept_lists().to_vec()).collect();
        let union_before: Vec<Vec<u32>> =
            (0..4).map(|si| p.layer(si, 1).union().to_vec()).collect();
        let l2_before: Vec<MaskSet> = (0..4).map(|si| p.layer(si, 2).to_mask_set()).collect();
        let sig = p.alloc_signature();
        p.resample_layer_range(2, 2, &mut rng);
        for si in 0..4 {
            // untouched layer: bits AND derived index lists bit-identical
            assert_eq!(p.layer(si, 1).to_mask_set(), l1_before[si]);
            assert_eq!(p.layer(si, 1).kept_lists(), kept_before[si].as_slice());
            assert_eq!(p.layer(si, 1).union(), union_before[si].as_slice());
            layer_invariants(p.layer(si, 2));
        }
        assert_ne!(
            (0..4).map(|si| p.layer(si, 2).to_mask_set()).collect::<Vec<_>>(),
            l2_before,
            "layer-2 range resample changed nothing"
        );
        assert_eq!(p.alloc_signature(), sig, "partial resample reallocated");
    }

    #[test]
    fn resample_into_matches_in_place_and_rejects_mismatches() {
        let (man, _) = fixture::tiny_fixture();
        let mut warm = Pcg32::new(9);
        let mut live = MaskPlan::bernoulli(&man, 0.5, &mut warm);
        let mut shadow = live.clone();
        let mut ra = Pcg32::new(77);
        let mut rb = ra.clone();
        live.resample_into(&mut shadow, &mut rb).unwrap();
        let mut inline = live.clone();
        inline.resample(&mut ra);
        for si in 0..4 {
            for layer in 1..=2 {
                assert_eq!(
                    shadow.layer(si, layer).to_mask_set(),
                    inline.layer(si, layer).to_mask_set()
                );
            }
        }
        assert_eq!(ra.next_u32(), rb.next_u32());
        // shape mismatches are rejected before any draw
        let mut wrong = MaskPlan::all_ones(&man, man.n_samples + 1);
        let mut rc = Pcg32::new(1);
        let state_before = rc.next_u32();
        let mut rc = Pcg32::new(1);
        assert!(live.resample_into(&mut wrong, &mut rc).is_err());
        assert_eq!(rc.next_u32(), state_before, "rejected resample drew from the rng");
    }

    #[test]
    fn bernoulli_tracks_keep_prob() {
        let (man, _) = fixture::paper_fixture(); // nb = 104: enough columns
        let mut rng = Pcg32::new(7);
        let p = MaskPlan::bernoulli(&man, 0.5, &mut rng);
        let l = p.layer(0, 1);
        let rate = l.kept(0).len() as f64 / l.width() as f64;
        assert!((rate - 0.5).abs() < 0.2, "keep rate {rate}");
    }
}
