//! Masksembles mask generation — bit-exact mirror of
//! `python/compile/masks.py` (same PCG32 stream, same partial
//! Fisher-Yates), so the coordinator can regenerate the exact masks baked
//! into the AOT artifacts from `manifest.json`'s `mask_seed`.
//!
//! Fixed masks are the paper's central hardware-enabling idea: because the
//! dropped positions are known offline, the accelerator stores only kept
//! weights (mask-zero skipping) and reorders the sampling loop
//! (batch-level scheme).

pub mod plan;

pub use plan::{LayerPlan, MaskPlan};

use crate::util::rng::Pcg32;

/// A set of N binary masks over a layer of `width` neurons.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MaskSet {
    pub n: usize,
    pub width: usize,
    /// Row-major `[n][width]`, values 0/1.
    pub bits: Vec<u8>,
}

impl MaskSet {
    pub fn row(&self, i: usize) -> &[u8] {
        &self.bits[i * self.width..(i + 1) * self.width]
    }

    /// Number of kept neurons in mask `i`.
    pub fn ones(&self, i: usize) -> usize {
        self.row(i).iter().map(|&b| b as usize).sum()
    }

    /// Row as f32 multipliers (the form the engines consume).
    pub fn row_f32(&self, i: usize) -> Vec<f32> {
        self.row(i).iter().map(|&b| b as f32).collect()
    }

    /// Indices of kept neurons in mask `i` — the mask-zero-skipping
    /// "stored weights" index list.
    pub fn kept_indices(&self, i: usize) -> Vec<usize> {
        self.row(i)
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == 1)
            .map(|(j, _)| j)
            .collect()
    }

    /// Mean pairwise IoU (the correlation proxy; lower = closer to Deep
    /// Ensembles).
    pub fn overlap(&self) -> f64 {
        if self.n < 2 {
            return 1.0;
        }
        let mut vals = Vec::new();
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let (mut inter, mut union) = (0usize, 0usize);
                for k in 0..self.width {
                    let a = self.row(i)[k] == 1;
                    let b = self.row(j)[k] == 1;
                    if a && b {
                        inter += 1;
                    }
                    if a || b {
                        union += 1;
                    }
                }
                vals.push(if union == 0 {
                    0.0
                } else {
                    inter as f64 / union as f64
                });
            }
        }
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

/// Python-compatible `round()`: half-to-even (banker's rounding).  Rust's
/// `f64::round` rounds half away from zero, which would desynchronise the
/// mask search path from the Python generator on exact .5 values.
pub(crate) fn pyround(x: f64) -> usize {
    let f = x.floor();
    if (x - f - 0.5).abs() < 1e-9 {
        let lo = f as i64;
        (if lo % 2 == 0 { lo } else { lo + 1 }) as usize
    } else {
        x.round() as usize
    }
}

/// Expected surviving width after dropping unused positions
/// (`round(m*s*(1-(1-1/s)^n))`, mirroring Python).
pub fn expected_width(m: usize, n: usize, s: f64) -> usize {
    pyround(m as f64 * s * (1.0 - (1.0 - 1.0 / s).powi(n as i32)))
}

fn attempt(m: usize, n: usize, s: f64, rng: &mut Pcg32) -> MaskSet {
    let total = pyround(m as f64 * s);
    let mut grid = vec![0u8; n * total];
    for i in 0..n {
        for idx in rng.choose(total, m) {
            grid[i * total + idx] = 1;
        }
    }
    // Keep only columns used by at least one mask.
    let keep: Vec<usize> = (0..total)
        .filter(|&c| (0..n).any(|r| grid[r * total + c] == 1))
        .collect();
    let width = keep.len();
    let mut bits = vec![0u8; n * width];
    for (new_c, &c) in keep.iter().enumerate() {
        for r in 0..n {
            bits[r * width + new_c] = grid[r * total + c];
        }
    }
    MaskSet { n, width, bits }
}

/// Retry `attempt` until the surviving width equals the expected width.
pub fn generate_masks(m: usize, n: usize, s: f64, rng: &mut Pcg32) -> MaskSet {
    let exp = expected_width(m, n, s);
    let mut masks = attempt(m, n, s, rng);
    let mut tries = 1;
    while masks.width != exp && tries < 4096 {
        masks = attempt(m, n, s, rng);
        tries += 1;
    }
    masks
}

fn solve_scale(m: usize, n: usize, c: usize) -> Option<f64> {
    let (mut lo, mut hi) = (1.0 + 1e-6, 64.0);
    if expected_width(m, n, hi) < c || expected_width(m, n, lo) > c {
        return None;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let e = expected_width(m, n, mid);
        if e == c {
            return Some(mid);
        }
        if e < c {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    None
}

/// Generate `n` masks of width exactly `c` with `~c/scale` ones each —
/// the identical directed search to `python/compile/masks.py::for_width`.
pub fn for_width(c: usize, n: usize, scale: f64, seed: u64) -> anyhow::Result<MaskSet> {
    anyhow::ensure!(c >= 1 && n >= 1, "width and mask count must be >= 1");
    if scale <= 1.0 {
        return Ok(MaskSet {
            n,
            width: c,
            bits: vec![1u8; n * c],
        });
    }
    let mut rng = Pcg32::new(seed);
    let mut m = pyround(c as f64 / scale).max(1);
    for _ in 0..(64 + c) {
        if expected_width(m, n, 64.0) < c {
            m += 1;
            continue;
        }
        if m > c {
            m -= 1;
            continue;
        }
        let Some(s) = solve_scale(m, n, c) else {
            m += 1;
            continue;
        };
        let masks = generate_masks(m, n, s, &mut rng);
        if masks.width == c {
            return Ok(masks);
        }
    }
    anyhow::bail!("mask search failed for width={c} n={n} scale={scale}")
}

/// The per-(subnet, layer) mask seed convention shared with
/// `python/compile/model.py::build_masks`.
pub fn subnet_layer_seed(mask_seed: u64, subnet_index: usize, layer: usize) -> u64 {
    mask_seed + 1000 * subnet_index as u64 + layer as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_width_and_uniform_ones() {
        let m = for_width(11, 4, 2.0, 2024).unwrap();
        assert_eq!((m.n, m.width), (4, 11));
        let ones: Vec<usize> = (0..4).map(|i| m.ones(i)).collect();
        assert!(ones.windows(2).all(|w| w[0] == w[1]), "{ones:?}");
        assert!(ones[0] >= 3 && ones[0] <= 8);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = for_width(16, 4, 1.8, 7).unwrap();
        let b = for_width(16, 4, 1.8, 7).unwrap();
        let c = for_width(16, 4, 1.8, 8).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn every_column_used() {
        let m = for_width(24, 4, 2.5, 3).unwrap();
        for c in 0..m.width {
            assert!((0..m.n).any(|r| m.row(r)[c] == 1), "dead column {c}");
        }
    }

    #[test]
    fn scale_one_is_all_ones() {
        let m = for_width(10, 4, 1.0, 0).unwrap();
        assert!(m.bits.iter().all(|&b| b == 1));
    }

    #[test]
    fn overlap_decreases_with_scale() {
        let low = for_width(64, 4, 1.3, 11).unwrap().overlap();
        let high = for_width(64, 4, 4.0, 11).unwrap().overlap();
        assert!(high < low, "{high} !< {low}");
    }

    #[test]
    fn hard_cases_from_python_scan() {
        // The n=2, scale>=3 family used to cycle in the undirected search.
        for &(c, n, scale) in &[(7usize, 2usize, 3.0f64), (10, 2, 3.5), (19, 2, 3.0)] {
            let m = for_width(c, n, scale, 0).unwrap();
            assert_eq!(m.width, c);
        }
    }

    #[test]
    fn kept_indices_match_bits() {
        let m = for_width(12, 4, 2.0, 5).unwrap();
        for i in 0..4 {
            let kept = m.kept_indices(i);
            assert_eq!(kept.len(), m.ones(i));
            for &k in &kept {
                assert_eq!(m.row(i)[k], 1);
            }
        }
    }

    #[test]
    fn pyround_is_half_even() {
        assert_eq!(pyround(4.5), 4);
        assert_eq!(pyround(5.5), 6);
        assert_eq!(pyround(2.3), 2);
        assert_eq!(pyround(2.7), 3);
        assert_eq!(pyround(0.5), 0);
        assert_eq!(pyround(1.5), 2);
    }

    /// Property: the empirical keep-rate (ones per row / width) tracks
    /// the configured Bernoulli keep probability 1/scale.  At the widths
    /// the paper uses the directed search concentrates tightly around it.
    ///
    /// n starts at 3: with only 2 masks the coverage constraint
    /// (n * ones >= width, every column used by some mask) forces the
    /// keep-rate up to ~0.5 regardless of the requested scale, so the
    /// Bernoulli approximation only holds from n = 3 on.
    #[test]
    fn property_keep_rate_tracks_bernoulli_rate() {
        use crate::testing::{forall, zip, Gen};
        forall(
            30,
            zip(Gen::usize_in(48, 104), Gen::usize_in(3, 8)),
            |&(c, n): &(usize, usize)| {
                [1.5f64, 2.0, 3.0].iter().all(|&scale| {
                    let m = for_width(c, n, scale, 17).unwrap();
                    let want = 1.0 / scale;
                    (0..n).all(|i| {
                        let got = m.ones(i) as f64 / m.width as f64;
                        (got - want).abs() < 0.12
                    })
                })
            },
        );
    }

    /// Property: generation is bit-exact in the seed — same (width, n,
    /// scale, seed) always yields the identical bits, and a different
    /// seed diverges.  This is what lets the Rust side regenerate the
    /// AOT-baked masks from `manifest.json`'s `mask_seed` alone.
    #[test]
    fn property_bit_exact_determinism() {
        use crate::testing::{forall, zip, Gen};
        forall(
            40,
            zip(Gen::usize_in(8, 64), Gen::usize_in(2, 8)),
            |&(c, n): &(usize, usize)| {
                let a = for_width(c, n, 2.0, 99).unwrap();
                let b = for_width(c, n, 2.0, 99).unwrap();
                a.bits == b.bits && a.width == b.width && a.n == b.n
            },
        );
    }

    /// Property: the N masks of a set are pairwise distinct — identical
    /// masks would collapse two Monte-Carlo samples into one and silently
    /// shrink the ensemble.
    #[test]
    fn property_masks_distinct_across_samples() {
        use crate::testing::{forall, zip, Gen};
        forall(
            30,
            zip(Gen::usize_in(24, 96), Gen::usize_in(2, 6)),
            |&(c, n): &(usize, usize)| {
                let m = for_width(c, n, 2.0, 5).unwrap();
                (0..n).all(|i| (i + 1..n).all(|j| m.row(i) != m.row(j)))
            },
        );
    }

    #[test]
    fn property_shapes() {
        use crate::testing::{forall, zip, Gen};
        forall(
            40,
            zip(Gen::usize_in(4, 48), Gen::usize_in(2, 8)),
            |&(c, n): &(usize, usize)| {
                let m = for_width(c, n, 2.0, 9).unwrap();
                m.width == c
                    && m.n == n
                    && m.bits.iter().all(|&b| b <= 1)
                    && (0..n).map(|i| m.ones(i)).collect::<std::collections::HashSet<_>>().len()
                        == 1
            },
        );
    }
}
