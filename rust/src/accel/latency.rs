//! Analytic latency model (paper §III Phase 3, eq. 2) — closed-form
//! cycle prediction from the configuration alone, cross-validated against
//! the cycle simulator ("The processing speed can be estimated based on
//! equation (2), which matches the practical results", §VI-C).

use super::pu::PuConfig;
use super::resource::AccelConfig;
use super::schemes::Scheme;
use super::sim::LOAD_WORDS_PER_CYCLE;
use crate::model::Manifest;

/// Closed-form cycle prediction for one batch.
///
/// Mirrors the controller schedule: per (subnet, layer, sample) a weight
/// load plus a pipelined streaming phase of
/// `ceil(kept/N_PE) * batch * chunks` cycles behind a fill of
/// eq. (2)'s PU latency.
pub fn predict_batch_cycles(man: &Manifest, cfg: &AccelConfig, scheme: Scheme) -> u64 {
    let pu = PuConfig {
        lanes: cfg.lanes.min(man.nb.next_power_of_two()),
        r_m: cfg.r_m,
        r_a: cfg.r_a,
    };
    let fill = pu.latency_cycles(man.nb) as u64;
    let chunks = pu.chunks(man.nb) as u64;
    let batch = cfg.batch as u64;
    let mut cycles = 0u64;

    let combine = |load: u64, compute: u64| {
        if cfg.overlap_loads {
            load.max(compute)
        } else {
            load + compute
        }
    };
    for sn in &man.subnets {
        for layer in 1..=2usize {
            let mask = man.mask(sn, layer).expect("mask");
            for s in 0..man.n_samples {
                let kept = mask.ones(s) as u64;
                let words = kept * man.nb as u64 + 3 * kept;
                let loads = match scheme {
                    Scheme::BatchLevel => 1u64,
                    Scheme::SamplingLevel => batch,
                };
                let load_c = words.div_ceil(LOAD_WORDS_PER_CYCLE as u64) * loads;
                let out_groups = kept.div_ceil(cfg.n_pe as u64);
                cycles += combine(load_c, fill + out_groups * batch * chunks);
            }
        }
        // encoder
        for _ in 0..man.n_samples {
            let words = man.nb as u64 + 1;
            let load_c = words.div_ceil(LOAD_WORDS_PER_CYCLE as u64);
            cycles += combine(load_c, fill + batch * chunks);
        }
    }
    cycles
}

/// Predicted batch latency in milliseconds.
pub fn predict_batch_ms(man: &Manifest, cfg: &AccelConfig, scheme: Scheme) -> f64 {
    predict_batch_cycles(man, cfg, scheme) as f64 / cfg.clock_hz * 1e3
}

/// Predicted throughput in voxels/second.
pub fn predict_voxels_per_s(man: &Manifest, cfg: &AccelConfig, scheme: Scheme) -> f64 {
    let ms = predict_batch_ms(man, cfg, scheme);
    cfg.batch as f64 / (ms / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::sim::AccelSimulator;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;
    use crate::model::Weights;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            // fixture fallback: the analytic model must match the
            // simulator on any manifest, not just the exported one
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn analytic_model_matches_simulator_exactly() {
        let Some((man, w)) = setup() else { return };
        for scheme in [Scheme::BatchLevel, Scheme::SamplingLevel] {
            let cfg = AccelConfig {
                batch: man.batch_infer,
                ..Default::default()
            };
            let mut sim = AccelSimulator::new(&man, &w, cfg, scheme).unwrap();
            let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
            let (_, stats) = sim.infer_batch_stats(&ds.signals).unwrap();
            let predicted = predict_batch_cycles(&man, &cfg, scheme);
            assert_eq!(
                predicted, stats.cycles,
                "{scheme:?}: analytic {predicted} vs simulated {}",
                stats.cycles
            );
        }
    }

    #[test]
    fn more_pes_fewer_cycles() {
        let Some((man, _)) = setup() else { return };
        let mut prev = u64::MAX;
        for n_pe in [2usize, 4, 8] {
            let cfg = AccelConfig {
                n_pe,
                batch: man.batch_infer,
                ..Default::default()
            };
            let c = predict_batch_cycles(&man, &cfg, Scheme::BatchLevel);
            assert!(c <= prev, "n_pe={n_pe}: {c} > {prev}");
            prev = c;
        }
    }

    #[test]
    fn sampling_level_strictly_slower() {
        let Some((man, _)) = setup() else { return };
        let cfg = AccelConfig {
            batch: man.batch_infer,
            ..Default::default()
        };
        assert!(
            predict_batch_cycles(&man, &cfg, Scheme::SamplingLevel)
                > predict_batch_cycles(&man, &cfg, Scheme::BatchLevel)
        );
    }

    #[test]
    fn throughput_consistent_with_latency() {
        let Some((man, _)) = setup() else { return };
        let cfg = AccelConfig {
            batch: man.batch_infer,
            ..Default::default()
        };
        let ms = predict_batch_ms(&man, &cfg, Scheme::BatchLevel);
        let vps = predict_voxels_per_s(&man, &cfg, Scheme::BatchLevel);
        assert!((vps - cfg.batch as f64 / (ms / 1e3)).abs() < 1e-6);
    }
}
