//! Processing Unit (PU) — the paper's §V-C datapath, bit- and
//! cycle-faithful.
//!
//! A PU computes one output neuron's dot product: a block of `lanes`
//! parallel 16-bit multipliers feeding a pipelined adder tree of depth
//! `L = ceil(log2(lanes))`, followed by a chunk accumulator and the bias
//! add.  `R_M` / `R_A` internal pipeline registers per multiplier / adder
//! let the PU accept a new chunk every cycle despite multi-cycle op
//! latency.
//!
//! Paper eq. (2) (with `N_PE` denoting the PU's multiplier lane count):
//!
//! ```text
//! Latency_PU = R_M + R_A*(L+1) + ceil(Nb/lanes) - 1
//! ```
//!
//! i.e. multiplier fill + tree fill + one extra tree level's register for
//! the accumulator + the serial accumulation of `ceil(Nb/lanes)` chunks.
//!
//! ## Functional evaluation: `Pu` state vs free-function oracle
//!
//! The hot path is [`Pu`]: it owns the chunk scratch once, so the
//! innermost loop of `accel/sim.rs` performs **zero heap allocations**
//! in steady state (the crate-wide contract; previously every dot
//! product allocated a `vec![0i64; lanes]`).  When the `simd` feature is
//! on and the CPU has AVX2, [`Pu::dot_acc`] dispatches the vectorised
//! chunk-MAC from [`crate::util::simd`] — **bit-exact** with the scalar
//! adder tree, because i64 addition is associative and commutative so
//! any summation order yields identical bits, and no overflow is
//! reachable (|product| ≤ 2^30; exceeding i64 would need > 2^33 terms).
//!
//! The free functions [`pu_dot_acc`] / [`pu_dot`] remain as the
//! allocating scalar **oracles** the dispatch is golden-tested against.
//!
//! Length contract: `x` and `w` must be equal length — enforced by a
//! hard `assert!` on every path.  (It used to be a `debug_assert!`,
//! which vanished in release builds and let mismatched slices silently
//! zip-truncate into a wrong dot product.)

use super::fixed::{sat_from_acc, Fx};

/// Static PU configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PuConfig {
    /// Parallel multiplier lanes (the paper's PEs handle up to 128
    /// elements per voxel).
    pub lanes: usize,
    /// Pipeline registers per multiplier.
    pub r_m: usize,
    /// Pipeline registers per adder.
    pub r_a: usize,
}

impl Default for PuConfig {
    fn default() -> Self {
        // R_M = 3, R_A = 2 are typical for 16-bit DSP48 mult / fabric add
        // at 250 MHz on UltraScale+.
        PuConfig {
            lanes: 128,
            r_m: 3,
            r_a: 2,
        }
    }
}

impl PuConfig {
    /// Adder tree depth.
    pub fn tree_depth(&self) -> usize {
        (self.lanes.max(2) as f64).log2().ceil() as usize
    }

    /// Paper eq. (2): cycles until the first dot product of an `nb`-long
    /// input emerges from the PU.
    pub fn latency_cycles(&self, nb: usize) -> usize {
        let chunks = nb.div_ceil(self.lanes);
        self.r_m + self.r_a * (self.tree_depth() + 1) + chunks - 1
    }

    /// Chunks (sequential accumulation steps) for an `nb`-long input.
    pub fn chunks(&self, nb: usize) -> usize {
        nb.div_ceil(self.lanes)
    }
}

#[inline]
fn assert_same_len(x: &[Fx], w: &[Fx]) {
    assert_eq!(
        x.len(),
        w.len(),
        "PU dot: input length {} != weight length {} (a mismatch would silently zip-truncate)",
        x.len(),
        w.len()
    );
}

/// Scalar adder-tree accumulation over caller-supplied chunk scratch
/// (`scratch.len() == cfg.lanes`) — the allocation-free body shared by
/// the [`Pu`] scalar path and the [`pu_dot_acc`] oracle.
pub fn pu_dot_acc_into(cfg: &PuConfig, scratch: &mut [i64], x: &[Fx], w: &[Fx]) -> i64 {
    assert_same_len(x, w);
    assert_eq!(
        scratch.len(),
        cfg.lanes,
        "PU dot: scratch sized for {} lanes, config has {}",
        scratch.len(),
        cfg.lanes
    );
    let mut acc: i64 = 0;
    for (xc, wc) in x.chunks(cfg.lanes).zip(w.chunks(cfg.lanes)) {
        for (i, slot) in scratch.iter_mut().enumerate() {
            *slot = if i < xc.len() {
                xc[i].mul_raw(wc[i]) as i64
            } else {
                0
            };
        }
        let mut width = cfg.lanes;
        while width > 1 {
            let half = width.div_ceil(2);
            for i in 0..half {
                let a = scratch[2 * i];
                let b = if 2 * i + 1 < width { scratch[2 * i + 1] } else { 0 };
                scratch[i] = a + b;
            }
            width = half;
        }
        acc += scratch[0];
    }
    acc
}

/// Raw PU accumulation: fixed-point dot product in adder-tree order,
/// returned as the wide Q8.24 accumulator (callers add bias / apply
/// shifts before saturating).  Bit-exact with the hardware datapath.
///
/// This is the allocating scalar **oracle** — it builds its chunk
/// scratch per call.  Hot paths hold a [`Pu`] instead.
pub fn pu_dot_acc(cfg: &PuConfig, x: &[Fx], w: &[Fx]) -> i64 {
    let mut scratch = vec![0i64; cfg.lanes];
    pu_dot_acc_into(cfg, &mut scratch, x, w)
}

/// Functional PU evaluation: fixed-point dot product + bias, computed in
/// adder-tree order (pairwise reduction) with a wide accumulator —
/// bit-exact with the hardware the cycle model describes.
///
/// `x` and `w` must be equal-length; shorter-than-`lanes` tails are
/// zero-padded exactly like the hardware's unused lanes.
pub fn pu_dot(cfg: &PuConfig, x: &[Fx], w: &[Fx], bias: Fx) -> Fx {
    // bias enters the accumulator in Q8.24
    let acc = pu_dot_acc(cfg, x, w) + ((bias.0 as i64) << super::fixed::FRAC_BITS);
    sat_from_acc(acc)
}

/// Reusable PU evaluation state: the configuration plus the chunk
/// scratch, allocated once.  Thread one `Pu` through a simulation loop
/// and every dot product is allocation-free; with the `simd` feature on
/// an AVX2 CPU the scratch is bypassed entirely in favour of the
/// vectorised chunk-MAC (bit-exact — see the module docs).
#[derive(Debug, Clone)]
pub struct Pu {
    cfg: PuConfig,
    scratch: Vec<i64>,
}

impl Pu {
    pub fn new(cfg: PuConfig) -> Pu {
        Pu {
            cfg,
            scratch: vec![0i64; cfg.lanes],
        }
    }

    pub fn config(&self) -> &PuConfig {
        &self.cfg
    }

    /// Kernel this instance dispatches (`"avx2"` or `"scalar"`), for
    /// the runtime-dispatch tests and bench labels.
    pub fn backend(&self) -> &'static str {
        if crate::util::simd::avx2_available() {
            "avx2"
        } else {
            "scalar"
        }
    }

    /// Raw accumulation — semantics of [`pu_dot_acc`], zero allocation.
    pub fn dot_acc(&mut self, x: &[Fx], w: &[Fx]) -> i64 {
        assert_same_len(x, w);
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if crate::util::simd::avx2_available() {
            use super::fixed::raw_slice;
            return crate::util::simd::fx_dot_acc(raw_slice(x), raw_slice(w));
        }
        pu_dot_acc_into(&self.cfg, &mut self.scratch, x, w)
    }

    /// Dot product + bias — semantics of [`pu_dot`], zero allocation.
    pub fn dot(&mut self, x: &[Fx], w: &[Fx], bias: Fx) -> Fx {
        let acc = self.dot_acc(x, w) + ((bias.0 as i64) << super::fixed::FRAC_BITS);
        sat_from_acc(acc)
    }

    /// Scratch capacity — the no-allocation witness for the
    /// alloc-signature stability tests.
    pub fn alloc_signature(&self) -> usize {
        self.scratch.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::fixed::{MAX_RAW, MIN_RAW};

    fn fx(v: f32) -> Fx {
        Fx::from_f32(v)
    }

    #[test]
    fn latency_matches_paper_formula() {
        // Paper example shape: Nb=104, lanes=128 -> 1 chunk.
        let cfg = PuConfig::default();
        let l = cfg.tree_depth(); // log2(128) = 7
        assert_eq!(l, 7);
        assert_eq!(cfg.latency_cycles(104), 3 + 2 * 8 + 0); // 19
        // Nb=300 on 128 lanes -> 3 chunks -> +2 cycles.
        assert_eq!(cfg.latency_cycles(300), 3 + 2 * 8 + 2);
    }

    #[test]
    fn tree_depth_non_pow2() {
        let cfg = PuConfig {
            lanes: 11,
            r_m: 1,
            r_a: 1,
        };
        assert_eq!(cfg.tree_depth(), 4); // ceil(log2(11))
        assert_eq!(cfg.chunks(11), 1);
        assert_eq!(cfg.chunks(12), 2);
    }

    #[test]
    fn dot_exact_small() {
        let cfg = PuConfig {
            lanes: 4,
            ..Default::default()
        };
        let x = vec![fx(1.0), fx(2.0), fx(-1.5), fx(0.5)];
        let w = vec![fx(0.5), fx(0.25), fx(1.0), fx(-2.0)];
        // 0.5 + 0.5 - 1.5 - 1.0 = -1.5; bias 0.25 -> -1.25
        let got = pu_dot(&cfg, &x, &w, fx(0.25));
        assert_eq!(got.to_f32(), -1.25);
        // the reusable state agrees
        assert_eq!(Pu::new(cfg).dot(&x, &w, fx(0.25)), got);
    }

    #[test]
    fn dot_handles_multi_chunk() {
        let cfg = PuConfig {
            lanes: 2,
            ..Default::default()
        };
        let x: Vec<Fx> = (0..6).map(|i| fx(0.5 * i as f32)).collect();
        let w: Vec<Fx> = (0..6).map(|_| fx(1.0)).collect();
        // sum 0+0.5+1+1.5+2+2.5 = 7.5
        assert_eq!(pu_dot(&cfg, &x, &w, Fx::ZERO).to_f32(), 7.5);
    }

    #[test]
    fn dot_saturates() {
        let cfg = PuConfig {
            lanes: 4,
            ..Default::default()
        };
        let x = vec![fx(7.9); 4];
        let w = vec![fx(7.9); 4];
        let got = pu_dot(&cfg, &x, &w, Fx::ZERO);
        assert_eq!(got, Fx(MAX_RAW));
    }

    #[test]
    fn dot_matches_f32_reference_within_quantisation() {
        use crate::util::rng::Pcg32;
        let cfg = PuConfig {
            lanes: 16,
            ..Default::default()
        };
        let mut rng = Pcg32::new(8);
        for _ in 0..50 {
            let n = 1 + rng.below(40) as usize;
            let xf: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let wf: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let x: Vec<Fx> = xf.iter().map(|&v| fx(v)).collect();
            let w: Vec<Fx> = wf.iter().map(|&v| fx(v)).collect();
            let want: f32 = x
                .iter()
                .zip(&w)
                .map(|(a, b)| a.to_f32() * b.to_f32())
                .sum();
            let got = pu_dot(&cfg, &x, &w, Fx::ZERO).to_f32();
            // n products each with <= eps/2 rounding in the accumulator
            let tol = Fx::epsilon() * (n as f32 * 0.5 + 1.0);
            assert!((got - want).abs() <= tol, "{got} vs {want} (n={n})");
        }
    }

    /// The dispatched `Pu` path (scalar-with-scratch, or AVX2 under the
    /// `simd` feature) must be bit-exact with the allocating scalar
    /// oracle — across lane counts, remainder tails, the empty input and
    /// full-range raw values including `i16::MIN` extremes.
    #[test]
    fn pu_state_matches_oracle_bit_exact() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(44);
        for lanes in [1usize, 2, 4, 16, 128] {
            let cfg = PuConfig {
                lanes,
                ..Default::default()
            };
            let mut pu = Pu::new(cfg);
            for n in [0usize, 1, 3, 7, 8, 9, 104, 300] {
                let x: Vec<Fx> = (0..n)
                    .map(|_| Fx(rng.below(1 << 16) as u16 as i16))
                    .collect();
                let w: Vec<Fx> = (0..n)
                    .map(|_| Fx(rng.below(1 << 16) as u16 as i16))
                    .collect();
                assert_eq!(
                    pu.dot_acc(&x, &w),
                    pu_dot_acc(&cfg, &x, &w),
                    "lanes={lanes} n={n}"
                );
            }
        }
        // saturation extremes: every product is (-32768)^2 = 2^30
        let cfg = PuConfig {
            lanes: 8,
            ..Default::default()
        };
        let mut pu = Pu::new(cfg);
        let x = vec![Fx(MIN_RAW); 20];
        assert_eq!(pu.dot_acc(&x, &x), pu_dot_acc(&cfg, &x, &x));
        assert_eq!(pu.dot_acc(&x, &x), 20 * (1i64 << 30));
    }

    /// The bugfix pin: mismatched slice lengths must panic loudly on
    /// every path — in release builds the old `debug_assert` let them
    /// zip-truncate into a silently wrong dot product.
    #[test]
    fn mismatched_lengths_panic_instead_of_truncating() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let cfg = PuConfig {
            lanes: 4,
            ..Default::default()
        };
        let x = vec![fx(1.0); 5];
        let w = vec![fx(1.0); 3];
        let r = catch_unwind(AssertUnwindSafe(|| pu_dot_acc(&cfg, &x, &w)));
        assert!(r.is_err(), "oracle must panic on mismatched lengths");
        let r = catch_unwind(AssertUnwindSafe(|| pu_dot(&cfg, &x, &w, Fx::ZERO)));
        assert!(r.is_err(), "pu_dot must panic on mismatched lengths");
        let mut pu = Pu::new(cfg);
        let r = catch_unwind(AssertUnwindSafe(|| pu.dot_acc(&x, &w)));
        assert!(r.is_err(), "Pu::dot_acc must panic on mismatched lengths");
        // and a matched call on the same instance still works after the
        // unwind (no poisoned state)
        let mut pu = Pu::new(cfg);
        assert_eq!(pu.dot_acc(&x[..3], &w), pu_dot_acc(&cfg, &x[..3], &w));
    }

    /// Steady-state zero-allocation pin: the scratch is sized once at
    /// construction and never grows, whatever input lengths follow.
    #[test]
    fn pu_scratch_capacity_is_stable() {
        let cfg = PuConfig {
            lanes: 16,
            ..Default::default()
        };
        let mut pu = Pu::new(cfg);
        let sig = pu.alloc_signature();
        assert_eq!(sig, cfg.lanes);
        let xs: Vec<Fx> = (0..300).map(|i| Fx(i as i16)).collect();
        for n in [0usize, 5, 16, 33, 200, 300] {
            for _ in 0..20 {
                let _ = pu.dot_acc(&xs[..n], &xs[..n]);
            }
        }
        assert_eq!(pu.alloc_signature(), sig, "chunk scratch reallocated");
    }

    /// Runtime-dispatch pin: without the `simd` feature the Pu must
    /// report (and use) the scalar backend.
    #[cfg(not(feature = "simd"))]
    #[test]
    fn scalar_fallback_selected_without_simd_feature() {
        assert_eq!(Pu::new(PuConfig::default()).backend(), "scalar");
        assert!(!crate::util::simd::avx2_available());
    }

    #[cfg(feature = "simd")]
    #[test]
    fn backend_follows_cpu_detection_with_simd_feature() {
        let want = if crate::util::simd::avx2_available() {
            "avx2"
        } else {
            "scalar"
        };
        assert_eq!(Pu::new(PuConfig::default()).backend(), want);
    }
}
