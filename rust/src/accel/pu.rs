//! Processing Unit (PU) — the paper's §V-C datapath, bit- and
//! cycle-faithful.
//!
//! A PU computes one output neuron's dot product: a block of `lanes`
//! parallel 16-bit multipliers feeding a pipelined adder tree of depth
//! `L = ceil(log2(lanes))`, followed by a chunk accumulator and the bias
//! add.  `R_M` / `R_A` internal pipeline registers per multiplier / adder
//! let the PU accept a new chunk every cycle despite multi-cycle op
//! latency.
//!
//! Paper eq. (2) (with `N_PE` denoting the PU's multiplier lane count):
//!
//! ```text
//! Latency_PU = R_M + R_A*(L+1) + ceil(Nb/lanes) - 1
//! ```
//!
//! i.e. multiplier fill + tree fill + one extra tree level's register for
//! the accumulator + the serial accumulation of `ceil(Nb/lanes)` chunks.

use super::fixed::{sat_from_acc, Fx};

/// Static PU configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PuConfig {
    /// Parallel multiplier lanes (the paper's PEs handle up to 128
    /// elements per voxel).
    pub lanes: usize,
    /// Pipeline registers per multiplier.
    pub r_m: usize,
    /// Pipeline registers per adder.
    pub r_a: usize,
}

impl Default for PuConfig {
    fn default() -> Self {
        // R_M = 3, R_A = 2 are typical for 16-bit DSP48 mult / fabric add
        // at 250 MHz on UltraScale+.
        PuConfig {
            lanes: 128,
            r_m: 3,
            r_a: 2,
        }
    }
}

impl PuConfig {
    /// Adder tree depth.
    pub fn tree_depth(&self) -> usize {
        (self.lanes.max(2) as f64).log2().ceil() as usize
    }

    /// Paper eq. (2): cycles until the first dot product of an `nb`-long
    /// input emerges from the PU.
    pub fn latency_cycles(&self, nb: usize) -> usize {
        let chunks = nb.div_ceil(self.lanes);
        self.r_m + self.r_a * (self.tree_depth() + 1) + chunks - 1
    }

    /// Chunks (sequential accumulation steps) for an `nb`-long input.
    pub fn chunks(&self, nb: usize) -> usize {
        nb.div_ceil(self.lanes)
    }
}

/// Raw PU accumulation: fixed-point dot product in adder-tree order,
/// returned as the wide Q8.24 accumulator (callers add bias / apply
/// shifts before saturating).  Bit-exact with the hardware datapath.
pub fn pu_dot_acc(cfg: &PuConfig, x: &[Fx], w: &[Fx]) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    let mut acc: i64 = 0;
    let mut chunk_prods = vec![0i64; cfg.lanes];
    for (xc, wc) in x.chunks(cfg.lanes).zip(w.chunks(cfg.lanes)) {
        for (i, slot) in chunk_prods.iter_mut().enumerate() {
            *slot = if i < xc.len() {
                xc[i].mul_raw(wc[i]) as i64
            } else {
                0
            };
        }
        let mut width = cfg.lanes;
        while width > 1 {
            let half = width.div_ceil(2);
            for i in 0..half {
                let a = chunk_prods[2 * i];
                let b = if 2 * i + 1 < width {
                    chunk_prods[2 * i + 1]
                } else {
                    0
                };
                chunk_prods[i] = a + b;
            }
            width = half;
        }
        acc += chunk_prods[0];
    }
    acc
}

/// Functional PU evaluation: fixed-point dot product + bias, computed in
/// adder-tree order (pairwise reduction) with a wide accumulator —
/// bit-exact with the hardware the cycle model describes.
///
/// `x` and `w` must be equal-length; shorter-than-`lanes` tails are
/// zero-padded exactly like the hardware's unused lanes.
pub fn pu_dot(cfg: &PuConfig, x: &[Fx], w: &[Fx], bias: Fx) -> Fx {
    // bias enters the accumulator in Q8.24
    let acc = pu_dot_acc(cfg, x, w) + ((bias.0 as i64) << super::fixed::FRAC_BITS);
    sat_from_acc(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fx(v: f32) -> Fx {
        Fx::from_f32(v)
    }

    #[test]
    fn latency_matches_paper_formula() {
        // Paper example shape: Nb=104, lanes=128 -> 1 chunk.
        let cfg = PuConfig::default();
        let l = cfg.tree_depth(); // log2(128) = 7
        assert_eq!(l, 7);
        assert_eq!(cfg.latency_cycles(104), 3 + 2 * 8 + 0); // 19
        // Nb=300 on 128 lanes -> 3 chunks -> +2 cycles.
        assert_eq!(cfg.latency_cycles(300), 3 + 2 * 8 + 2);
    }

    #[test]
    fn tree_depth_non_pow2() {
        let cfg = PuConfig {
            lanes: 11,
            r_m: 1,
            r_a: 1,
        };
        assert_eq!(cfg.tree_depth(), 4); // ceil(log2(11))
        assert_eq!(cfg.chunks(11), 1);
        assert_eq!(cfg.chunks(12), 2);
    }

    #[test]
    fn dot_exact_small() {
        let cfg = PuConfig {
            lanes: 4,
            ..Default::default()
        };
        let x = vec![fx(1.0), fx(2.0), fx(-1.5), fx(0.5)];
        let w = vec![fx(0.5), fx(0.25), fx(1.0), fx(-2.0)];
        // 0.5 + 0.5 - 1.5 - 1.0 = -1.5; bias 0.25 -> -1.25
        let got = pu_dot(&cfg, &x, &w, fx(0.25));
        assert_eq!(got.to_f32(), -1.25);
    }

    #[test]
    fn dot_handles_multi_chunk() {
        let cfg = PuConfig {
            lanes: 2,
            ..Default::default()
        };
        let x: Vec<Fx> = (0..6).map(|i| fx(0.5 * i as f32)).collect();
        let w: Vec<Fx> = (0..6).map(|_| fx(1.0)).collect();
        // sum 0+0.5+1+1.5+2+2.5 = 7.5
        assert_eq!(pu_dot(&cfg, &x, &w, Fx::ZERO).to_f32(), 7.5);
    }

    #[test]
    fn dot_saturates() {
        let cfg = PuConfig {
            lanes: 4,
            ..Default::default()
        };
        let x = vec![fx(7.9); 4];
        let w = vec![fx(7.9); 4];
        let got = pu_dot(&cfg, &x, &w, Fx::ZERO);
        assert_eq!(got, Fx(super::super::fixed::MAX_RAW));
    }

    #[test]
    fn dot_matches_f32_reference_within_quantisation() {
        use crate::util::rng::Pcg32;
        let cfg = PuConfig {
            lanes: 16,
            ..Default::default()
        };
        let mut rng = Pcg32::new(8);
        for _ in 0..50 {
            let n = 1 + rng.below(40) as usize;
            let xf: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let wf: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let x: Vec<Fx> = xf.iter().map(|&v| fx(v)).collect();
            let w: Vec<Fx> = wf.iter().map(|&v| fx(v)).collect();
            let want: f32 = x
                .iter()
                .zip(&w)
                .map(|(a, b)| a.to_f32() * b.to_f32())
                .sum();
            let got = pu_dot(&cfg, &x, &w, Fx::ZERO).to_f32();
            // n products each with <= eps/2 rounding in the accumulator
            let tol = Fx::epsilon() * (n as f32 * 0.5 + 1.0);
            assert!((got - want).abs() <= tol, "{got} vs {want} (n={n})");
        }
    }
}
