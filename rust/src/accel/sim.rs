//! Cycle-level accelerator simulator — the controller (§V-D), PE array
//! (§V-C) and both operation-ordering schemes, functionally evaluating
//! uIVIM-NET in Q4.12 and counting cycles / weight loads as the RTL
//! would.
//!
//! Controller schedule per batch (batch-level scheme):
//!
//! ```text
//! for subnet in [D, D*, f, S0]:
//!   for layer in [1, 2, encoder]:
//!     for sample in 0..N:                  # outer = batch-level
//!       load sample's (mask-skipped) weights        -> load cycles
//!       for voxel in batch:                          # pipelined
//!         for out_group in ceil(kept/N_PE):          # PEs in parallel
//!           PU: chunks = ceil(nb/lanes) cycles each
//! ```
//!
//! The sampling-level scheme swaps the sample and voxel loops, forcing a
//! weight re-load per (voxel, sample) — same arithmetic, same results,
//! `batchsize`x the load traffic (paper Fig. 5).
//!
//! Mask-zero skipping: dropped output neurons are never scheduled (no
//! cycles, no weights stored); the sigmoid is the hardware-standard PLAN
//! piecewise-linear approximation.
//!
//! Masks are hot-swappable runtime state ([`AccelSimulator::swap_masks`]):
//! folded-BN columns are quantised once at construction (unmasked,
//! worst-case capacity) and a swap only re-selects kept-column index
//! lists in place — many mask draws over one fixed weight block, the
//! economy SoftDropConnect-style mask sampling assumes.

use super::fixed::{quantize_slice, Fx};
use super::memory::WeightStore;
use super::pu::{Pu, PuConfig};
use super::resource::AccelConfig;
use super::schemes::Scheme;
use crate::infer::{Engine, InferOutput};
use crate::ivim::Param;
use crate::masks::{LayerPlan, MaskPlan, MaskSet};
use crate::model::{Manifest, Weights};

/// Words fetched per cycle during a weight load (burst width).
pub const LOAD_WORDS_PER_CYCLE: usize = 8;

/// Counters accumulated by a simulation run.
#[derive(Debug, Clone, Copy, Default)]
pub struct CycleStats {
    pub cycles: u64,
    /// Cycles in which the MAC array was streaming (vs load/drain).
    pub active_cycles: u64,
    pub weight_loads: u64,
    pub weight_words_loaded: u64,
    pub macs: u64,
}

impl CycleStats {
    pub fn merge(&mut self, o: &CycleStats) {
        self.cycles += o.cycles;
        self.active_cycles += o.active_cycles;
        self.weight_loads += o.weight_loads;
        self.weight_words_loaded += o.weight_words_loaded;
        self.macs += o.macs;
    }

    /// Wall-clock seconds at the given clock.
    pub fn seconds(&self, clock_hz: f64) -> f64 {
        self.cycles as f64 / clock_hz
    }
}

/// PLAN piecewise-linear sigmoid (Amin et al.), the standard FPGA
/// approximation; max error ~0.019.
pub fn plan_sigmoid(x: Fx) -> Fx {
    let neg = x.0 < 0;
    let ax = Fx(x.0.unsigned_abs().min(i16::MAX as u16) as i16);
    let xf = ax.to_f32();
    let y = if xf >= 5.0 {
        Fx::from_f32(1.0)
    } else if xf >= 2.375 {
        Fx::from_f32(0.03125 * xf + 0.84375)
    } else if xf >= 1.0 {
        Fx::from_f32(0.125 * xf + 0.625)
    } else {
        Fx::from_f32(0.25 * xf + 0.5)
    };
    if neg {
        Fx::ONE.sub(y)
    } else {
        y
    }
}

/// One quantised output column of a masked layer after offline BN
/// folding (stored for every column; masks select which are scheduled).
///
/// The BatchNorm affine is folded into the column weights offline
/// (standard FPGA quantisation flow): `h = (x·W + b)·scale + shift =
/// x·(W·scale) + (b·scale + shift)`.  Trained BN scales can exceed the
/// Q4.12 range (observed up to ~14x), so each column additionally gets a
/// power-of-two pre-shift `k`: weights/bias are stored divided by `2^k`
/// and the wide accumulator is barrel-shifted left by `k` before
/// saturation — free in fabric, bit-faithful here.
struct QuantColumn {
    weights: Vec<Fx>,
    bias: Fx,
    shift_k: u32,
}

/// One masked layer's quantised storage.
///
/// Mask lifecycle (the simulator-side half of the mask-lifecycle
/// refactor): **every** output column's folded-BN data is quantised
/// exactly once at construction, unmasked, into `dense` — the worst-case
/// capacity a resampled mask can ever need.  Which columns a sample
/// actually schedules is the per-sample `kept` index lists into that
/// block, so a [`QuantLayer::swap`] only re-fills index lists and the
/// [`WeightStore`] counts in place: no re-quantisation, no allocation.
/// Column quantisation is mask-independent, which is what makes a swap
/// bit-identical to a fresh build with the same masks.
struct QuantLayer {
    nb_in: usize,
    /// All `nb` output columns, quantised once from the folded-BN data.
    dense: Vec<QuantColumn>,
    /// Per sample: kept output column indices into `dense`, ascending
    /// (mask-zero skipping — dropped columns are never scheduled).
    kept: Vec<Vec<u32>>,
    store: WeightStore,
}

impl QuantLayer {
    #[allow(clippy::too_many_arguments)]
    fn build(
        nb: usize,
        w: &[f32],
        b: &[f32],
        g: &[f32],
        be: &[f32],
        m: &[f32],
        v: &[f32],
        mask: &MaskSet,
    ) -> QuantLayer {
        const EPS: f32 = 1e-5;
        let mut dense = Vec::with_capacity(nb);
        for o in 0..nb {
            let scale = g[o] / (v[o] + EPS).sqrt();
            let shift = be[o] - m[o] * scale;
            let col: Vec<f32> = (0..nb).map(|i| w[i * nb + o] * scale).collect();
            let bias = b[o] * scale + shift;
            // smallest k so the scaled column and bias fit Q4.12
            let maxabs = col
                .iter()
                .map(|x| x.abs())
                .fold(bias.abs(), f32::max);
            let mut k = 0u32;
            while maxabs / (1u32 << k) as f32 >= 7.9 && k < 12 {
                k += 1;
            }
            let div = (1u32 << k) as f32;
            dense.push(QuantColumn {
                weights: quantize_slice(
                    &col.iter().map(|x| x / div).collect::<Vec<_>>(),
                ),
                bias: Fx::from_f32(bias / div),
                shift_k: k,
            });
        }
        let kept = (0..mask.n)
            .map(|s| {
                // capacity = nb: a later swap may keep every column
                let mut ks = Vec::with_capacity(nb);
                ks.extend(mask.kept_indices(s).into_iter().map(|o| o as u32));
                ks
            })
            .collect();
        QuantLayer {
            nb_in: nb,
            dense,
            kept,
            store: WeightStore::from_mask(nb, mask),
        }
    }

    /// Re-select this layer's kept columns from a [`LayerPlan`], in place
    /// (index lists + store counts only; `dense` is never touched).
    fn swap(&mut self, plan: &LayerPlan) {
        assert_eq!(plan.width(), self.nb_in);
        assert_eq!(plan.n(), self.kept.len());
        for (s, ks) in self.kept.iter_mut().enumerate() {
            ks.clear();
            ks.extend_from_slice(plan.kept(s));
        }
        self.store
            .refresh_kept_counts(self.kept.iter().map(|k| k.len()));
    }

    /// Stored words for one sample (mask-skipped).
    fn words(&self, s: usize) -> usize {
        self.store.skipped_words(s)
    }

    /// Owned-buffer capacities (no-allocation witness for swap tests).
    fn alloc_signature(&self, sig: &mut Vec<usize>) {
        sig.push(self.dense.capacity());
        sig.push(self.store.kept_per_sample.capacity());
        sig.extend(self.kept.iter().map(|k| k.capacity()));
    }
}

/// Encoder layer (nb -> 1), dense (no mask).
struct QuantEncoder {
    w: Vec<Fx>,
    b: Fx,
}

struct QuantSubnet {
    param: Param,
    l1: QuantLayer,
    l2: QuantLayer,
    enc: QuantEncoder,
}

/// The simulator.  Owns quantised weights; evaluates batches in Q4.12
/// while counting cycles.
pub struct AccelSimulator {
    pub cfg: AccelConfig,
    /// Reusable PU state (config + chunk scratch) — every dot product in
    /// the layer loops goes through it, allocation-free.
    pu: Pu,
    nb: usize,
    n_samples: usize,
    scheme: Scheme,
    subnets: Vec<QuantSubnet>,
    /// Stats of the last `infer_batch` call.
    pub last_stats: CycleStats,
    // scratch reused across calls (hot path: no allocation)
    x0: Vec<Fx>,
    h1: Vec<Fx>,
    h2: Vec<Fx>,
}

impl AccelSimulator {
    pub fn new(
        man: &Manifest,
        weights: &Weights,
        cfg: AccelConfig,
        scheme: Scheme,
    ) -> anyhow::Result<AccelSimulator> {
        let mut subnets = Vec::with_capacity(4);
        for p in Param::ALL {
            let sn = p.name();
            let sw = weights.subnet(man, sn);
            let m1 = man
                .mask(sn, 1)
                .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.1"))?;
            let m2 = man
                .mask(sn, 2)
                .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.2"))?;
            subnets.push(QuantSubnet {
                param: p,
                l1: QuantLayer::build(man.nb, sw.w1, sw.b1, sw.g1, sw.be1, sw.m1, sw.v1, m1),
                l2: QuantLayer::build(man.nb, sw.w2, sw.b2, sw.g2, sw.be2, sw.m2, sw.v2, m2),
                enc: QuantEncoder {
                    w: quantize_slice(sw.w3),
                    b: Fx::from_f32(sw.b3[0]),
                },
            });
        }
        let pu = Pu::new(PuConfig {
            lanes: cfg.lanes.min(man.nb.next_power_of_two()),
            r_m: cfg.r_m,
            r_a: cfg.r_a,
        });
        let scratch = cfg.batch * man.nb;
        Ok(AccelSimulator {
            cfg,
            pu,
            nb: man.nb,
            n_samples: man.n_samples,
            scheme,
            subnets,
            last_stats: CycleStats::default(),
            x0: vec![Fx::ZERO; scratch],
            h1: vec![Fx::ZERO; scratch],
            h2: vec![Fx::ZERO; scratch],
        })
    }

    pub fn scheme(&self) -> Scheme {
        self.scheme
    }
    pub fn set_scheme(&mut self, s: Scheme) {
        self.scheme = s;
    }
    pub fn pu_config(&self) -> &PuConfig {
        self.pu.config()
    }

    /// Re-point the PE-count knob without rebuilding the datapath.
    /// Parallelism is a scheduling choice — numerics are invariant, only
    /// cycle/resource accounting changes — so a DSE sweep varies it on
    /// one live simulator instead of re-instantiating per point.
    pub fn set_n_pe(&mut self, n_pe: usize) {
        self.cfg.n_pe = n_pe;
    }

    /// Hot-swap the simulator's masks from a [`MaskPlan`] without
    /// touching the quantised weights or scratch: each masked layer
    /// re-selects its kept columns (index lists into the dense quantised
    /// block) and refreshes its [`WeightStore`] counts in place — zero
    /// steady-state allocation, mirroring `NativeEngine::swap_masks`.
    ///
    /// Contract: after a swap the simulator is **bit-for-bit** identical
    /// — outputs *and* cycle/load counters — to a freshly constructed
    /// `AccelSimulator` whose manifest carried the plan's masks.  The
    /// plan must match the simulator's shape (`nb`, `n_samples`) and
    /// subnet names; a rejected swap leaves the simulator untouched.
    pub fn swap_masks(&mut self, plan: &MaskPlan) -> anyhow::Result<()> {
        // Validate every lookup and layer shape BEFORE mutating anything:
        // a failed swap must never leave the datapath half-swapped.
        self.check_plan(plan)?;
        for sn in &mut self.subnets {
            let name = sn.param.name();
            for (layer, l) in [(1usize, &mut sn.l1), (2usize, &mut sn.l2)] {
                l.swap(plan.layer_for(name, layer).expect("validated above"));
            }
        }
        Ok(())
    }

    /// Every check [`AccelSimulator::swap_masks`] runs before mutating —
    /// exposed so the pipelined prep worker can validate a shadow plan
    /// off the critical path with the *same* rules the swap enforces
    /// (mutates nothing; `Ok` means the swap's validation would pass).
    pub fn check_plan(&self, plan: &MaskPlan) -> anyhow::Result<()> {
        anyhow::ensure!(
            plan.nb() == self.nb,
            "plan width {} != simulator width {}",
            plan.nb(),
            self.nb
        );
        anyhow::ensure!(
            plan.n_samples() == self.n_samples,
            "plan has {} samples, simulator runs {}",
            plan.n_samples(),
            self.n_samples
        );
        for sn in &self.subnets {
            let name = sn.param.name();
            for layer in [1usize, 2] {
                let lp = plan
                    .layer_for(name, layer)
                    .ok_or_else(|| anyhow::anyhow!("plan has no subnet '{name}'"))?;
                anyhow::ensure!(
                    lp.width() == self.nb && lp.n() == self.n_samples,
                    "plan layer {name}.{layer} is {}x{}, simulator needs {}x{}",
                    lp.n(),
                    lp.width(),
                    self.n_samples,
                    self.nb
                );
            }
        }
        Ok(())
    }

    /// Capacities of every owned buffer (scratch + per-layer stores) —
    /// stable across `swap_masks`/`execute_into_stats` calls in steady
    /// state (the no-allocation witness).
    pub fn alloc_signature(&self) -> Vec<usize> {
        let mut sig = vec![
            self.x0.capacity(),
            self.h1.capacity(),
            self.h2.capacity(),
            self.pu.alloc_signature(),
        ];
        for sn in &self.subnets {
            sn.l1.alloc_signature(&mut sig);
            sn.l2.alloc_signature(&mut sig);
        }
        sig
    }

    /// Weight stores of all masked layers (for the resource model).
    pub fn weight_stores(&self) -> Vec<WeightStore> {
        self.subnets
            .iter()
            .flat_map(|s| [s.l1.store.clone(), s.l2.store.clone()])
            .collect()
    }

    /// Cycles to load `words` weight words.
    fn load_cycles(words: usize) -> u64 {
        words.div_ceil(LOAD_WORDS_PER_CYCLE) as u64
    }

    /// Compute cycles for evaluating `kept` output neurons over `batch`
    /// voxels with the PE array (pipelined; one chunk per cycle per PE).
    fn compute_cycles(&self, kept: usize, batch: usize) -> (u64, u64) {
        let out_groups = kept.div_ceil(self.cfg.n_pe);
        let chunks = self.pu.config().chunks(self.nb);
        let fill = self.pu.config().latency_cycles(self.nb) as u64;
        let stream = (out_groups * batch * chunks) as u64;
        (fill + stream, stream)
    }

    /// Two-phase hot path: run one batch through the full model under
    /// the configured scheme, writing predictions into a caller-provided
    /// output and returning the cycle stats.  All simulator scratch
    /// (quantised input, layer activations) is pre-sized at construction
    /// — zero steady-state allocations.
    pub fn execute_into_stats(
        &mut self,
        signals: &[f32],
        out: &mut InferOutput,
    ) -> anyhow::Result<CycleStats> {
        let batch = self.cfg.batch;
        let nb = self.nb;
        anyhow::ensure!(
            signals.len() == batch * nb,
            "expected {batch}x{nb} signals, got {}",
            signals.len()
        );
        out.reset(self.n_samples, batch);
        // Scratch is moved out for the duration of the call so the
        // per-layer helper can borrow `self.pu` mutably (and the layers
        // immutably) alongside it.
        let mut x0 = std::mem::take(&mut self.x0);
        let mut h1 = std::mem::take(&mut self.h1);
        let mut h2 = std::mem::take(&mut self.h2);
        x0.clear();
        x0.extend(signals.iter().map(|&v| Fx::from_f32(v)));
        h1.clear();
        h1.resize(batch * nb, Fx::ZERO);
        h2.clear();
        h2.resize(batch * nb, Fx::ZERO);
        let mut stats = CycleStats::default();

        // The functional result is scheme-independent (verified by test);
        // cycle/load accounting follows the configured scheme.
        for sn in &self.subnets {
            for s in 0..self.n_samples {
                // layer 1
                stats.macs += eval_layer(&mut self.pu, nb, &sn.l1, s, &x0, batch, &mut h1);
                // layer 2
                stats.macs += eval_layer(&mut self.pu, nb, &sn.l2, s, &h1, batch, &mut h2);
                // encoder + PLAN sigmoid
                for v in 0..batch {
                    let x = &h2[v * nb..(v + 1) * nb];
                    let logit = self.pu.dot(x, &sn.enc.w, sn.enc.b);
                    let sig = plan_sigmoid(logit);
                    out.set(
                        sn.param,
                        s,
                        v,
                        sn.param.convert(sig.to_f32() as f64) as f32,
                    );
                    stats.macs += nb as u64;
                }
            }

            // Cycle accounting per layer under the scheme.
            for layer in [&sn.l1, &sn.l2] {
                for s in 0..self.n_samples {
                    let kept = layer.kept[s].len();
                    let words = layer.words(s);
                    let loads = match self.scheme {
                        Scheme::BatchLevel => 1usize,
                        Scheme::SamplingLevel => batch,
                    };
                    stats.weight_loads += loads as u64;
                    stats.weight_words_loaded += (loads * words) as u64;
                    let load_c = Self::load_cycles(words) * loads as u64;
                    let (c, active) = self.compute_cycles(kept, batch);
                    if self.cfg.overlap_loads {
                        // Double-buffered weight memories: the next
                        // sample's load hides behind this sample's
                        // compute; the sequence is bound by the larger.
                        stats.cycles += load_c.max(c);
                    } else {
                        stats.cycles += load_c + c;
                    }
                    stats.active_cycles += active;
                }
            }
            // encoder: dense single output, loaded once per batch per
            // sample (its weights are tiny).
            for _s in 0..self.n_samples {
                let words = nb + 1;
                stats.weight_loads += 1;
                stats.weight_words_loaded += words as u64;
                let load_c = Self::load_cycles(words);
                let (c, active) = self.compute_cycles(1, batch);
                if self.cfg.overlap_loads {
                    stats.cycles += load_c.max(c);
                } else {
                    stats.cycles += load_c + c;
                }
                stats.active_cycles += active;
            }
        }

        self.x0 = x0;
        self.h1 = h1;
        self.h2 = h2;
        self.last_stats = stats;
        Ok(stats)
    }

    /// Allocating wrapper over [`Self::execute_into_stats`] for cold
    /// paths (experiments, DSE sweeps).
    pub fn infer_batch_stats(
        &mut self,
        signals: &[f32],
    ) -> anyhow::Result<(InferOutput, CycleStats)> {
        let mut out = InferOutput::new(self.n_samples, self.cfg.batch);
        let stats = self.execute_into_stats(signals, &mut out)?;
        Ok((out, stats))
    }

    /// Latency of one batch in milliseconds at the configured clock.
    pub fn batch_latency_ms(&self, stats: &CycleStats) -> f64 {
        stats.seconds(self.cfg.clock_hz) * 1e3
    }
}

/// Evaluate one masked layer for one sample over the whole batch
/// (functional), accumulating into `out` (`[batch][nb]`) and returning
/// the MAC count.  A free function rather than a method so callers can
/// borrow the PU state mutably alongside `&self.subnets` — the borrows
/// are disjoint fields of the simulator.
fn eval_layer(
    pu: &mut Pu,
    nb: usize,
    layer: &QuantLayer,
    sample: usize,
    input: &[Fx],
    batch: usize,
    out: &mut [Fx],
) -> u64 {
    out.fill(Fx::ZERO);
    let mut macs = 0u64;
    for v in 0..batch {
        let x = &input[v * layer.nb_in..(v + 1) * layer.nb_in];
        for &ci in &layer.kept[sample] {
            let c = &layer.dense[ci as usize];
            // BN is folded into the stored weights; the accumulator
            // is barrel-shifted by the column's pre-shift before
            // saturating back to Q4.12 (see QuantColumn docs).
            let mut acc = pu.dot_acc(x, &c.weights);
            acc += (c.bias.0 as i64) << super::fixed::FRAC_BITS;
            acc <<= c.shift_k;
            out[v * nb + ci as usize] = super::fixed::sat_from_acc(acc).relu();
            macs += layer.nb_in as u64;
        }
    }
    macs
}

impl Engine for AccelSimulator {
    fn name(&self) -> &str {
        "fpga-sim-q4.12"
    }
    fn batch_size(&self) -> usize {
        self.cfg.batch
    }
    fn n_samples(&self) -> usize {
        self.n_samples
    }
    fn execute_into(&mut self, signals: &[f32], out: &mut InferOutput) -> anyhow::Result<()> {
        self.execute_into_stats(signals, out).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::native::NativeEngine;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    /// Artifacts when exported, else the deterministic in-tree fixture
    /// (same shapes) — these tests never skip.
    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    fn cfg_for(man: &Manifest) -> AccelConfig {
        AccelConfig {
            batch: man.batch_infer,
            ..Default::default()
        }
    }

    #[test]
    fn plan_sigmoid_accuracy() {
        for i in -80..=80 {
            let x = i as f32 * 0.1;
            let want = 1.0 / (1.0 + (-x).exp());
            let got = plan_sigmoid(Fx::from_f32(x)).to_f32();
            assert!((got - want).abs() < 0.022, "x={x}: {got} vs {want}");
        }
        assert_eq!(plan_sigmoid(Fx::from_f32(7.0)).to_f32(), 1.0);
    }

    #[test]
    fn matches_native_engine_within_quantisation() {
        let Some((man, w)) = setup() else { return };
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut native = NativeEngine::new(&man, &w).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 5);
        let a = sim.infer_batch(&ds.signals).unwrap();
        let b = native.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            let (lo, hi) = p.range();
            // quantisation (Q4.12 through 3 layers) + PLAN sigmoid error,
            // scaled into the parameter range
            let tol = (hi - lo) * 0.05;
            for s in 0..a.n_samples {
                for v in 0..a.batch {
                    let d = (a.get(p, s, v) - b.get(p, s, v)).abs() as f64;
                    assert!(d <= tol, "{p:?} s{s} v{v}: diff {d} > {tol}");
                }
            }
        }
    }

    #[test]
    fn schemes_are_bit_identical_in_results() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 6);
        let mut sim_b =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut sim_s =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::SamplingLevel).unwrap();
        let a = sim_b.infer_batch(&ds.signals).unwrap();
        let b = sim_s.infer_batch(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(a.samples[p.index()], b.samples[p.index()]);
        }
    }

    #[test]
    fn batch_level_reduces_weight_loads_by_batchsize() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 7);
        let mut sim_b =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut sim_s =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::SamplingLevel).unwrap();
        let (_, st_b) = sim_b.infer_batch_stats(&ds.signals).unwrap();
        let (_, st_s) = sim_s.infer_batch_stats(&ds.signals).unwrap();
        // masked layers re-load batchsize x (encoder always 1/batch)
        assert_eq!(
            st_s.weight_words_loaded - (st_b.weight_words_loaded - masked_words(&sim_b)),
            masked_words(&sim_b) * man.batch_infer as u64,
        );
        assert!(st_s.cycles > st_b.cycles);
    }

    fn masked_words(sim: &AccelSimulator) -> u64 {
        sim.weight_stores()
            .iter()
            .map(|s| s.total_skipped_words() as u64)
            .sum()
    }

    #[test]
    fn mask_zero_skipping_reduces_cycles() {
        let Some((man, w)) = setup() else { return };
        // With ~half the neurons masked out, active cycles must be well
        // below the dense op count.
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 8);
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let (_, st) = sim.infer_batch_stats(&ds.signals).unwrap();
        // dense macs = 4 subnets * N * batch * (2*nb^2 + nb)
        let nb = man.nb as u64;
        let dense = 4 * man.n_samples as u64 * man.batch_infer as u64 * (2 * nb * nb + nb);
        assert!(st.macs < dense, "macs {} !< dense {}", st.macs, dense);
        assert!(st.macs > dense / 4);
    }

    #[test]
    fn overlap_loads_saves_cycles_not_accuracy() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 10);
        let base = cfg_for(&man);
        let over = AccelConfig {
            overlap_loads: true,
            ..base
        };
        let mut a = AccelSimulator::new(&man, &w, base, Scheme::BatchLevel).unwrap();
        let mut b = AccelSimulator::new(&man, &w, over, Scheme::BatchLevel).unwrap();
        let (oa, sa) = a.infer_batch_stats(&ds.signals).unwrap();
        let (ob, sb) = b.infer_batch_stats(&ds.signals).unwrap();
        assert!(sb.cycles < sa.cycles, "{} !< {}", sb.cycles, sa.cycles);
        for p in Param::ALL {
            assert_eq!(oa.samples[p.index()], ob.samples[p.index()]);
        }
    }

    #[test]
    fn deterministic_stats() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 9);
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let (_, s1) = sim.infer_batch_stats(&ds.signals).unwrap();
        let (_, s2) = sim.infer_batch_stats(&ds.signals).unwrap();
        assert_eq!(s1.cycles, s2.cycles);
        assert_eq!(s1.weight_words_loaded, s2.weight_words_loaded);
    }

    /// Golden pin of the PLAN piecewise bounds in Q4.12 (ISSUE #5): the
    /// exact breakpoints |x| = 1.0 / 2.375 / 5.0, `Fx` saturation at the
    /// `i16::MIN` input, and the negative-side symmetry σ(-x) = 1 - σ(x).
    /// Raw values: 0.75 = 3072/4096, 0.91796875 = 3760/4096, 1.0 = 4096.
    #[test]
    fn plan_sigmoid_breakpoint_goldens() {
        use crate::accel::fixed::{MAX_RAW, MIN_RAW};
        // positive breakpoints land exactly on the segment formulae
        assert_eq!(plan_sigmoid(Fx::from_f32(1.0)), Fx(3072));
        assert_eq!(plan_sigmoid(Fx::from_f32(2.375)), Fx(3760));
        assert_eq!(plan_sigmoid(Fx::from_f32(5.0)), Fx(4096));
        assert_eq!(plan_sigmoid(Fx::ZERO), Fx(2048)); // σ(0) = 0.5
        // negative side: exact Q4.12 complements
        assert_eq!(plan_sigmoid(Fx::from_f32(-1.0)), Fx(4096 - 3072));
        assert_eq!(plan_sigmoid(Fx::from_f32(-2.375)), Fx(4096 - 3760));
        assert_eq!(plan_sigmoid(Fx::from_f32(-5.0)), Fx(0));
        // Fx saturation: i16::MIN has no positive counterpart — the
        // |x| clamp must saturate to MAX_RAW, not wrap, giving σ = 0.
        assert_eq!(plan_sigmoid(Fx(MIN_RAW)), Fx(0));
        assert_eq!(plan_sigmoid(Fx(MAX_RAW)), Fx::ONE);
        // σ(-x) = 1 - σ(x) holds bit-exactly across the whole range
        for i in 0..=80 {
            let x = Fx::from_f32(i as f32 * 0.1);
            let neg = Fx(-x.0);
            assert_eq!(
                plan_sigmoid(neg),
                Fx::ONE.sub(plan_sigmoid(x)),
                "symmetry broken at x = {}",
                x.to_f32()
            );
        }
    }

    /// Tentpole golden gate (ISSUE #5): a hot mask swap on a live
    /// simulator must be **bit-for-bit** indistinguishable — outputs AND
    /// cycle/load counters — from tearing the simulator down and
    /// rebuilding it with the new masks baked into the manifest.
    #[test]
    fn swap_masks_matches_fresh_simulator_bit_for_bit() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let Some((man, w)) = setup() else { return };
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut plan = MaskPlan::from_manifest(&man).unwrap();
        let mut rng = Pcg32::new(71);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 41);
        for round in 0..4 {
            plan.resample(&mut rng);
            sim.swap_masks(&plan).unwrap();
            let (a, sa) = sim.infer_batch_stats(&ds.signals).unwrap();
            let mut man2 = man.clone();
            plan.apply_to_manifest(&mut man2);
            let mut fresh =
                AccelSimulator::new(&man2, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
            let (b, sb) = fresh.infer_batch_stats(&ds.signals).unwrap();
            for p in Param::ALL {
                assert_eq!(
                    a.samples[p.index()],
                    b.samples[p.index()],
                    "round {round}: swap != fresh for {p:?}"
                );
            }
            assert_eq!(sa.cycles, sb.cycles, "round {round}: cycle counters diverged");
            assert_eq!(sa.active_cycles, sb.active_cycles, "round {round}");
            assert_eq!(sa.weight_loads, sb.weight_loads, "round {round}");
            assert_eq!(
                sa.weight_words_loaded, sb.weight_words_loaded,
                "round {round}: load counters diverged"
            );
            assert_eq!(sa.macs, sb.macs, "round {round}: mac counters diverged");
        }
    }

    /// Swapping back to the manifest's own masks restores outputs and
    /// counters exactly (nothing beyond the index lists mutated).
    #[test]
    fn swap_masks_roundtrips_to_original() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let Some((man, w)) = setup() else { return };
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 42);
        let (original, st0) = sim.infer_batch_stats(&ds.signals).unwrap();
        let mut plan = MaskPlan::from_manifest(&man).unwrap();
        let mut rng = Pcg32::new(6);
        plan.resample(&mut rng);
        sim.swap_masks(&plan).unwrap();
        let baked = MaskPlan::from_manifest(&man).unwrap();
        sim.swap_masks(&baked).unwrap();
        let (restored, st1) = sim.infer_batch_stats(&ds.signals).unwrap();
        for p in Param::ALL {
            assert_eq!(original.samples[p.index()], restored.samples[p.index()]);
        }
        assert_eq!(st0.cycles, st1.cycles);
        assert_eq!(st0.weight_words_loaded, st1.weight_words_loaded);
    }

    /// The swap path must stay inside the capacity reserved at
    /// construction: 100 resample/swap/execute cycles without a single
    /// reallocation, even when the resampled union grows.
    #[test]
    fn swap_masks_never_reallocates_over_100_cycles() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let Some((man, w)) = setup() else { return };
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut plan = MaskPlan::from_manifest(&man).unwrap();
        let mut rng = Pcg32::new(12);
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 43);
        let mut out = InferOutput::new(man.n_samples, man.batch_infer);
        sim.execute_into_stats(&ds.signals, &mut out).unwrap();
        let sig = sim.alloc_signature();
        for i in 0..100 {
            plan.resample(&mut rng);
            sim.swap_masks(&plan).unwrap();
            sim.execute_into_stats(&ds.signals, &mut out).unwrap();
            assert_eq!(sim.alloc_signature(), sig, "cycle {i}: swap or execute reallocated");
        }
    }

    #[test]
    fn swap_masks_rejects_mismatched_plans() {
        use crate::masks::MaskPlan;
        use crate::testing::fixture;
        let (man, w) = fixture::tiny_fixture();
        let mut sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        // wrong width
        let (other, _) = fixture::build(&fixture::FixtureConfig {
            nb: 17,
            ..Default::default()
        });
        let wrong_width = MaskPlan::from_manifest(&other).unwrap();
        assert!(sim.check_plan(&wrong_width).is_err());
        assert!(sim.swap_masks(&wrong_width).is_err());
        // wrong sample count
        assert!(sim.swap_masks(&MaskPlan::all_ones(&man, man.n_samples + 1)).is_err());
        // a rejected swap leaves the simulator fully functional
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 44);
        assert!(sim.infer_batch(&ds.signals).is_ok());
    }

    /// The pipelined hand-off's core lemma, proven at the simulator
    /// level: resampling a *stale cloned shadow* plan with the serial
    /// RNG stream and swapping it in is bit-identical — outputs AND
    /// cycle counters — to resampling the live plan inline.
    #[test]
    fn swap_from_cloned_shadow_plan_matches_inline_resample() {
        use crate::masks::MaskPlan;
        use crate::util::rng::Pcg32;
        let Some((man, w)) = setup() else { return };
        let mut inline_sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut shadow_sim =
            AccelSimulator::new(&man, &w, cfg_for(&man), Scheme::BatchLevel).unwrap();
        let mut live = MaskPlan::from_manifest(&man).unwrap();
        // The shadow starts as a clone but is deliberately diverged so
        // the test would catch any prior-state dependence in resample.
        let mut shadow = live.clone();
        let mut scratch_rng = Pcg32::new(999);
        shadow.resample(&mut scratch_rng);
        let mut rng_inline = Pcg32::new(77);
        let mut rng_shadow = rng_inline.clone();
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 45);
        for round in 0..4 {
            live.resample(&mut rng_inline);
            inline_sim.swap_masks(&live).unwrap();
            shadow.resample(&mut rng_shadow);
            shadow_sim.check_plan(&shadow).unwrap();
            shadow_sim.swap_masks(&shadow).unwrap();
            let (a, sa) = inline_sim.infer_batch_stats(&ds.signals).unwrap();
            let (b, sb) = shadow_sim.infer_batch_stats(&ds.signals).unwrap();
            for p in Param::ALL {
                assert_eq!(
                    a.samples[p.index()],
                    b.samples[p.index()],
                    "round {round}: shadow swap != inline resample for {p:?}"
                );
            }
            assert_eq!(sa.cycles, sb.cycles, "round {round}: cycle counters diverged");
            assert_eq!(sa.macs, sb.macs, "round {round}: mac counters diverged");
        }
    }
}
