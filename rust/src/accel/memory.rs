//! On-chip memory model: I/O manager (voxel + output store), mask-zero-
//! skipped weight memories, and the intermediate layer cache (paper §V-B).
//!
//! All sizes in 16-bit words.  BRAM36 blocks hold 36 Kib = 2048 words of
//! 18 bits; we model 2048 16-bit words per block.

use crate::masks::MaskSet;

/// Words per BRAM36 block (36Kib at 18-bit width -> 2048 entries; we
/// store 16-bit words).
pub const WORDS_PER_BRAM36: usize = 2048;

/// I/O manager: stores a window of input voxels and the per-sample
/// outputs (paper: 20k voxels on chip, batch of 64).
#[derive(Debug, Clone)]
pub struct IoManager {
    pub voxel_capacity: usize,
    pub nb: usize,
    pub n_samples: usize,
}

impl IoManager {
    pub fn new(voxel_capacity: usize, nb: usize, n_samples: usize) -> Self {
        IoManager {
            voxel_capacity,
            nb,
            n_samples,
        }
    }

    /// Input store size in 16-bit words.
    pub fn input_words(&self) -> usize {
        self.voxel_capacity * self.nb
    }

    /// Output store: 4 IVIM parameters x N samples per voxel.
    pub fn output_words(&self) -> usize {
        self.voxel_capacity * 4 * self.n_samples
    }

    pub fn bram36(&self) -> usize {
        (self.input_words() + self.output_words()).div_ceil(WORDS_PER_BRAM36)
    }

    /// Batches needed to stream `n` voxels through a `batch`-sized window.
    pub fn batches_for(&self, n: usize, batch: usize) -> usize {
        n.div_ceil(batch)
    }
}

/// Mask-zero-skipped weight store for one layer of one sub-network
/// (paper §V-C, Fig. 4): only the weights of *kept* output neurons are
/// stored, one copy per mask sample.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub nb: usize,
    /// kept output counts per sample.
    pub kept_per_sample: Vec<usize>,
}

impl WeightStore {
    pub fn from_mask(nb: usize, mask: &MaskSet) -> Self {
        WeightStore {
            nb,
            kept_per_sample: (0..mask.n).map(|s| mask.ones(s)).collect(),
        }
    }

    /// Refresh the per-sample kept counts in place (the mask-swap path:
    /// the store is sized once for its sample count and only the counts
    /// change when masks are hot-swapped — no allocation).
    pub fn refresh_kept_counts(&mut self, kept: impl IntoIterator<Item = usize>) {
        let mut it = kept.into_iter();
        let mut n = 0usize;
        for slot in self.kept_per_sample.iter_mut() {
            let Some(k) = it.next() else { break };
            *slot = k;
            n += 1;
        }
        assert_eq!(n, self.kept_per_sample.len(), "fewer kept counts than samples");
        assert!(it.next().is_none(), "more kept counts than samples");
    }

    /// Dense (no skipping) words for one sample: full `nb x nb` weights +
    /// nb biases + 2*nb folded-BN terms.
    pub fn dense_words_per_sample(&self) -> usize {
        self.nb * self.nb + 3 * self.nb
    }

    /// Stored words for sample `s` with mask-zero skipping: only kept
    /// output columns keep their `nb` weights + bias + BN terms.
    pub fn skipped_words(&self, s: usize) -> usize {
        let kept = self.kept_per_sample[s];
        kept * self.nb + 3 * kept
    }

    /// Total words across samples with skipping.
    pub fn total_skipped_words(&self) -> usize {
        (0..self.kept_per_sample.len())
            .map(|s| self.skipped_words(s))
            .sum()
    }

    /// Total words without skipping (what an MC-Dropout design stores,
    /// plus it needs the runtime sampler — paper Fig. 4 left).
    pub fn total_dense_words(&self) -> usize {
        self.kept_per_sample.len() * self.dense_words_per_sample()
    }

    /// Storage saved by mask-zero skipping.
    pub fn savings_ratio(&self) -> f64 {
        1.0 - self.total_skipped_words() as f64 / self.total_dense_words() as f64
    }
}

/// Intermediate layer cache: double-buffered activations for one batch
/// (paper §V-B: results of early layers, or partial results when the
/// layer is wider than the PE array).
#[derive(Debug, Clone)]
pub struct LayerCache {
    pub batch: usize,
    pub nb: usize,
}

impl LayerCache {
    pub fn words(&self) -> usize {
        2 * self.batch * self.nb // ping-pong buffers
    }
    pub fn bram36(&self) -> usize {
        self.words().div_ceil(WORDS_PER_BRAM36)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masks::for_width;

    #[test]
    fn io_manager_paper_configuration() {
        // Paper §VI-A: 20k voxels, 104 b-values, 4 samples.
        let io = IoManager::new(20_000, 104, 4);
        assert_eq!(io.input_words(), 20_000 * 104);
        assert_eq!(io.output_words(), 20_000 * 16);
        // ~2.08M + 320k words -> over 1000 BRAM36
        assert!(io.bram36() > 1000);
        assert_eq!(io.batches_for(20_000, 64), 313);
    }

    #[test]
    fn weight_store_skipping_saves_memory() {
        let mask = for_width(104, 4, 2.0, 1).unwrap();
        let ws = WeightStore::from_mask(104, &mask);
        assert!(ws.total_skipped_words() < ws.total_dense_words());
        // scale 2.0 -> roughly half the neurons kept -> ~50% savings
        let r = ws.savings_ratio();
        assert!(r > 0.35 && r < 0.65, "savings {r}");
    }

    #[test]
    fn weight_store_all_ones_mask_no_savings() {
        let mask = for_width(16, 4, 1.0, 0).unwrap();
        let ws = WeightStore::from_mask(16, &mask);
        assert_eq!(ws.total_skipped_words(), ws.total_dense_words());
        assert_eq!(ws.savings_ratio(), 0.0);
    }

    #[test]
    fn refresh_kept_counts_updates_words_without_realloc() {
        let mask = for_width(16, 4, 2.0, 3).unwrap();
        let mut ws = WeightStore::from_mask(16, &mask);
        let cap = ws.kept_per_sample.capacity();
        ws.refresh_kept_counts([1usize, 2, 3, 4]);
        assert_eq!(ws.kept_per_sample, vec![1, 2, 3, 4]);
        assert_eq!(ws.skipped_words(3), 4 * 16 + 3 * 4);
        assert_eq!(ws.kept_per_sample.capacity(), cap);
    }

    #[test]
    fn layer_cache_words() {
        let c = LayerCache { batch: 64, nb: 104 };
        assert_eq!(c.words(), 2 * 64 * 104);
        assert!(c.bram36() >= 6);
    }
}
