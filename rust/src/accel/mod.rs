//! Cycle-level simulator of the paper's FPGA accelerator (§V) — the
//! hardware substrate of this reproduction (DESIGN.md §5: the physical
//! VU13P is replaced by this model; the paper's hardware claims are
//! architectural and the simulator reproduces exactly those mechanisms).
//!
//! Components, mapped to the paper:
//!
//! | Paper | Module |
//! |---|---|
//! | 16-bit fixed-point, 4 integer bits (§VI-A) | [`fixed`] |
//! | PU: parallel multipliers + pipelined adder tree, eq. (2) (§V-C) | [`pu`] |
//! | I/O manager, intermediate layer cache, weight memories (§V-B) | [`memory`] |
//! | Mask-zero skipping (§V-C, Fig. 4) | [`sim`] (`QuantLayer`: only kept outputs stored/scheduled) |
//! | Sampling-level vs batch-level schemes (§V-D, Fig. 5) | [`schemes`], accounted in [`sim`] |
//! | Controller state machine (§V-D) | [`sim`] (`infer_batch_stats` schedule) |
//! | Latency model (§III Phase 3, eq. 2) | [`latency`] (cross-checked == simulator) |
//! | VU13P resources (Fig. 8) | [`resource`] |
//! | Power / energy (Tables I, II) | [`power`] |
//! | PE-count design space (Fig. 8) | [`dse`] |

pub mod dse;
pub mod fixed;
pub mod latency;
pub mod memory;
pub mod power;
pub mod pu;
pub mod resource;
pub mod schemes;
pub mod sim;

pub use power::MaskSampler;
pub use resource::AccelConfig;
pub use schemes::Scheme;
pub use sim::{AccelSimulator, CycleStats};
