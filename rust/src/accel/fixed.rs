//! Q4.12 fixed-point arithmetic — the paper's quantisation scheme
//! ("16-bit fixed-point representation with 4 integer bits", §VI-A).
//!
//! Layout: 1 sign + 3 integer + 12 fractional bits, value range
//! [-8, 8 - 2^-12].  All ops saturate (no wrap-around), matching the
//! conventional FPGA datapath.  Multiplication uses a 32-bit product with
//! round-half-up on the dropped fractional bits, and the PU's adder tree
//! accumulates in 32-bit before the final saturation back to Q4.12 —
//! mirrored exactly by [`crate::accel::pu`].

/// Fractional bits of the Q4.12 format.
pub const FRAC_BITS: u32 = 12;
/// Scale factor 2^12.
pub const SCALE: i32 = 1 << FRAC_BITS;
/// Maximum representable raw value (+7.999756).
pub const MAX_RAW: i16 = i16::MAX;
/// Minimum representable raw value (-8.0).
pub const MIN_RAW: i16 = i16::MIN;

/// A Q4.12 fixed-point number.
///
/// `repr(transparent)` over the raw `i16` so `&[Fx]` can be viewed as
/// `&[i16]` ([`raw_slice`]) for the SIMD chunk-MAC without copying.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
#[repr(transparent)]
pub struct Fx(pub i16);

#[inline]
fn sat16(v: i32) -> i16 {
    if v > MAX_RAW as i32 {
        MAX_RAW
    } else if v < MIN_RAW as i32 {
        MIN_RAW
    } else {
        v as i16
    }
}

impl Fx {
    pub const ZERO: Fx = Fx(0);
    pub const ONE: Fx = Fx(SCALE as i16);

    /// Quantise an f32 (round to nearest, saturate).
    pub fn from_f32(v: f32) -> Fx {
        let scaled = (v as f64 * SCALE as f64).round();
        if scaled > MAX_RAW as f64 {
            Fx(MAX_RAW)
        } else if scaled < MIN_RAW as f64 {
            Fx(MIN_RAW)
        } else {
            Fx(scaled as i16)
        }
    }

    /// Back to f32.
    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / SCALE as f32
    }

    /// Saturating addition.
    #[inline]
    pub fn add(self, rhs: Fx) -> Fx {
        Fx(sat16(self.0 as i32 + rhs.0 as i32))
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sub(self, rhs: Fx) -> Fx {
        Fx(sat16(self.0 as i32 - rhs.0 as i32))
    }

    /// Saturating multiplication with round-half-up.
    #[inline]
    pub fn mul(self, rhs: Fx) -> Fx {
        let prod = self.0 as i32 * rhs.0 as i32; // Q8.24 in 32 bits
        let rounded = (prod + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
        Fx(sat16(rounded))
    }

    /// Raw product in Q8.24 (for tree accumulation in i32/i64).
    #[inline]
    pub fn mul_raw(self, rhs: Fx) -> i32 {
        self.0 as i32 * rhs.0 as i32
    }

    /// ReLU.
    #[inline]
    pub fn relu(self) -> Fx {
        if self.0 < 0 {
            Fx(0)
        } else {
            self
        }
    }

    /// Quantisation step (resolution).
    pub fn epsilon() -> f32 {
        1.0 / SCALE as f32
    }
}

/// Saturate a wide Q8.24 accumulator back to Q4.12 with rounding.
#[inline]
pub fn sat_from_acc(acc: i64) -> Fx {
    let rounded = (acc + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
    if rounded > MAX_RAW as i64 {
        Fx(MAX_RAW)
    } else if rounded < MIN_RAW as i64 {
        Fx(MIN_RAW)
    } else {
        Fx(rounded as i16)
    }
}

/// View a slice of Q4.12 values as their raw `i16` bits, zero-copy.
#[inline]
pub fn raw_slice(xs: &[Fx]) -> &[i16] {
    // SAFETY: Fx is repr(transparent) over i16 — same size, alignment
    // and validity; the lifetime is inherited from the input borrow.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const i16, xs.len()) }
}

/// Quantise a whole f32 slice.
pub fn quantize_slice(xs: &[f32]) -> Vec<Fx> {
    xs.iter().map(|&v| Fx::from_f32(v)).collect()
}

/// Max |quantised - original| over a slice (for error reporting).
pub fn quantization_error(xs: &[f32]) -> f32 {
    xs.iter()
        .map(|&v| (Fx::from_f32(v).to_f32() - v).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    #[test]
    fn roundtrip_within_epsilon() {
        for v in [-7.5f32, -1.0, -0.001, 0.0, 0.5, 1.0, 3.25, 7.9] {
            let q = Fx::from_f32(v).to_f32();
            assert!((q - v).abs() <= Fx::epsilon() / 2.0 + 1e-7, "{v} -> {q}");
        }
    }

    #[test]
    fn saturates_at_bounds() {
        assert_eq!(Fx::from_f32(100.0), Fx(MAX_RAW));
        assert_eq!(Fx::from_f32(-100.0), Fx(MIN_RAW));
        assert_eq!(Fx(MAX_RAW).add(Fx::ONE), Fx(MAX_RAW));
        assert_eq!(Fx(MIN_RAW).sub(Fx::ONE), Fx(MIN_RAW));
        assert_eq!(Fx::from_f32(7.0).mul(Fx::from_f32(7.0)), Fx(MAX_RAW));
    }

    #[test]
    fn exact_small_arithmetic() {
        let a = Fx::from_f32(1.5);
        let b = Fx::from_f32(0.25);
        assert_eq!(a.add(b).to_f32(), 1.75);
        assert_eq!(a.sub(b).to_f32(), 1.25);
        assert_eq!(a.mul(b).to_f32(), 0.375);
        assert_eq!(Fx::ONE.mul(a), a);
        assert_eq!(Fx::ZERO.mul(a), Fx::ZERO);
    }

    #[test]
    fn relu_behaviour() {
        assert_eq!(Fx::from_f32(-1.0).relu(), Fx::ZERO);
        let p = Fx::from_f32(2.5);
        assert_eq!(p.relu(), p);
    }

    #[test]
    fn acc_saturation() {
        assert_eq!(sat_from_acc(i64::MAX / 2), Fx(MAX_RAW));
        assert_eq!(sat_from_acc(i64::MIN / 2), Fx(MIN_RAW));
        assert_eq!(sat_from_acc(0), Fx::ZERO);
        // 1.0 * 1.0 accumulated once = 1.0
        assert_eq!(sat_from_acc(Fx::ONE.mul_raw(Fx::ONE) as i64), Fx::ONE);
    }

    #[test]
    fn mul_matches_float_within_epsilon() {
        forall(
            300,
            crate::testing::zip(Gen::f64_in(-2.5, 2.5), Gen::f64_in(-2.5, 2.5)),
            |&(a, b): &(f64, f64)| {
                let fa = Fx::from_f32(a as f32);
                let fb = Fx::from_f32(b as f32);
                let got = fa.mul(fb).to_f32() as f64;
                let want = (fa.to_f32() * fb.to_f32()) as f64;
                (got - want).abs() <= 1.5 * Fx::epsilon() as f64
            },
        );
    }

    #[test]
    fn add_monotone_property() {
        forall(
            200,
            crate::testing::zip(Gen::f64_in(-7.0, 7.0), Gen::f64_in(0.0, 1.0)),
            |&(a, d): &(f64, f64)| {
                let x = Fx::from_f32(a as f32);
                let y = Fx::from_f32((a + d) as f32);
                x <= y
            },
        );
    }

    #[test]
    fn raw_slice_is_a_transparent_view() {
        let xs = vec![Fx(0), Fx(1), Fx(-1), Fx(MAX_RAW), Fx(MIN_RAW)];
        let raw = raw_slice(&xs);
        assert_eq!(raw.len(), xs.len());
        for (f, r) in xs.iter().zip(raw) {
            assert_eq!(f.0, *r);
        }
        assert!(raw_slice(&[]).is_empty());
    }

    #[test]
    fn quantization_error_bounded() {
        let xs: Vec<f32> = (-100..100).map(|i| i as f32 * 0.07).collect();
        assert!(quantization_error(&xs) <= Fx::epsilon() / 2.0 + 1e-7);
    }
}
