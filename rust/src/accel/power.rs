//! Power model (paper Tables I/II substrate).
//!
//! Decomposition: static + DSP dynamic + BRAM access + weight-load
//! energy.  The split follows Horowitz (ISSCC'14 [8]): on-chip SRAM/BRAM
//! accesses cost an order of magnitude more than arithmetic, which is why
//! the paper's batch-level scheme (cutting weight loads by `batchsize`x)
//! is the headline power optimisation, and why designs with runtime
//! Bernoulli samplers + dropout modules ([33][35][36]) burn more.
//!
//! Constants are calibrated so the paper's shipped configuration (32 PEs,
//! 250 MHz, batch-level) lands on its reported 11.78 W; the *shape*
//! (scaling with N_PE, scheme contrast) comes from the model structure.

use super::resource::{AccelConfig, ResourceUsage};
use super::sim::CycleStats;

/// Static (leakage + clocking) watts for the VU13P at 250 MHz.
pub const P_STATIC_W: f64 = 3.2;
/// Dynamic watts per active DSP slice at 250 MHz, 16-bit operands.
pub const P_DSP_W: f64 = 0.90e-3;
/// Dynamic watts per BRAM36 block held active.
pub const P_BRAM_W: f64 = 0.25e-3;
/// Per-PE infrastructure power (clock tree, register files, control) —
/// calibrated so the paper's shipped point (32 PE, 250 MHz, batch-level)
/// lands near its reported 11.78 W.
pub const P_PE_W: f64 = 0.21;
/// Energy per 16-bit word fetched during a weight load (J).  BRAM read +
/// distribution network; ~10x a MAC per Horowitz.
pub const E_WEIGHT_WORD_J: f64 = 12.0e-12;
/// Energy per runtime Bernoulli sample + dropout mux (J/weight) — charged
/// only to MC-Dropout-style designs (paper Fig. 4 left), used by the
/// ablation in Table I discussion.
pub const E_SAMPLER_J: f64 = 6.0e-12;

/// Where the modelled design's Bernoulli masks come from — decides
/// whether [`estimate`] charges the runtime sampler energy
/// ([`E_SAMPLER_J`]).  A named enum so call sites read as the design
/// they model, not a bare `false`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskSampler {
    /// Masks folded offline (uIVIM-NET / Masksembles): no sampler
    /// hardware, no sampler energy.
    Offline,
    /// Masks drawn at runtime (MC-Dropout-style prior designs
    /// [33][35][36], paper Fig. 4 left): charges [`E_SAMPLER_J`] per
    /// loaded weight word.
    Runtime,
}

/// Power/energy report for one simulated run.
#[derive(Debug, Clone, Copy)]
pub struct PowerReport {
    /// Average power over the run (W).
    pub watts: f64,
    /// Energy for the whole run (J).
    pub energy_j: f64,
    /// Energy attributable to weight loading (J).
    pub weight_load_j: f64,
    /// Runtime of the run (s).
    pub seconds: f64,
}

impl PowerReport {
    pub fn energy_mj(&self) -> f64 {
        self.energy_j * 1e3
    }
}

/// Estimate power for a run described by `stats` on configuration `cfg`
/// with resource usage `usage`.
///
/// `sampler`: [`MaskSampler::Runtime`] charges the MC-Dropout sampler
/// energy (for modelling the prior designs the paper compares against);
/// [`MaskSampler::Offline`] for uIVIM-NET, whose masks are folded
/// offline.
pub fn estimate(
    cfg: &AccelConfig,
    usage: &ResourceUsage,
    stats: &CycleStats,
    sampler: MaskSampler,
) -> PowerReport {
    let seconds = stats.cycles as f64 / cfg.clock_hz;
    // Utilisation-scaled DSP power: fraction of cycles the MAC array is
    // actually streaming.
    let util = if stats.cycles == 0 {
        0.0
    } else {
        stats.active_cycles as f64 / stats.cycles as f64
    };
    let p_dsp = usage.dsp as f64 * P_DSP_W * util;
    let p_bram = usage.bram36 as f64 * P_BRAM_W * 1.0;
    let p_pe = usage.n_pe as f64 * P_PE_W;
    let base_w = P_STATIC_W + p_dsp + p_bram + p_pe;

    let mut weight_load_j = stats.weight_words_loaded as f64 * E_WEIGHT_WORD_J;
    if sampler == MaskSampler::Runtime {
        weight_load_j += stats.weight_words_loaded as f64 * E_SAMPLER_J;
    }
    let energy_j = base_w * seconds + weight_load_j;
    let watts = if seconds > 0.0 {
        energy_j / seconds
    } else {
        base_w
    };
    PowerReport {
        watts,
        energy_j,
        weight_load_j,
        seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(cycles: u64, loads: u64) -> CycleStats {
        CycleStats {
            cycles,
            active_cycles: (cycles as f64 * 0.9) as u64,
            weight_loads: 4,
            weight_words_loaded: loads,
            macs: cycles * 32 * 128,
        }
    }

    fn usage32() -> ResourceUsage {
        ResourceUsage {
            n_pe: 32,
            dsp: 8192,
            bram36: 1300,
            lut: 500_000,
            io: 300,
        }
    }

    #[test]
    fn more_loads_more_power() {
        let cfg = AccelConfig::default();
        let u = usage32();
        let a = estimate(&cfg, &u, &stats(100_000, 10_000), MaskSampler::Offline);
        let b = estimate(&cfg, &u, &stats(100_000, 10_000 * 64), MaskSampler::Offline);
        assert!(b.watts > a.watts, "{} !> {}", b.watts, a.watts);
        assert!(b.weight_load_j > a.weight_load_j * 50.0);
    }

    #[test]
    fn sampler_energy_only_for_mc_dropout() {
        let cfg = AccelConfig::default();
        let u = usage32();
        let s = stats(100_000, 500_000);
        let ours = estimate(&cfg, &u, &s, MaskSampler::Offline);
        let mcd = estimate(&cfg, &u, &s, MaskSampler::Runtime);
        assert!(mcd.energy_j > ours.energy_j);
    }

    #[test]
    fn energy_equals_power_times_time() {
        let cfg = AccelConfig::default();
        let u = usage32();
        let r = estimate(&cfg, &u, &stats(250_000, 1000), MaskSampler::Offline);
        assert!((r.energy_j - r.watts * r.seconds).abs() < 1e-12);
        assert!(r.seconds > 0.0);
    }

    #[test]
    fn calibrated_to_paper_operating_point() {
        // Paper §VI-A/C: 32 PEs @ 250 MHz, batch-level -> 11.78 W.  The
        // model must land in the same regime (+-35%, DESIGN.md §5) when
        // running the REAL paper-scale workload through the simulator.
        use crate::model::manifest::{artifacts_root, Manifest};
        let dir = artifacts_root().join("paper");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let w = crate::model::Weights::load_init(&man).unwrap();
        let cfg = AccelConfig {
            batch: man.batch_infer,
            ..Default::default()
        };
        let mut sim = crate::accel::AccelSimulator::new(
            &man,
            &w,
            cfg,
            crate::accel::Scheme::BatchLevel,
        )
        .unwrap();
        let ds = crate::ivim::synth::synth_dataset(man.batch_infer, &man.bvalues, 20.0, 77);
        let (_, st) = sim.infer_batch_stats(&ds.signals).unwrap();
        let u = crate::accel::resource::usage(&cfg, man.nb, man.n_samples, &sim.weight_stores());
        let r = estimate(&cfg, &u, &st, MaskSampler::Offline);
        assert!(
            r.watts > 11.78 * 0.65 && r.watts < 11.78 * 1.35,
            "calibration drifted: {} W vs paper 11.78 W",
            r.watts
        );
    }

    #[test]
    fn zero_cycles_degrades_gracefully() {
        let cfg = AccelConfig::default();
        let u = usage32();
        let r = estimate(&cfg, &u, &stats(0, 0), MaskSampler::Offline);
        assert!(r.watts > 0.0);
        assert_eq!(r.energy_j, 0.0);
    }
}
