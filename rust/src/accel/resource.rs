//! VU13P resource model (paper Fig. 8 substrate).
//!
//! Budgets from the Xilinx VU13P datasheet; consumption follows the PE
//! structure: each PE owns `lanes` 16-bit multipliers (2 DSP slices per
//! mult lane in the paper's mapping — 32 PEs x 128 lanes x 2 = 8192 DSPs
//! = 66.7%, matching the paper's "67% of all available DSPs with 32
//! PEs"), an adder tree in fabric LUTs, and its weight BRAM.

use super::memory::{IoManager, LayerCache, WeightStore, WORDS_PER_BRAM36};

/// VU13P budgets.
pub const VU13P_DSP: usize = 12_288;
pub const VU13P_BRAM36: usize = 2_688;
pub const VU13P_LUT: usize = 1_728_000;
pub const VU13P_IO: usize = 832;

/// DSP slices per multiplier lane (paper mapping).
pub const DSP_PER_LANE: usize = 2;
/// Fabric LUTs per adder-tree node (16-bit add + pipeline reg).
pub const LUT_PER_ADDER: usize = 48;
/// LUTs of fixed control/infra logic (controller FSM, AXI, etc.).
pub const LUT_FIXED: usize = 120_000;
/// I/O pins used (constant: AXI + clocking), paper: "IO resources
/// remain relatively constant".
pub const IO_USED: usize = 300;

/// Resource usage summary for one accelerator configuration.
#[derive(Debug, Clone, Copy)]
pub struct ResourceUsage {
    pub n_pe: usize,
    pub dsp: usize,
    pub bram36: usize,
    pub lut: usize,
    pub io: usize,
}

impl ResourceUsage {
    pub fn dsp_pct(&self) -> f64 {
        100.0 * self.dsp as f64 / VU13P_DSP as f64
    }
    pub fn bram_pct(&self) -> f64 {
        100.0 * self.bram36 as f64 / VU13P_BRAM36 as f64
    }
    pub fn lut_pct(&self) -> f64 {
        100.0 * self.lut as f64 / VU13P_LUT as f64
    }
    pub fn io_pct(&self) -> f64 {
        100.0 * self.io as f64 / VU13P_IO as f64
    }
    /// Does the configuration fit the device?
    pub fn fits(&self) -> bool {
        self.dsp <= VU13P_DSP
            && self.bram36 <= VU13P_BRAM36
            && self.lut <= VU13P_LUT
            && self.io <= VU13P_IO
    }
}

/// Accelerator-level static configuration used by the resource/power
/// models and the cycle simulator.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    pub n_pe: usize,
    pub lanes: usize,
    pub clock_hz: f64,
    pub voxel_capacity: usize,
    pub batch: usize,
    pub r_m: usize,
    pub r_a: usize,
    /// Double-buffered weight memories: overlap the next sample's weight
    /// load with the current sample's compute (perf-pass optimization,
    /// EXPERIMENTS.md §Perf; off by default to match the paper's
    /// reported operating point).
    pub overlap_loads: bool,
}

impl Default for AccelConfig {
    fn default() -> Self {
        // The paper's shipped configuration (§VI-A).
        AccelConfig {
            n_pe: 32,
            lanes: 128,
            clock_hz: 250.0e6,
            voxel_capacity: 20_000,
            batch: 64,
            r_m: 3,
            r_a: 2,
            overlap_loads: false,
        }
    }
}

/// Compute resource usage for a model (nb, n_samples, weight stores).
pub fn usage(
    cfg: &AccelConfig,
    nb: usize,
    n_samples: usize,
    weight_stores: &[WeightStore],
) -> ResourceUsage {
    let dsp = cfg.n_pe * cfg.lanes * DSP_PER_LANE;

    // BRAM: I/O manager + per-PE weight copies + intermediate cache.
    let io_mgr = IoManager::new(cfg.voxel_capacity, nb, n_samples);
    let weight_words: usize = weight_stores.iter().map(|w| w.total_skipped_words()).sum();
    let cache = LayerCache {
        batch: cfg.batch,
        nb,
    };
    let bram36 = io_mgr.bram36() + weight_words.div_ceil(WORDS_PER_BRAM36) + cache.bram36();

    // LUT: adder trees (lanes-1 adders per PE) + control.
    let adders_per_pe = cfg.lanes.saturating_sub(1);
    let lut = LUT_FIXED + cfg.n_pe * adders_per_pe * LUT_PER_ADDER;

    ResourceUsage {
        n_pe: cfg.n_pe,
        dsp,
        bram36,
        lut,
        io: IO_USED,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masks::for_width;

    fn stores(nb: usize) -> Vec<WeightStore> {
        // 4 subnets x 2 masked layers
        (0..8)
            .map(|i| {
                let m = for_width(nb, 4, 2.0, i as u64).unwrap();
                WeightStore::from_mask(nb, &m)
            })
            .collect()
    }

    #[test]
    fn paper_configuration_uses_67pct_dsp() {
        let cfg = AccelConfig::default();
        let u = usage(&cfg, 104, 4, &stores(104));
        assert!((u.dsp_pct() - 66.7).abs() < 1.0, "dsp {}%", u.dsp_pct());
        assert!(u.fits(), "paper config must fit: {u:?}");
    }

    #[test]
    fn dsp_scales_linearly_with_pes() {
        let s = stores(104);
        let mut prev = 0;
        for n_pe in [4, 8, 16, 32] {
            let cfg = AccelConfig {
                n_pe,
                ..Default::default()
            };
            let u = usage(&cfg, 104, 4, &s);
            assert!(u.dsp > prev);
            assert_eq!(u.dsp, n_pe * 128 * DSP_PER_LANE);
            prev = u.dsp;
        }
    }

    #[test]
    fn bram_dominated_by_voxel_store() {
        // Paper: "BRAM consumption primarily depends on the storage of
        // voxels and model weights" and stays ~constant with PE count.
        let s = stores(104);
        let u4 = usage(
            &AccelConfig {
                n_pe: 4,
                ..Default::default()
            },
            104,
            4,
            &s,
        );
        let u32 = usage(&AccelConfig::default(), 104, 4, &s);
        assert_eq!(u4.bram36, u32.bram36);
        assert!(u32.bram_pct() > 10.0);
    }

    #[test]
    fn oversized_config_does_not_fit() {
        let cfg = AccelConfig {
            n_pe: 64,
            ..Default::default()
        };
        let u = usage(&cfg, 104, 4, &stores(104));
        assert!(u.dsp > VU13P_DSP);
        assert!(!u.fits());
    }

    #[test]
    fn io_constant() {
        let s = stores(104);
        let pcts: Vec<f64> = [4usize, 16, 32]
            .iter()
            .map(|&n_pe| {
                usage(
                    &AccelConfig {
                        n_pe,
                        ..Default::default()
                    },
                    104,
                    4,
                    &s,
                )
                .io_pct()
            })
            .collect();
        assert!(pcts.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));
    }
}
