//! Operation-ordering schemes (paper §V-D, Fig. 5).
//!
//! * **Sampling-level** — the conventional order: for each voxel, run all
//!   N mask samples back-to-back.  Each sample switch re-loads that
//!   sample's weights, so a batch costs `N * batchsize` weight loads.
//! * **Batch-level** — the paper's optimisation: load one sample's
//!   weights, run the *whole batch* under it, then move to the next
//!   sample: `N` loads per batch, a `batchsize`x reduction, which is the
//!   dominant power saving (weight loads dominate energy per Horowitz).

/// Loop order for the multi-sample evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    SamplingLevel,
    BatchLevel,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::SamplingLevel => "sampling-level",
            Scheme::BatchLevel => "batch-level",
        }
    }

    /// Weight-load events for one (layer, batch) evaluation.
    pub fn weight_loads(self, n_samples: usize, batch: usize) -> usize {
        match self {
            Scheme::SamplingLevel => n_samples * batch,
            Scheme::BatchLevel => n_samples,
        }
    }

    /// The (sample, voxel) iteration order.  Both schemes visit the same
    /// `n_samples * batch` pairs — only the order (and hence the load
    /// count) differs; results must be bit-identical.
    pub fn iteration_order(self, n_samples: usize, batch: usize) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(n_samples * batch);
        match self {
            Scheme::BatchLevel => {
                for s in 0..n_samples {
                    for v in 0..batch {
                        order.push((s, v));
                    }
                }
            }
            Scheme::SamplingLevel => {
                for v in 0..batch {
                    for s in 0..n_samples {
                        order.push((s, v));
                    }
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_counts_match_paper() {
        // Paper: sampling-level needs N*batchsize loads, batch-level N.
        assert_eq!(Scheme::SamplingLevel.weight_loads(4, 64), 256);
        assert_eq!(Scheme::BatchLevel.weight_loads(4, 64), 4);
    }

    #[test]
    fn orders_cover_same_pairs() {
        let a = Scheme::SamplingLevel.iteration_order(3, 5);
        let b = Scheme::BatchLevel.iteration_order(3, 5);
        assert_eq!(a.len(), 15);
        let mut sa = a.clone();
        let mut sb = b.clone();
        sa.sort_unstable();
        sb.sort_unstable();
        assert_eq!(sa, sb);
        assert_ne!(a, b); // but in different order
    }

    #[test]
    fn batch_level_groups_by_sample() {
        let o = Scheme::BatchLevel.iteration_order(2, 3);
        assert_eq!(o, vec![(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]);
    }

    #[test]
    fn property_load_reduction_is_batchsize() {
        use crate::testing::{forall, zip, Gen};
        forall(
            50,
            zip(Gen::usize_in(1, 16), Gen::usize_in(1, 256)),
            |&(n, b): &(usize, usize)| {
                Scheme::SamplingLevel.weight_loads(n, b)
                    == Scheme::BatchLevel.weight_loads(n, b) * b
            },
        );
    }
}
