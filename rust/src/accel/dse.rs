//! Design-space exploration over the PE count — regenerates paper Fig. 8
//! ("Relationship between resource utilization and performance") and the
//! parallelism trade-off discussion of §VI-C — extended with the mask
//! keep-rate axis the hot-swappable mask plan unlocks.
//!
//! All sweeps reuse **one** simulator: PE count is a scheduling knob
//! ([`AccelSimulator::set_n_pe`] — numerics invariant, only accounting
//! changes) and each mask-rate point is a `resample` + in-place
//! [`AccelSimulator::swap_masks`] instead of a full datapath
//! re-instantiation, so a PE-count × mask-rate grid quantises the
//! weights exactly once.

use super::power::{estimate, MaskSampler, PowerReport};
use super::resource::{usage, AccelConfig, ResourceUsage};
use super::schemes::Scheme;
use super::sim::AccelSimulator;
use crate::masks::MaskPlan;
use crate::model::{Manifest, Weights};
use crate::util::rng::Pcg32;

/// One row of the Fig. 8 sweep.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    pub n_pe: usize,
    /// Bernoulli keep rate of the swept mask plan (`None` = the
    /// manifest's fixed Masksembles masks).
    pub keep_prob: Option<f64>,
    pub usage: ResourceUsage,
    pub batch_ms: f64,
    pub voxels_per_s: f64,
    pub power: PowerReport,
    pub fits: bool,
}

/// Evaluate `pe_counts` on a live simulator (whatever masks it currently
/// carries), appending one row per PE count.
fn sweep_points(
    sim: &mut AccelSimulator,
    man: &Manifest,
    pe_counts: &[usize],
    keep_prob: Option<f64>,
    signals: &[f32],
    rows: &mut Vec<DsePoint>,
) -> anyhow::Result<()> {
    // The stores only change on a mask swap, never with the PE count.
    let stores = sim.weight_stores();
    for &n_pe in pe_counts {
        sim.set_n_pe(n_pe);
        let (_, stats) = sim.infer_batch_stats(signals)?;
        let cfg = sim.cfg;
        let u = usage(&cfg, man.nb, man.n_samples, &stores);
        let p = estimate(&cfg, &u, &stats, MaskSampler::Offline);
        let batch_ms = stats.seconds(cfg.clock_hz) * 1e3;
        rows.push(DsePoint {
            n_pe,
            keep_prob,
            usage: u,
            batch_ms,
            voxels_per_s: man.batch_infer as f64 / (batch_ms / 1e3),
            power: p,
            fits: u.fits(),
        });
    }
    Ok(())
}

/// Sweep the PE counts (paper plots 4..64) on a reference batch, under
/// the manifest's fixed masks.  One simulator serves every point.
pub fn sweep(
    man: &Manifest,
    weights: &Weights,
    pe_counts: &[usize],
    scheme: Scheme,
    signals: &[f32],
) -> anyhow::Result<Vec<DsePoint>> {
    let cfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };
    let mut sim = AccelSimulator::new(man, weights, cfg, scheme)?;
    let mut rows = Vec::with_capacity(pe_counts.len());
    sweep_points(&mut sim, man, pe_counts, None, signals, &mut rows)?;
    Ok(rows)
}

/// PE-count × mask-keep-rate grid: for each keep rate, redraw the plan
/// at that density and hot-swap it into the **same** simulator, then
/// walk the PE counts.  Rows come out keep-rate-major, PE-count-minor.
pub fn sweep_grid(
    man: &Manifest,
    weights: &Weights,
    pe_counts: &[usize],
    keep_probs: &[f64],
    scheme: Scheme,
    signals: &[f32],
    seed: u64,
) -> anyhow::Result<Vec<DsePoint>> {
    let cfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };
    let mut sim = AccelSimulator::new(man, weights, cfg, scheme)?;
    let mut plan = MaskPlan::from_manifest(man)?;
    let mut rng = Pcg32::new(seed);
    let mut rows = Vec::with_capacity(pe_counts.len() * keep_probs.len());
    for &kp in keep_probs {
        plan.set_keep_prob(kp);
        plan.resample(&mut rng);
        sim.swap_masks(&plan)?;
        // record the CLAMPED rate the masks were actually drawn at, not
        // the caller's raw value
        sweep_points(&mut sim, man, pe_counts, Some(plan.keep_prob()), signals, &mut rows)?;
    }
    Ok(rows)
}

/// Pick the fastest configuration that fits the device — the §VI-C
/// guidance ("parallelism can be determined according to resources
/// available on chip and performance requirements").
pub fn best_fitting(points: &[DsePoint]) -> Option<&DsePoint> {
    points
        .iter()
        .filter(|p| p.fits)
        .min_by(|a, b| a.batch_ms.partial_cmp(&b.batch_ms).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn sweep_shapes_match_paper_fig8() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 3);
        let rows = sweep(&man, &w, &[4, 8, 16, 32], Scheme::BatchLevel, &ds.signals).unwrap();
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.keep_prob.is_none()));
        // DSP% strictly increases with PEs; speed increases (latency falls);
        // BRAM and IO stay flat (paper: "remain relatively constant").
        for w2 in rows.windows(2) {
            assert!(w2[1].usage.dsp_pct() > w2[0].usage.dsp_pct());
            assert!(w2[1].batch_ms <= w2[0].batch_ms);
            assert_eq!(w2[1].usage.bram36, w2[0].usage.bram36);
            assert_eq!(w2[1].usage.io, w2[0].usage.io);
        }
        // power increases with parallelism
        assert!(rows.last().unwrap().power.watts > rows[0].power.watts * 0.9);
    }

    /// The one-simulator contract: a reused simulator must produce the
    /// same sweep as the old construct-per-point loop would — i.e. each
    /// row matches a freshly built simulator at that PE count.
    #[test]
    fn reused_simulator_matches_fresh_per_point() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 5);
        let rows = sweep(&man, &w, &[4, 16], Scheme::BatchLevel, &ds.signals).unwrap();
        for row in &rows {
            let cfg = AccelConfig {
                n_pe: row.n_pe,
                batch: man.batch_infer,
                ..Default::default()
            };
            let mut fresh = AccelSimulator::new(&man, &w, cfg, Scheme::BatchLevel).unwrap();
            let (_, st) = fresh.infer_batch_stats(&ds.signals).unwrap();
            let fresh_ms = st.seconds(cfg.clock_hz) * 1e3;
            assert_eq!(row.batch_ms, fresh_ms, "PE {} diverged", row.n_pe);
        }
    }

    #[test]
    fn grid_sweeps_mask_rates_on_one_simulator() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 4);
        let rates = [0.9, 0.3];
        let rows = sweep_grid(
            &man,
            &w,
            &[8, 32],
            &rates,
            Scheme::BatchLevel,
            &ds.signals,
            17,
        )
        .unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].keep_prob, Some(0.9));
        assert_eq!(rows[3].keep_prob, Some(0.3));
        // sparser masks schedule fewer columns: at a fixed PE count the
        // denser plan can never be faster
        for pe in 0..2 {
            let dense = &rows[pe];
            let sparse = &rows[2 + pe];
            assert_eq!(dense.n_pe, sparse.n_pe);
            assert!(
                sparse.batch_ms <= dense.batch_ms,
                "keep 0.3 slower than keep 0.9 at {} PEs: {} vs {}",
                dense.n_pe,
                sparse.batch_ms,
                dense.batch_ms
            );
        }
    }

    #[test]
    fn best_fitting_prefers_fast_valid() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 4);
        let rows = sweep(&man, &w, &[4, 16, 64], Scheme::BatchLevel, &ds.signals).unwrap();
        let best = best_fitting(&rows).unwrap();
        assert!(best.fits);
        // 64 PEs exceeds the VU13P DSP budget -> best must not be 64
        assert_ne!(best.n_pe, 64);
    }
}
