//! Design-space exploration over the PE count — regenerates paper Fig. 8
//! ("Relationship between resource utilization and performance") and the
//! parallelism trade-off discussion of §VI-C.

use super::power::{estimate, PowerReport};
use super::resource::{usage, AccelConfig, ResourceUsage};
use super::schemes::Scheme;
use super::sim::AccelSimulator;
use crate::model::{Manifest, Weights};

/// One row of the Fig. 8 sweep.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    pub n_pe: usize,
    pub usage: ResourceUsage,
    pub batch_ms: f64,
    pub voxels_per_s: f64,
    pub power: PowerReport,
    pub fits: bool,
}

/// Sweep the PE counts (paper plots 4..64) on a reference batch.
pub fn sweep(
    man: &Manifest,
    weights: &Weights,
    pe_counts: &[usize],
    scheme: Scheme,
    signals: &[f32],
) -> anyhow::Result<Vec<DsePoint>> {
    let mut rows = Vec::with_capacity(pe_counts.len());
    for &n_pe in pe_counts {
        let cfg = AccelConfig {
            n_pe,
            batch: man.batch_infer,
            ..Default::default()
        };
        let mut sim = AccelSimulator::new(man, weights, cfg, scheme)?;
        let (_, stats) = sim.infer_batch_stats(signals)?;
        let u = usage(&cfg, man.nb, man.n_samples, &sim.weight_stores());
        let p = estimate(&cfg, &u, &stats, false);
        let batch_ms = stats.seconds(cfg.clock_hz) * 1e3;
        rows.push(DsePoint {
            n_pe,
            usage: u,
            batch_ms,
            voxels_per_s: man.batch_infer as f64 / (batch_ms / 1e3),
            power: p,
            fits: u.fits(),
        });
    }
    Ok(rows)
}

/// Pick the fastest configuration that fits the device — the §VI-C
/// guidance ("parallelism can be determined according to resources
/// available on chip and performance requirements").
pub fn best_fitting(points: &[DsePoint]) -> Option<&DsePoint> {
    points
        .iter()
        .filter(|p| p.fits)
        .min_by(|a, b| a.batch_ms.partial_cmp(&b.batch_ms).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::synth::synth_dataset;
    use crate::model::manifest::artifacts_root;

    fn setup() -> Option<(Manifest, Weights)> {
        let dir = artifacts_root().join("tiny");
        if !dir.join("manifest.json").exists() {
            return Some(crate::testing::fixture::tiny_fixture());
        }
        let man = Manifest::load(&dir).unwrap();
        let w = Weights::load_init(&man).unwrap();
        Some((man, w))
    }

    #[test]
    fn sweep_shapes_match_paper_fig8() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 3);
        let rows = sweep(&man, &w, &[4, 8, 16, 32], Scheme::BatchLevel, &ds.signals).unwrap();
        assert_eq!(rows.len(), 4);
        // DSP% strictly increases with PEs; speed increases (latency falls);
        // BRAM and IO stay flat (paper: "remain relatively constant").
        for w2 in rows.windows(2) {
            assert!(w2[1].usage.dsp_pct() > w2[0].usage.dsp_pct());
            assert!(w2[1].batch_ms <= w2[0].batch_ms);
            assert_eq!(w2[1].usage.bram36, w2[0].usage.bram36);
            assert_eq!(w2[1].usage.io, w2[0].usage.io);
        }
        // power increases with parallelism
        assert!(rows.last().unwrap().power.watts > rows[0].power.watts * 0.9);
    }

    #[test]
    fn best_fitting_prefers_fast_valid() {
        let Some((man, w)) = setup() else { return };
        let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 4);
        let rows = sweep(&man, &w, &[4, 16, 64], Scheme::BatchLevel, &ds.signals).unwrap();
        let best = best_fitting(&rows).unwrap();
        assert!(best.fits);
        // 64 PEs exceeds the VU13P DSP budget -> best must not be 64
        assert_ne!(best.n_pe, 64);
    }
}
