//! Deterministic concurrency harness for the coordinator's serving
//! protocol — batcher, per-shard work-stealing deques, the pooled
//! signal-buffer lifecycle, and the network front door's
//! admission/shed accounting (`Op::NetArrive` / `Op::NetShed` run the
//! real `coordinator::net::admission` rule against the live backlog) —
//! driven in **virtual time** with **no threads, no sleeps, no
//! retries**.
//!
//! Real threads interleave the protocol's atomic steps (push a batch,
//! pop locally, steal from a victim, close, exit) in whatever order the
//! OS scheduler picks; a bug is a *bad ordering*.  Here the ordering is
//! explicit: a [`Sim`] executes a script of [`Op`]s, each op being
//! exactly one atomic protocol step against the **real production
//! structures** (`coordinator::Batcher`, `coordinator::ShardDeques`,
//! `util::pool::VecPool`).  The script *is* the schedule, so races like
//! "a steal overlapping shutdown" are reproducible table rows.  All
//! randomness (p2c placement, steal-victim choice, generated scripts)
//! comes from seeded [`Pcg32`] streams, and batch deadlines run on a
//! virtual clock advanced only by [`Op::Tick`] — a fixed seed replays
//! the exact trace, bit for bit.
//!
//! Request integrity is checked structurally: every arriving request's
//! leased buffer is filled with a per-request fingerprint, and the
//! harness asserts at claim time that each served row still carries its
//! own fingerprint and every padding row is exactly zero — a scrambled
//! route, a leaked padding row, or a recycled-buffer aliasing bug all
//! fail loudly at the step that caused them.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig, Pending};
use crate::coordinator::deque::{Claim, ShardDeques};
use crate::util::pool::VecPool;
use crate::util::rng::Pcg32;

/// One atomic protocol step of the simulated coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// `n` requests arrive (leased buffers, sequential ids) at the
    /// current virtual time.
    Arrive(usize),
    /// A streaming-volume driver ingests one slice of `n` voxels: the
    /// whole slice is admitted only if it fits under the configured
    /// in-flight cap (`SimConfig::inflight_cap`); otherwise the slice
    /// is **deferred** (counted, no ids consumed) — the modelled driver
    /// drains completions and retries, exactly the
    /// `volume::stream` backpressure rule.
    IngestSlice(usize),
    /// Advance virtual time by this many microseconds (drives the
    /// batcher's deadline flush — the harness's only notion of waiting).
    Tick(u64),
    /// Dispatcher: cut every *ready* batch and place it with
    /// power-of-two-choices on deque depth.
    Cut,
    /// Dispatcher: cut every ready batch onto shard `k`'s deque
    /// (models a placement skew / stalled-victim backlog).
    CutTo(usize),
    /// Shard `k`: one claim attempt — local LIFO pop, else a FIFO steal
    /// scan from a seeded-random victim offset.
    Pop(usize),
    /// Shard `k`: strictly local LIFO pop (no steal).
    PopLocal(usize),
    /// `thief` steals FIFO from exactly `victim`'s deque.
    StealFrom { thief: usize, victim: usize },
    /// `n` framed requests arrive on the network front door, each with
    /// this relative deadline (µs; 0 = none).  Every request walks the
    /// REAL server-side admission chain in server order — connection
    /// quota (`SimConfig::net_quota`), then the deadline gate
    /// (`coordinator::net::admission::should_shed` fed the live deque /
    /// batcher backlog and `SimConfig::net_ewma_us`) — and is either
    /// admitted to the batcher or shed with an explicit `OVERLOADED`
    /// (recorded in `SimResult::shed`; the id is consumed, never lost).
    NetArrive { n: usize, deadline_us: u64 },
    /// The reply-side expiry sweep (`net::Conn::sweep_replies`): every
    /// admitted net request whose deadline has passed in virtual time
    /// is answered `EXPIRED` now; the shard's eventual service of those
    /// rows is discarded instead of double-counted.
    NetShed,
    /// Graceful shutdown: flush everything pending through the deques,
    /// then close them (pushes fail from here on; claims keep
    /// draining).
    Shutdown,
    /// Shard `k` exits.  When the last one goes, the dead-pool failsafe
    /// closes and drains the deques, failing the backlog fast.
    Exit(usize),
}

/// One served (real) row, in global service order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServedRow {
    pub shard: usize,
    pub id: u64,
    pub claim: Claim,
}

/// The observable outcome of a script — `PartialEq` so reproducibility
/// is a single `assert_eq!`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SimResult {
    /// Real rows in the order shards served them.
    pub served: Vec<ServedRow>,
    /// Ids of each cut batch, in cut order (global FIFO of formation).
    pub cut_order: Vec<Vec<u64>>,
    /// Rows failed by the dead-pool drain or a push-after-close.
    pub failed: Vec<u64>,
    /// Rows shed by batcher backpressure at arrival.
    pub rejected: Vec<u64>,
    /// Batches claimed from the claimer's own deque / stolen.
    pub local: u64,
    pub stolen: u64,
    /// Lease-slab high-water mark (fresh request-buffer allocations).
    pub lease_created: usize,
    /// Idle lease buffers at the end of the script.
    pub lease_idle: usize,
    /// Batch signal-buffer pool high-water / idle.
    pub batch_created: usize,
    pub batch_idle: usize,
    /// Highest number of streamed (slice-ingested) requests in flight
    /// at once — admitted but not yet served, failed or rejected.
    pub max_inflight: usize,
    /// Slices refused admission by the in-flight cap (each is one
    /// driver stall-and-drain event).
    pub deferred_slices: usize,
    /// Net requests shed at the admission gate with an explicit
    /// `OVERLOADED` (quota or deadline rule), in arrival order.
    pub shed: Vec<u64>,
    /// Net requests answered `EXPIRED` by the reply-side sweep after
    /// their deadline lapsed in the queue.
    pub expired: Vec<u64>,
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub shards: usize,
    /// Voxel width (signals per request).
    pub nb: usize,
    pub batch_size: usize,
    /// Batcher deadline, in virtual microseconds.
    pub max_wait_us: u64,
    pub queue_capacity: usize,
    /// In-flight cap for `Op::IngestSlice` (streamed requests admitted
    /// but not yet completed). Unlimited by default.
    pub inflight_cap: usize,
    /// Connection quota for `Op::NetArrive`: admitted-but-unanswered
    /// net requests allowed at once (the server's per-connection
    /// `NetConfig::conn_quota`). Unlimited by default.
    pub net_quota: usize,
    /// Virtual EWMA batch latency (µs) fed to the admission estimator
    /// by `Op::NetArrive`. 0 models a cold coordinator, which never
    /// sheds on delay.
    pub net_ewma_us: u64,
    /// Seeds the dispatcher's p2c stream, each shard's steal-victim
    /// stream, and nothing else.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            shards: 2,
            nb: 3,
            batch_size: 4,
            max_wait_us: 100,
            queue_capacity: 10_000,
            inflight_cap: usize::MAX,
            net_quota: usize::MAX,
            net_ewma_us: 0,
            seed: 0xC0FFEE,
        }
    }
}

/// The simulated coordinator: real batcher + real deques + real pools,
/// scheduled by a script instead of threads.
pub struct Sim {
    cfg: SimConfig,
    base: Instant,
    now_us: u64,
    batcher: Batcher<u64>,
    deques: ShardDeques<crate::coordinator::Batch<u64>>,
    request_pool: Arc<VecPool>,
    signal_pool: Arc<VecPool>,
    dispatch_rng: Pcg32,
    shard_rngs: Vec<Pcg32>,
    alive: Vec<bool>,
    next_id: u64,
    /// Ids admitted through `Op::IngestSlice` and not yet completed.
    streamed: BTreeSet<u64>,
    /// `streamed.len()`, tracked alongside for the gauge updates.
    inflight: usize,
    /// Net-admitted ids → absolute virtual-time expiry (µs;
    /// `u64::MAX` = no deadline), awaiting a reply.
    net_pending: BTreeMap<u64, u64>,
    /// Ids already answered `EXPIRED`: their eventual service or
    /// failure is accounting-discarded, never double-counted.
    disposed: BTreeSet<u64>,
    out: SimResult,
}

impl Sim {
    pub fn new(cfg: SimConfig) -> Sim {
        let request_pool = Arc::new(VecPool::new(cfg.queue_capacity.max(1)));
        let signal_pool = Arc::new(VecPool::new(2 * cfg.shards.max(1)));
        let batcher = Batcher::with_pools(
            BatcherConfig {
                batch_size: cfg.batch_size,
                max_wait: Duration::from_micros(cfg.max_wait_us),
                queue_capacity: cfg.queue_capacity,
            },
            cfg.nb,
            Arc::clone(&signal_pool),
            Arc::clone(&request_pool),
        );
        // the production placement bound, not a copy of it
        let cap = crate::coordinator::deque::cap_for(
            cfg.queue_capacity,
            cfg.batch_size,
            cfg.shards,
        );
        Sim {
            base: Instant::now(),
            now_us: 0,
            batcher,
            deques: ShardDeques::new(cfg.shards, cap),
            request_pool,
            signal_pool,
            dispatch_rng: Pcg32::with_stream(cfg.seed, 0xD15),
            shard_rngs: (0..cfg.shards.max(1))
                .map(|k| Pcg32::with_stream(cfg.seed, 0x57EA1 + k as u64))
                .collect(),
            alive: vec![true; cfg.shards.max(1)],
            next_id: 0,
            streamed: BTreeSet::new(),
            inflight: 0,
            net_pending: BTreeMap::new(),
            disposed: BTreeSet::new(),
            out: SimResult::default(),
            cfg,
        }
    }

    /// The per-request fingerprint: every signal slot of request `id`
    /// carries this value (never zero, so padding leaks are visible).
    fn fingerprint(id: u64) -> f32 {
        (id + 1) as f32
    }

    fn virtual_now(&self) -> Instant {
        self.base + Duration::from_micros(self.now_us)
    }

    /// Requests still waiting in the batcher.
    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    /// Batches queued across all deques.
    pub fn queued(&self) -> usize {
        self.deques.total()
    }

    pub fn lease_created(&self) -> usize {
        self.request_pool.created()
    }
    pub fn lease_idle(&self) -> usize {
        self.request_pool.idle()
    }
    pub fn batch_created(&self) -> usize {
        self.signal_pool.created()
    }
    pub fn batch_idle(&self) -> usize {
        self.signal_pool.idle()
    }
    pub fn is_closed(&self) -> bool {
        self.deques.is_closed()
    }
    /// Streamed (slice-ingested) requests admitted but not yet served,
    /// failed or rejected.
    pub fn inflight(&self) -> usize {
        self.inflight
    }
    /// Net requests admitted and still awaiting a reply (the quota
    /// gauge for `Op::NetArrive`).
    pub fn net_pending(&self) -> usize {
        self.net_pending.len()
    }

    /// Record a failed batch, releasing any streamed ids it carried.
    /// Rows already answered `EXPIRED` were accounted at sweep time and
    /// are discarded here.
    fn fail_tags(&mut self, tags: &[u64]) {
        for &id in tags {
            if self.streamed.remove(&id) {
                self.inflight -= 1;
            }
            self.net_pending.remove(&id);
            if self.disposed.remove(&id) {
                continue;
            }
            self.out.failed.push(id);
        }
    }

    /// Execute one atomic protocol step.
    pub fn step(&mut self, op: Op) {
        match op {
            Op::Arrive(n) => {
                for _ in 0..n {
                    let id = self.next_id;
                    self.next_id += 1;
                    let mut signals = self.request_pool.take(self.cfg.nb);
                    signals.resize(self.cfg.nb, Self::fingerprint(id));
                    let pend = Pending {
                        signals,
                        tag: id,
                        enqueued: self.virtual_now(),
                    };
                    if let Err(p) = self.batcher.push(pend) {
                        self.out.rejected.push(id);
                        self.request_pool.put(p.signals);
                    }
                }
            }
            Op::IngestSlice(n) => {
                // All-or-nothing admission under the in-flight cap —
                // the streaming driver's backpressure gate.
                if self.inflight + n > self.cfg.inflight_cap {
                    self.out.deferred_slices += 1;
                } else {
                    for _ in 0..n {
                        let id = self.next_id;
                        self.next_id += 1;
                        let mut signals = self.request_pool.take(self.cfg.nb);
                        signals.resize(self.cfg.nb, Self::fingerprint(id));
                        let pend = Pending {
                            signals,
                            tag: id,
                            enqueued: self.virtual_now(),
                        };
                        if let Err(p) = self.batcher.push(pend) {
                            self.out.rejected.push(id);
                            self.request_pool.put(p.signals);
                        } else {
                            self.streamed.insert(id);
                            self.inflight += 1;
                            self.out.max_inflight = self.out.max_inflight.max(self.inflight);
                        }
                    }
                }
            }
            Op::NetArrive { n, deadline_us } => {
                for _ in 0..n {
                    let id = self.next_id;
                    self.next_id += 1;
                    // The real admission chain in server order — quota,
                    // then the deadline gate fed the live backlog —
                    // both checked before any lease is taken (the
                    // server sheds without touching the slab).
                    let est = crate::coordinator::net::admission::estimate_delay_us(
                        self.deques.total(),
                        self.batcher.len(),
                        self.cfg.batch_size,
                        self.cfg.shards,
                        self.cfg.net_ewma_us,
                    );
                    if self.net_pending.len() >= self.cfg.net_quota
                        || crate::coordinator::net::admission::should_shed(deadline_us, est)
                    {
                        self.out.shed.push(id);
                        continue;
                    }
                    let mut signals = self.request_pool.take(self.cfg.nb);
                    signals.resize(self.cfg.nb, Self::fingerprint(id));
                    let pend = Pending {
                        signals,
                        tag: id,
                        enqueued: self.virtual_now(),
                    };
                    if let Err(p) = self.batcher.push(pend) {
                        self.out.rejected.push(id);
                        self.request_pool.put(p.signals);
                    } else {
                        let exp = if deadline_us == 0 {
                            u64::MAX
                        } else {
                            self.now_us.saturating_add(deadline_us)
                        };
                        self.net_pending.insert(id, exp);
                    }
                }
            }
            Op::NetShed => {
                // Reply-side sweep: answer EXPIRED for every overdue
                // pending reply (expiry instant counts as overdue).
                let now = self.now_us;
                let overdue: Vec<u64> = self
                    .net_pending
                    .iter()
                    .filter(|&(_, &exp)| exp <= now)
                    .map(|(&id, _)| id)
                    .collect();
                for id in overdue {
                    self.net_pending.remove(&id);
                    self.disposed.insert(id);
                    self.out.expired.push(id);
                }
            }
            Op::Tick(us) => self.now_us += us,
            Op::Cut => {
                while self.batcher.ready(self.virtual_now()) {
                    let Some(batch) = self.batcher.cut() else { break };
                    self.out.cut_order.push(batch.tags.clone());
                    if let Err(batch) = self.deques.push_balanced(batch, &mut self.dispatch_rng)
                    {
                        self.fail_tags(&batch.tags);
                    }
                }
            }
            Op::CutTo(k) => {
                while self.batcher.ready(self.virtual_now()) {
                    let Some(batch) = self.batcher.cut() else { break };
                    self.out.cut_order.push(batch.tags.clone());
                    if let Err(batch) = self.deques.push_to(k, batch) {
                        self.fail_tags(&batch.tags);
                    }
                }
            }
            Op::Pop(k) => {
                if self.alive[k] {
                    if let Some((batch, claim)) = self.deques.try_pop(k, &mut self.shard_rngs[k])
                    {
                        self.serve(k, batch, claim);
                    }
                }
            }
            Op::PopLocal(k) => {
                if self.alive[k] {
                    if let Some(batch) = self.deques.pop_local(k) {
                        self.serve(k, batch, Claim::Local);
                    }
                }
            }
            Op::StealFrom { thief, victim } => {
                if self.alive[thief] {
                    if let Some(batch) = self.deques.steal_from(victim) {
                        self.serve(thief, batch, Claim::Stolen { victim });
                    }
                }
            }
            Op::Shutdown => {
                // the dispatcher's graceful path: flush *everything*
                // still pending, then close — claims keep draining
                while let Some(batch) = self.batcher.cut() {
                    self.out.cut_order.push(batch.tags.clone());
                    if let Err(batch) = self.deques.push_balanced(batch, &mut self.dispatch_rng)
                    {
                        self.fail_tags(&batch.tags);
                    }
                }
                self.deques.close();
            }
            Op::Exit(k) => {
                if self.alive[k] {
                    self.alive[k] = false;
                    if self.alive.iter().all(|a| !a) {
                        // dead-pool failsafe: last exit closes + drains
                        self.deques.close();
                        for batch in self.deques.drain() {
                            self.fail_tags(&batch.tags);
                        }
                    }
                }
            }
        }
    }

    /// "Run" a claimed batch: verify row integrity (each real row still
    /// carries its own fingerprint, each padding row is exactly zero),
    /// record the service, and hand the batch buffer back — the shard
    /// side of the buffer lifecycle.
    fn serve(&mut self, shard: usize, batch: crate::coordinator::Batch<u64>, claim: Claim) {
        let nb = self.cfg.nb;
        assert_eq!(
            batch.signals.len(),
            self.cfg.batch_size * nb,
            "batch not padded to the static shape"
        );
        for (row, &id) in batch.tags.iter().enumerate() {
            let r = &batch.signals[row * nb..(row + 1) * nb];
            assert!(
                r.iter().all(|&v| v == Self::fingerprint(id)),
                "request {id} served with another request's signals (row {row}: {r:?})"
            );
            if self.streamed.remove(&id) {
                self.inflight -= 1;
            }
            self.net_pending.remove(&id);
            if self.disposed.remove(&id) {
                // already answered EXPIRED at sweep time — the shard
                // computed it, but the reply side discards it
                continue;
            }
            self.out.served.push(ServedRow { shard, id, claim });
        }
        for row in batch.real..self.cfg.batch_size {
            let r = &batch.signals[row * nb..(row + 1) * nb];
            assert!(
                r.iter().all(|&v| v == 0.0),
                "padding row {row} leaked data: {r:?}"
            );
        }
        match claim {
            Claim::Local => self.out.local += 1,
            Claim::Stolen { .. } => self.out.stolen += 1,
        }
        self.signal_pool.put(batch.signals);
    }

    /// Drain to completion: flush + close (idempotent if the script
    /// already shut down — arrivals admitted *after* a close still get
    /// flushed, and fail fast at the closed deques), then round-robin
    /// claim attempts across shards until every queued batch is served.
    /// Panics rather than spinning forever — "it would eventually
    /// finish" is not an acceptance bar here.
    pub fn drain_to_completion(&mut self) {
        self.step(Op::Shutdown);
        let mut guard = 0usize;
        let budget = 10_000 + 10 * (self.next_id as usize + 1);
        while self.queued() > 0 {
            for k in 0..self.cfg.shards {
                self.step(Op::Pop(k));
            }
            guard += 1;
            assert!(
                guard < budget,
                "drain did not converge: {} batches still queued",
                self.queued()
            );
        }
    }

    /// Finish: capture the pool gauges and hand the trace over.
    pub fn finish(mut self) -> SimResult {
        self.out.lease_created = self.request_pool.created();
        self.out.lease_idle = self.request_pool.idle();
        self.out.batch_created = self.signal_pool.created();
        self.out.batch_idle = self.signal_pool.idle();
        self.out
    }
}

/// Run a script end to end (no implicit drain — the script is the whole
/// schedule).
pub fn run_script(cfg: SimConfig, script: &[Op]) -> SimResult {
    let mut sim = Sim::new(cfg);
    for &op in script {
        sim.step(op);
    }
    sim.finish()
}

/// One atomic step of the pipelined mask-prep hand-off
/// (`bayes::pipeline::PrepProtocol`) — the same state machine the
/// background `PrepWorker` walks, scheduled explicitly.  `Prep` and
/// `Take` are the two sides whose interleaving the real pipeline leaves
/// to the OS; here a script pins it, so "prepare racing swap" orderings
/// are reproducible table rows like the deque races above.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrepOp {
    /// Engine side: hand the held stale plan + RNG to the slot.
    Submit,
    /// Worker side: one non-blocking prepare attempt (`try_prep`).
    Prep,
    /// Engine side: one non-blocking take attempt (`try_take`); on
    /// success the prepared plan becomes live and the stale one is held
    /// for the next `Submit`.
    Take,
    /// Tear the protocol down.
    Shutdown,
}

/// Observable outcome of a [`PrepOp`] script — `PartialEq` so replay
/// and ordering-independence are single `assert_eq!`s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrepResult {
    /// Kept-column lists of every layer of the plan the engine ends up
    /// holding, in `(subnet, layer)` order — the mask bits that a real
    /// engine would have swapped in.
    pub final_kept: Vec<Vec<Vec<u32>>>,
    /// Completed prepare→take cycles (passes whose masks advanced).
    pub completed_passes: usize,
    /// One entry per op: what the step observed.
    pub log: Vec<&'static str>,
}

/// The synchronous twin of `bayes::Pipelined`'s hand-off loop: same
/// construction (seeded RNG, Bernoulli plan, shadow clone submitted
/// with the RNG), but `Prep`/`Take` run inline under script control.
pub struct PrepSim {
    proto: crate::bayes::pipeline::PrepProtocol,
    /// The plan "the engine" currently executes with.
    live: crate::masks::MaskPlan,
    /// The stale plan + travelling RNG awaiting the next `Submit`.
    held: Option<(crate::masks::MaskPlan, Pcg32)>,
    completed: usize,
    log: Vec<&'static str>,
}

impl PrepSim {
    pub fn new(man: &crate::model::Manifest, seed: u64, layers: (usize, usize)) -> PrepSim {
        use crate::bayes::pipeline::{PlanShape, PrepProtocol};
        let mut rng = Pcg32::new(seed);
        let live = crate::masks::MaskPlan::bernoulli(man, 1.0 / man.scale, &mut rng);
        let proto = PrepProtocol::new(PlanShape::of(&live), layers.0, layers.1);
        let held = Some((live.clone(), rng));
        PrepSim {
            proto,
            live,
            held,
            completed: 0,
            log: Vec::new(),
        }
    }

    pub fn step(&mut self, op: PrepOp) {
        let ev = match op {
            PrepOp::Submit => match self.held.take() {
                Some((plan, rng)) => match self.proto.submit(plan, rng) {
                    Ok(()) => "submit",
                    Err(_) => "submit-rejected",
                },
                None => "submit-nothing-held",
            },
            PrepOp::Prep => {
                if self.proto.try_prep() {
                    "prep"
                } else {
                    "prep-idle"
                }
            }
            PrepOp::Take => match self.proto.try_take() {
                Some((plan, rng, check)) => {
                    check.expect("shape never changes in the sim");
                    let stale = std::mem::replace(&mut self.live, plan);
                    self.held = Some((stale, rng));
                    self.completed += 1;
                    "take"
                }
                None => "take-not-ready",
            },
            PrepOp::Shutdown => {
                self.proto.shutdown();
                "shutdown"
            }
        };
        self.log.push(ev);
    }

    pub fn finish(self) -> PrepResult {
        let n_subnets = self.live.subnets().len();
        let mut final_kept = Vec::with_capacity(n_subnets * 2);
        for si in 0..n_subnets {
            for layer in [1usize, 2] {
                final_kept.push(self.live.layer(si, layer).kept_lists().to_vec());
            }
        }
        PrepResult {
            final_kept,
            completed_passes: self.completed,
            log: self.log,
        }
    }
}

/// Run a prep-protocol script end to end.
pub fn run_prep_script(
    man: &crate::model::Manifest,
    seed: u64,
    layers: (usize, usize),
    script: &[PrepOp],
) -> PrepResult {
    let mut sim = PrepSim::new(man, seed, layers);
    for &op in script {
        sim.step(op);
    }
    sim.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall_seeded, Gen};
    use std::collections::BTreeSet;

    fn ids(rows: &[ServedRow]) -> Vec<u64> {
        rows.iter().map(|r| r.id).collect()
    }

    /// Every id arrives exactly once somewhere: served ∪ failed ∪
    /// rejected ∪ shed ∪ expired partitions 0..n — the exactly-once
    /// accounting contract, network paths included.
    fn assert_conservation(r: &SimResult, n: u64) {
        let mut seen = BTreeSet::new();
        for &id in ids(&r.served)
            .iter()
            .chain(&r.failed)
            .chain(&r.rejected)
            .chain(&r.shed)
            .chain(&r.expired)
        {
            assert!(seen.insert(id), "request {id} delivered twice: {r:?}");
        }
        assert_eq!(
            seen,
            (0..n).collect::<BTreeSet<_>>(),
            "lost requests (served {} / failed {} / rejected {} / shed {} / expired {} of {n})",
            r.served.len(),
            r.failed.len(),
            r.rejected.len(),
            r.shed.len(),
            r.expired.len()
        );
    }

    /// Batches form in global FIFO order: each cut batch is a
    /// contiguous ascending id run, and the runs concatenate to 0..cut.
    fn assert_fifo_formation(r: &SimResult) {
        let mut next = 0u64;
        for run in &r.cut_order {
            for &id in run {
                assert_eq!(id, next, "batch formation broke FIFO: {:?}", r.cut_order);
                next += 1;
            }
        }
    }

    #[test]
    fn fixed_seed_reproduces_the_exact_trace() {
        let cfg = SimConfig {
            shards: 3,
            seed: 42,
            ..Default::default()
        };
        let script = [
            Op::Arrive(10),
            Op::Tick(200),
            Op::Cut,
            Op::Pop(2),
            Op::Arrive(5),
            Op::Pop(0),
            Op::Tick(200),
            Op::Cut,
            Op::Pop(1),
            Op::Pop(1),
            Op::Shutdown,
            Op::Pop(0),
            Op::Pop(2),
            Op::Pop(0),
        ];
        let a = run_script(cfg, &script);
        let b = run_script(cfg, &script);
        assert_eq!(a, b, "same seed + same script must replay bit-for-bit");
        // nothing was served twice
        assert_eq!(
            ids(&a.served).iter().collect::<BTreeSet<_>>().len(),
            a.served.len()
        );
    }

    /// THE interleaving the old single-shared-queue tests could not
    /// express: the dispatcher closes for shutdown while a batch still
    /// sits in a *specific shard's* deque, and a *different* shard
    /// claims it cross-shard (a steal) after the close.  With one
    /// shared queue there is no "someone else's backlog" to steal —
    /// post-close pops are indistinguishable from normal pops.
    #[test]
    fn steal_racing_shutdown_loses_nothing() {
        let cfg = SimConfig {
            shards: 2,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::Arrive(8));
        sim.step(Op::Tick(1_000)); // both batches past the deadline
        sim.step(Op::CutTo(1)); // entire backlog lands on shard 1
        assert_eq!(sim.queued(), 2);
        // shard 1 takes its freshest batch (LIFO): ids 4..8
        sim.step(Op::PopLocal(1));
        // shutdown closes the deques with batch {0..4} still queued on
        // shard 1
        sim.step(Op::Shutdown);
        assert!(sim.is_closed());
        assert_eq!(sim.queued(), 1);
        // shard 0, post-close, steals shard 1's remaining backlog
        sim.step(Op::Pop(0));
        let r = sim.finish();
        assert_conservation(&r, 8);
        assert!(r.failed.is_empty(), "close must not strand the backlog");
        // the LIFO local pop served 4..8 first…
        let served_ids = ids(&r.served);
        assert_eq!(&served_ids[..4], &[4, 5, 6, 7]);
        // …and the post-close claim was a genuine cross-shard steal
        let last = &r.served[4..];
        assert_eq!(ids(last), vec![0, 1, 2, 3], "steal is FIFO (oldest first)");
        assert!(
            last.iter()
                .all(|row| row.shard == 0 && row.claim == Claim::Stolen { victim: 1 }),
            "the post-shutdown claim must be shard 0 stealing from shard 1: {last:?}"
        );
        assert_eq!((r.local, r.stolen), (1, 1));
    }

    /// Shutdown-during-steal, both orderings: a steal immediately
    /// before the close and immediately after it both succeed — close
    /// stops *pushes*, never claims.
    #[test]
    fn shutdown_before_and_after_a_steal_both_drain() {
        for close_first in [false, true] {
            let cfg = SimConfig {
                shards: 2,
                batch_size: 4,
                ..Default::default()
            };
            let mut sim = Sim::new(cfg);
            sim.step(Op::Arrive(4));
            sim.step(Op::Tick(1_000));
            sim.step(Op::CutTo(1));
            if close_first {
                sim.step(Op::Shutdown);
                sim.step(Op::StealFrom { thief: 0, victim: 1 });
            } else {
                sim.step(Op::StealFrom { thief: 0, victim: 1 });
                sim.step(Op::Shutdown);
            }
            let r = sim.finish();
            assert_conservation(&r, 4);
            assert!(r.failed.is_empty(), "close_first={close_first}");
            assert_eq!(r.stolen, 1);
        }
    }

    /// Arrivals racing the shutdown flush: whatever was admitted to the
    /// batcher before `Shutdown` is flushed and served; pushes after
    /// the close fail fast into `failed` instead of hanging.
    #[test]
    fn arrivals_after_close_fail_fast_instead_of_stranding() {
        let cfg = SimConfig {
            shards: 2,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::Arrive(3)); // partial batch, not yet ready
        sim.step(Op::Shutdown); // flushes the partial batch, closes
        sim.step(Op::Arrive(2)); // land in the batcher…
        sim.step(Op::Shutdown); // …and the flush now hits closed deques
        sim.step(Op::Pop(0));
        sim.step(Op::Pop(1));
        let r = sim.finish();
        assert_conservation(&r, 5);
        assert_eq!(ids(&r.served), vec![0, 1, 2], "pre-close batch served");
        assert_eq!(r.failed, vec![3, 4], "post-close batch failed fast");
    }

    /// Dead-pool failsafe: when the last shard exits, the drained
    /// backlog is failed — not stranded, not double-served.
    #[test]
    fn dead_pool_drains_and_fails_the_backlog() {
        let cfg = SimConfig {
            shards: 2,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::Arrive(8));
        sim.step(Op::Tick(1_000));
        sim.step(Op::Cut);
        sim.step(Op::Pop(0)); // one batch served before the crash
        sim.step(Op::Exit(0));
        assert!(!sim.is_closed(), "one shard still alive");
        sim.step(Op::Exit(1)); // last exit: close + drain
        assert!(sim.is_closed());
        assert_eq!(sim.queued(), 0);
        let r = sim.finish();
        assert_conservation(&r, 8);
        assert_eq!(r.served.len(), 4);
        assert_eq!(r.failed.len(), 4);
    }

    /// The lease contract, step by step: arrivals own their buffers;
    /// the cut reclaims them into the slab; the batch buffer belongs to
    /// the deque until a shard serves and returns it.  Two full waves
    /// through the cycle allocate nothing new — the capacity-stability
    /// signature.
    #[test]
    fn lease_reclaim_ordering_is_exact() {
        let cfg = SimConfig {
            shards: 1,
            nb: 3,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::Arrive(4));
        assert_eq!(sim.lease_created(), 4, "four fresh leases");
        assert_eq!(sim.lease_idle(), 0, "arrivals own their buffers");
        sim.step(Op::Cut); // full batch: size-triggered, no tick needed
        assert_eq!(sim.lease_idle(), 4, "cut reclaims every request buffer");
        assert_eq!(sim.batch_created(), 1);
        assert_eq!(sim.batch_idle(), 0, "batch buffer is owned by the deque");
        sim.step(Op::Pop(0));
        assert_eq!(sim.batch_idle(), 1, "serving returns the batch buffer");
        // wave 2: everything recycles, nothing allocates
        sim.step(Op::Arrive(4));
        assert_eq!(sim.lease_idle(), 0);
        sim.step(Op::Cut);
        sim.step(Op::Pop(0));
        let r = sim.finish();
        assert_eq!(r.lease_created, 4, "wave 2 allocated no request buffers");
        assert_eq!(r.batch_created, 1, "wave 2 allocated no batch buffers");
        assert_conservation(&r, 8);
    }

    /// ISSUE #7: slice arrivals racing shutdown.  A slice already
    /// flushed to a deque before the close is served (and its in-flight
    /// accounting released on completion); a slice ingested after the
    /// close fails fast at the flush and releases its accounting too —
    /// the streaming driver never waits on voxels that can't complete.
    #[test]
    fn slice_arrivals_racing_shutdown_fail_fast_and_release_inflight() {
        let cfg = SimConfig {
            shards: 2,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::IngestSlice(4)); // slice A: ids 0..4, one full batch
        assert_eq!(sim.inflight(), 4);
        sim.step(Op::Shutdown); // flushes slice A to a deque, closes
        assert!(sim.is_closed());
        assert_eq!(sim.inflight(), 4, "queued-but-unserved is still in flight");
        sim.step(Op::IngestSlice(4)); // slice B lands in the batcher post-close
        assert_eq!(sim.inflight(), 8);
        sim.step(Op::Shutdown); // flush hits closed deques: fail fast
        assert_eq!(sim.inflight(), 4, "failed slice released its accounting");
        sim.step(Op::Pop(0));
        sim.step(Op::Pop(1));
        assert_eq!(sim.inflight(), 0, "served slice released its accounting");
        let r = sim.finish();
        assert_conservation(&r, 8);
        assert_eq!(ids(&r.served), vec![0, 1, 2, 3], "pre-close slice served");
        assert_eq!(r.failed, vec![4, 5, 6, 7], "post-close slice failed fast");
        assert_eq!(r.max_inflight, 8);
    }

    /// ISSUE #7: out-of-order completion.  Two slices are cut onto one
    /// shard's deque; LIFO local pop serves the *newer* slice first and
    /// a cross-shard steal completes the older one — service order is
    /// scrambled relative to ingest order, yet every voxel of the
    /// "volume" completes exactly once (id-keyed assembly is order-
    /// independent, the property `volume::stream` relies on).
    #[test]
    fn out_of_order_completion_assembles_the_full_volume() {
        let cfg = SimConfig {
            shards: 2,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::IngestSlice(4)); // slice A: ids 0..4
        sim.step(Op::IngestSlice(4)); // slice B: ids 4..8
        sim.step(Op::Tick(1_000));
        sim.step(Op::CutTo(1)); // both batches pile on shard 1
        sim.step(Op::PopLocal(1)); // LIFO: slice B completes first
        sim.step(Op::Pop(0)); // shard 0 steals slice A (FIFO)
        let r = sim.finish();
        assert_conservation(&r, 8);
        let served = ids(&r.served);
        assert_eq!(&served[..4], &[4, 5, 6, 7], "newer slice completed first");
        assert_eq!(&served[4..], &[0, 1, 2, 3], "older slice stolen after");
        assert_ne!(served, (0..8).collect::<Vec<_>>(), "order really scrambled");
        let mut sorted = served;
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>(), "volume fully assembled");
        assert_eq!(r.stolen, 1);
    }

    /// ISSUE #7: the in-flight cap is never exceeded — a slice that
    /// would overflow it is deferred (a counted stall), admitted only
    /// after completions free room.  Fixed seed, bit-for-bit replay.
    #[test]
    fn inflight_cap_is_never_exceeded() {
        let cfg = SimConfig {
            shards: 1,
            batch_size: 4,
            inflight_cap: 8,
            ..Default::default()
        };
        let script = [
            Op::IngestSlice(4), // ids 0..4
            Op::IngestSlice(4), // ids 4..8 — at the cap
            Op::IngestSlice(4), // would exceed: deferred, no ids consumed
            Op::Cut,            // two full batches to the deque
            Op::Pop(0),
            Op::Pop(0), // both served: in-flight back to 0
            Op::IngestSlice(4), // ids 8..12 — now admitted
            Op::Cut,
            Op::Pop(0),
        ];
        let a = run_script(cfg, &script);
        let b = run_script(cfg, &script);
        assert_eq!(a, b, "fixed seed must replay bit-for-bit");
        assert_eq!(a.deferred_slices, 1, "the overflow slice was deferred");
        assert_eq!(a.max_inflight, 8, "cap reached but never exceeded");
        assert_conservation(&a, 12);
        assert_eq!(a.served.len(), 12);
        assert!(a.failed.is_empty() && a.rejected.is_empty());
    }

    /// Satellite property: over randomized arrival/deadline/claim
    /// interleavings — including a mid-stream shutdown — delivery is
    /// exactly-once (zero lost, zero duplicated) and batch formation is
    /// globally FIFO.  Seeded: any failure replays.
    #[test]
    fn property_random_interleavings_conserve_and_stay_fifo() {
        forall_seeded(
            0x5EED_5EED,
            60,
            Gen::usize_in(0, 1 << 30),
            |&case_seed| {
                let mut script_rng = Pcg32::new(case_seed as u64);
                let shards = 1 + script_rng.below(4) as usize;
                let cfg = SimConfig {
                    shards,
                    nb: 2,
                    batch_size: 1 + script_rng.below(5) as usize,
                    max_wait_us: 50,
                    queue_capacity: 10_000,
                    seed: case_seed as u64,
                };
                let mut sim = Sim::new(cfg);
                let steps = 30 + script_rng.below(50);
                let shutdown_at = script_rng.below(steps);
                for s in 0..steps {
                    if s == shutdown_at {
                        sim.step(Op::Shutdown);
                        continue;
                    }
                    let k = script_rng.below(shards as u32) as usize;
                    match script_rng.below(6) {
                        0 => sim.step(Op::Arrive(1 + script_rng.below(3) as usize)),
                        1 => sim.step(Op::Tick(script_rng.below(120) as u64)),
                        2 => sim.step(Op::Cut),
                        3 => sim.step(Op::CutTo(k)),
                        4 => sim.step(Op::Pop(k)),
                        _ => {
                            let victim = script_rng.below(shards as u32) as usize;
                            sim.step(Op::StealFrom { thief: k, victim });
                        }
                    }
                }
                let n = sim.next_id;
                sim.drain_to_completion();
                let r = sim.finish();
                assert_conservation(&r, n);
                assert_fifo_formation(&r);
                assert_eq!(r.local + r.stolen, r.cut_order.len() as u64 - {
                    // batches that were failed (pushed after close /
                    // dead-pool) were cut but never claimed
                    let failed_batches = r
                        .cut_order
                        .iter()
                        .filter(|run| run.iter().all(|id| r.failed.contains(id)))
                        .count();
                    failed_batches as u64
                });
                true
            },
        );
    }

    /// ISSUE #9: net admission racing shutdown.  Requests the gate
    /// admitted before the close are flushed and served; requests
    /// admitted after it fail fast at the closed deques — and shed +
    /// served + failed still partitions the arrivals exactly once.
    #[test]
    fn net_admit_racing_shutdown_is_exactly_once() {
        let cfg = SimConfig {
            shards: 2,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::NetArrive { n: 4, deadline_us: 0 }); // cold gate admits
        assert_eq!(sim.net_pending(), 4);
        sim.step(Op::Shutdown); // flushes the full batch, closes
        assert!(sim.is_closed());
        sim.step(Op::NetArrive { n: 4, deadline_us: 0 }); // land in the batcher…
        sim.step(Op::Shutdown); // …and the flush hits closed deques
        assert_eq!(sim.net_pending(), 4, "failed admits released their quota");
        sim.step(Op::Pop(0));
        sim.step(Op::Pop(1));
        assert_eq!(sim.net_pending(), 0, "served admits released their quota");
        let r = sim.finish();
        assert_conservation(&r, 8);
        assert_eq!(ids(&r.served), vec![0, 1, 2, 3], "pre-close admits served");
        assert_eq!(r.failed, vec![4, 5, 6, 7], "post-close admits fail fast");
        assert!(r.shed.is_empty() && r.expired.is_empty());
    }

    /// ISSUE #9: a deadline that lapses *in the queue* is answered
    /// `EXPIRED` by the reply-side sweep exactly once — the shard still
    /// computes the batch, but the late rows are discarded rather than
    /// double-counted as served.
    #[test]
    fn net_deadline_expiring_in_queue_is_shed_exactly_once() {
        let cfg = SimConfig {
            shards: 1,
            batch_size: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::NetArrive { n: 4, deadline_us: 300 }); // est 0: admitted
        assert_eq!(sim.net_pending(), 4);
        sim.step(Op::Cut); // one full batch onto the deque
        sim.step(Op::Tick(300)); // queue delay eats the whole deadline
        sim.step(Op::NetShed); // sweep answers all four EXPIRED
        assert_eq!(sim.net_pending(), 0);
        sim.step(Op::Pop(0)); // the shard still serves the batch…
        let r = sim.finish();
        assert_conservation(&r, 4);
        assert!(r.served.is_empty(), "…but the late rows are discarded");
        assert_eq!(r.expired, vec![0, 1, 2, 3]);
        assert!(r.failed.is_empty() && r.shed.is_empty());
    }

    /// ISSUE #9: quota exhaustion sheds the excess with an explicit
    /// OVERLOADED, and the quota frees as replies complete — later
    /// arrivals are admitted again.
    #[test]
    fn net_quota_sheds_excess_then_recovers() {
        let cfg = SimConfig {
            shards: 1,
            batch_size: 4,
            net_quota: 4,
            ..Default::default()
        };
        let mut sim = Sim::new(cfg);
        sim.step(Op::NetArrive { n: 6, deadline_us: 0 }); // 4 admitted, 2 shed
        assert_eq!(sim.net_pending(), 4, "quota caps the pending replies");
        sim.step(Op::Cut);
        sim.step(Op::Pop(0)); // replies go out: quota frees
        assert_eq!(sim.net_pending(), 0);
        sim.step(Op::NetArrive { n: 4, deadline_us: 0 }); // admitted again
        sim.step(Op::Cut);
        sim.step(Op::Pop(0));
        let r = sim.finish();
        assert_conservation(&r, 10);
        assert_eq!(r.shed, vec![4, 5], "overflow shed in arrival order");
        assert_eq!(r.served.len(), 8);
        assert!(r.expired.is_empty() && r.failed.is_empty());
    }

    /// ISSUE #9: the admission gate reads the LIVE backlog.  With a
    /// warm EWMA and eight requests pending, a tight-deadline arrival
    /// is shed at the door while a no-deadline and a loose-deadline one
    /// ride the same backlog in — and the whole trace replays
    /// bit-for-bit from the fixed seed.
    #[test]
    fn net_admission_gate_reads_live_backlog_and_replays() {
        let cfg = SimConfig {
            shards: 1,
            batch_size: 4,
            net_ewma_us: 100,
            ..Default::default()
        };
        let script = [
            Op::Arrive(8), // backlog: 2 forming batches = est 200 µs
            Op::NetArrive { n: 1, deadline_us: 150 }, // 200 > 150: shed
            Op::NetArrive { n: 1, deadline_us: 0 },   // no deadline: admitted
            Op::NetArrive { n: 1, deadline_us: 350 }, // est 300 ≤ 350: admitted
            Op::Cut,       // two full batches; ids 9,10 still forming
            Op::Pop(0),
            Op::Pop(0),
            Op::Tick(200), // partial batch past its deadline
            Op::Cut,
            Op::Pop(0),
        ];
        let a = run_script(cfg, &script);
        let b = run_script(cfg, &script);
        assert_eq!(a, b, "fixed seed must replay bit-for-bit");
        assert_conservation(&a, 11);
        assert_eq!(a.shed, vec![8], "only the tight deadline was shed");
        assert_eq!(a.served.len(), 10);
        assert!(a.expired.is_empty() && a.failed.is_empty());
    }

    /// ISSUE #8: prepare racing swap.  An eager worker (prep lands the
    /// moment a request is submitted) and a lagging one (the engine's
    /// take attempts keep arriving before the prep) walk different
    /// interleavings of the same hand-off — yet after the same number of
    /// completed passes both hold exactly the serial oracle's mask bits.
    #[test]
    fn prep_orderings_race_to_identical_masks() {
        use crate::masks::MaskPlan;
        let (man, _) = crate::testing::fixture::tiny_fixture();
        let seed = 0xAB5EED;
        let eager = [
            PrepOp::Submit,
            PrepOp::Prep,
            PrepOp::Take,
            PrepOp::Submit,
            PrepOp::Prep,
            PrepOp::Take,
        ];
        let racy = [
            PrepOp::Take, // nothing ready yet
            PrepOp::Submit,
            PrepOp::Take, // request not prepared yet
            PrepOp::Prep,
            PrepOp::Prep, // idle: nothing new submitted
            PrepOp::Take,
            PrepOp::Submit,
            PrepOp::Prep,
            PrepOp::Take,
            PrepOp::Take, // slot already empty
        ];
        let a = run_prep_script(&man, seed, (1, 2), &eager);
        let b = run_prep_script(&man, seed, (1, 2), &racy);
        assert_eq!(a.completed_passes, 2);
        assert_eq!(b.completed_passes, 2);
        assert_eq!(
            a.final_kept, b.final_kept,
            "interleaving changed the mask bits"
        );
        assert_eq!(
            b.log,
            vec![
                "take-not-ready",
                "submit",
                "take-not-ready",
                "prep",
                "prep-idle",
                "take",
                "submit",
                "prep",
                "take",
                "take-not-ready"
            ]
        );
        // …and both equal the serial oracle: two in-place resamples of
        // the same seed's stream.
        let mut rng = Pcg32::new(seed);
        let mut plan = MaskPlan::bernoulli(&man, 1.0 / man.scale, &mut rng);
        plan.resample(&mut rng);
        plan.resample(&mut rng);
        let mut oracle = Vec::new();
        for si in 0..plan.subnets().len() {
            for layer in [1usize, 2] {
                oracle.push(plan.layer(si, layer).kept_lists().to_vec());
            }
        }
        assert_eq!(a.final_kept, oracle, "pipelined masks != serial oracle");
        // replay determinism
        assert_eq!(run_prep_script(&man, seed, (1, 2), &racy), b);
    }

    /// ISSUE #8: the last-layer range flows through the protocol — a
    /// completed pass leaves layer-1 masks exactly as constructed.
    #[test]
    fn prep_last_layer_range_only_redraws_layer_two() {
        use crate::masks::MaskPlan;
        let (man, _) = crate::testing::fixture::tiny_fixture();
        let seed = 31u64;
        let r = run_prep_script(
            &man,
            seed,
            (2, 2),
            &[PrepOp::Submit, PrepOp::Prep, PrepOp::Take],
        );
        assert_eq!(r.completed_passes, 1);
        let mut rng = Pcg32::new(seed);
        let base = MaskPlan::bernoulli(&man, 1.0 / man.scale, &mut rng);
        for si in 0..base.subnets().len() {
            assert_eq!(
                r.final_kept[si * 2],
                base.layer(si, 1).kept_lists().to_vec(),
                "subnet {si}: layer-1 masks moved under a last-layer prep"
            );
        }
    }

    /// ISSUE #8: shutdown racing a pending request — the worker step
    /// refuses, the take side reports not-ready, nothing hangs, and a
    /// submit with nothing held is a visible no-op (not a crash).
    #[test]
    fn prep_shutdown_and_empty_steps_are_loud_no_ops() {
        let (man, _) = crate::testing::fixture::tiny_fixture();
        let r = run_prep_script(
            &man,
            7,
            (1, 2),
            &[
                PrepOp::Prep, // nothing submitted yet
                PrepOp::Submit,
                PrepOp::Submit, // stale plan already handed over
                PrepOp::Shutdown,
                PrepOp::Prep, // pending request, but protocol is down
                PrepOp::Take,
            ],
        );
        assert_eq!(
            r.log,
            vec![
                "prep-idle",
                "submit",
                "submit-nothing-held",
                "shutdown",
                "prep-idle",
                "take-not-ready"
            ]
        );
        assert_eq!(r.completed_passes, 0);
    }

    /// Satellite property: a slow (never-claiming) victim shard cannot
    /// strand its backlog — thieves drain it completely, in FIFO order,
    /// even when the shutdown lands mid-drain.
    #[test]
    fn property_slow_victim_is_fully_drained_by_thieves() {
        forall_seeded(
            0xBAD_5EED,
            40,
            Gen::usize_in(0, 1 << 30),
            |&case_seed| {
                let mut script_rng = Pcg32::new(case_seed as u64);
                let shards = 2 + script_rng.below(3) as usize;
                let cfg = SimConfig {
                    shards,
                    nb: 2,
                    batch_size: 2,
                    max_wait_us: 50,
                    queue_capacity: 10_000,
                    seed: case_seed as u64,
                };
                let mut sim = Sim::new(cfg);
                let n_arrive = 4 + script_rng.below(20) as usize;
                sim.step(Op::Arrive(n_arrive));
                sim.step(Op::Tick(1_000));
                sim.step(Op::CutTo(0)); // shard 0 is the stalled victim
                let early_shutdown = script_rng.below(2) == 0;
                if early_shutdown {
                    sim.step(Op::Shutdown);
                }
                // only the *other* shards ever claim
                let mut guard = 0;
                while sim.queued() > 0 {
                    let thief = 1 + script_rng.below((shards - 1) as u32) as usize;
                    sim.step(Op::StealFrom { thief, victim: 0 });
                    guard += 1;
                    assert!(guard < 10_000, "thieves failed to drain the victim");
                }
                if !early_shutdown {
                    sim.step(Op::Shutdown);
                }
                let r = sim.finish();
                assert_conservation(&r, n_arrive as u64);
                assert!(r.failed.is_empty() && r.rejected.is_empty());
                assert_eq!(r.local, 0, "the victim never claimed");
                // steals drain the victim FIFO: service order == arrival
                // order
                assert_eq!(
                    ids(&r.served),
                    (0..n_arrive as u64).collect::<Vec<_>>(),
                    "FIFO-per-request delivery under pure stealing"
                );
                true
            },
        );
    }
}
