//! Mini property-testing framework (proptest substitute, DESIGN.md §7).
//!
//! Provides seeded generators and a `forall` runner with greedy shrinking:
//! when a case fails, the runner re-tries progressively "smaller" variants
//! produced by the generator's `shrink` and reports the smallest failure.
//!
//! Usage:
//! ```no_run
//! use uivim::testing::{forall, Gen};
//! forall(100, Gen::usize_in(1, 64), |&n| n >= 1 && n <= 64);
//! ```

use crate::util::rng::Pcg32;

pub mod sched;

/// A seeded generator of values of `T` plus a shrinking strategy.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Pcg32) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + std::fmt::Debug + 'static> Gen<T> {
    /// Build from explicit generate/shrink closures.
    pub fn new(
        gen: impl Fn(&mut Pcg32) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    /// Generator with no shrinking.
    pub fn no_shrink(gen: impl Fn(&mut Pcg32) -> T + 'static) -> Self {
        Gen::new(gen, |_| Vec::new())
    }

    /// Map the generated value (shrinks are mapped too — requires the
    /// mapping to be cheap and pure).
    pub fn map<U: Clone + std::fmt::Debug + 'static>(
        self,
        f: impl Fn(T) -> U + Clone + 'static,
    ) -> Gen<U> {
        let f2 = f.clone();
        let gen = self.gen;
        let shrink = self.shrink;
        // Shrinking through a map needs the inverse; we instead shrink in
        // the source domain by regenerating: keep a copy of the source via
        // pairing. For simplicity, mapped generators do not shrink.
        let _ = shrink;
        Gen::no_shrink(move |rng| f2((gen)(rng)))
    }
}

impl Gen<usize> {
    /// Uniform usize in `[lo, hi]` inclusive; shrinks toward `lo`.
    pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
        assert!(lo <= hi);
        Gen::new(
            move |rng| lo + rng.below((hi - lo + 1) as u32) as usize,
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2);
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl Gen<f64> {
    /// Uniform f64 in `[lo, hi)`; shrinks toward `lo` and 0/1 landmarks.
    pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
        assert!(lo < hi);
        Gen::new(
            move |rng| rng.uniform(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if v != lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2.0);
                }
                out
            },
        )
    }
}

impl Gen<Vec<f64>> {
    /// Vector of given length range with elements in `[lo, hi)`; shrinks by
    /// halving the length.
    pub fn f64_vec(len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> Gen<Vec<f64>> {
        Gen::new(
            move |rng| {
                let n = len_lo + rng.below((len_hi - len_lo + 1) as u32) as usize;
                (0..n).map(|_| rng.uniform(lo, hi)).collect()
            },
            move |v: &Vec<f64>| {
                let mut out = Vec::new();
                if v.len() > len_lo {
                    out.push(v[..len_lo.max(v.len() / 2)].to_vec());
                    out.push(v[..v.len() - 1].to_vec());
                }
                out
            },
        )
    }
}

/// Pair two generators.
pub fn zip<A: Clone + std::fmt::Debug + 'static, B: Clone + std::fmt::Debug + 'static>(
    a: Gen<A>,
    b: Gen<B>,
) -> Gen<(A, B)> {
    let (ga, sa) = (a.gen, a.shrink);
    let (gb, sb) = (b.gen, b.shrink);
    Gen::new(
        move |rng| ((ga)(rng), (gb)(rng)),
        move |(x, y)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for xs in (sa)(x) {
                out.push((xs, y.clone()));
            }
            for ys in (sb)(y) {
                out.push((x.clone(), ys));
            }
            out
        },
    )
}

/// Run `cases` random cases of `prop`; on failure, shrink greedily and
/// panic with the smallest failing input.  Seeded deterministically so CI
/// failures reproduce.
pub fn forall<T: Clone + std::fmt::Debug>(cases: usize, gen: Gen<T>, prop: impl Fn(&T) -> bool) {
    forall_seeded(0xC0FFEE, cases, gen, prop)
}

/// `forall` with an explicit seed.
pub fn forall_seeded<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let input = (gen.gen)(&mut rng);
        if !prop(&input) {
            // Greedy shrink: repeatedly take the first failing shrink.
            let mut smallest = input.clone();
            let mut budget = 20_000;
            'outer: while budget > 0 {
                for cand in (gen.shrink)(&smallest) {
                    budget -= 1;
                    if !prop(&cand) {
                        smallest = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case}: input {input:?} (shrunk to {smallest:?})"
            );
        }
    }
}

/// Deterministic in-memory artifact fixtures, so engine / coordinator /
/// masks tests run everywhere instead of skipping when the Python-side
/// artifacts (`make artifacts`) are absent.
///
/// The fixture mirrors the real compile path's conventions exactly:
/// layout names (`{subnet}.w1` …), mask keys (`{subnet}.mask{1,2}`), the
/// per-(subnet, layer) mask-seed rule, and `batch_train % n_samples == 0`
/// — so `Manifest::validate` and `verify_mask_parity` both pass on it.
pub mod fixture {
    use std::collections::BTreeMap;

    use crate::masks::{for_width, subnet_layer_seed};
    use crate::model::manifest::{AdamHyper, LayoutEntry, Manifest};
    use crate::model::Weights;

    /// Fixture knobs; `Default` is the "tiny-like" shape used by most
    /// unit tests.
    #[derive(Debug, Clone)]
    pub struct FixtureConfig {
        pub nb: usize,
        pub n_samples: usize,
        pub scale: f64,
        pub mask_seed: u64,
        pub batch_infer: usize,
        pub weight_seed: u64,
    }

    impl Default for FixtureConfig {
        fn default() -> Self {
            FixtureConfig {
                nb: 11,
                n_samples: 4,
                scale: 2.0,
                mask_seed: 2024,
                batch_infer: 16,
                weight_seed: 7,
            }
        }
    }

    /// Synthetic b-value protocol of length `nb` (starts at b=0 so the
    /// data generator's normalisation works).
    pub fn fixture_bvalues(nb: usize) -> Vec<f64> {
        (0..nb)
            .map(|i| {
                if nb < 2 {
                    0.0
                } else {
                    800.0 * (i as f64 / (nb - 1) as f64).powi(2)
                }
            })
            .collect()
    }

    /// Build a validated manifest + deterministic He-initialised weights.
    pub fn build(cfg: &FixtureConfig) -> (Manifest, Weights) {
        let nb = cfg.nb;
        let subnets: Vec<String> =
            ["d", "dstar", "f", "s0"].iter().map(|s| s.to_string()).collect();

        let mut param_layout = Vec::new();
        let mut bn_layout = Vec::new();
        let mut p_off = 0usize;
        let mut b_off = 0usize;
        let mut push_p = |layout: &mut Vec<LayoutEntry>, name: String, shape: Vec<usize>| {
            let len: usize = shape.iter().product();
            layout.push(LayoutEntry {
                name,
                offset: p_off,
                shape,
            });
            p_off += len;
        };
        for sn in &subnets {
            push_p(&mut param_layout, format!("{sn}.w1"), vec![nb, nb]);
            push_p(&mut param_layout, format!("{sn}.b1"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.g1"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.be1"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.w2"), vec![nb, nb]);
            push_p(&mut param_layout, format!("{sn}.b2"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.g2"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.be2"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.w3"), vec![nb]);
            push_p(&mut param_layout, format!("{sn}.b3"), vec![1]);
            for bn_name in ["m1", "v1", "m2", "v2"] {
                bn_layout.push(LayoutEntry {
                    name: format!("{sn}.{bn_name}"),
                    offset: b_off,
                    shape: vec![nb],
                });
                b_off += nb;
            }
        }

        let mut masks = BTreeMap::new();
        for (si, sn) in subnets.iter().enumerate() {
            for layer in 1..=2usize {
                let seed = subnet_layer_seed(cfg.mask_seed, si, layer);
                let m = for_width(nb, cfg.n_samples, cfg.scale, seed)
                    .expect("fixture mask generation");
                masks.insert(format!("{sn}.mask{layer}"), m);
            }
        }

        let man = Manifest {
            variant: "fixture".to_string(),
            nb,
            n_samples: cfg.n_samples,
            scale: cfg.scale,
            mask_seed: cfg.mask_seed,
            batch_infer: cfg.batch_infer,
            batch_train: cfg.n_samples * 8,
            param_count: p_off,
            bn_count: b_off,
            bvalues: fixture_bvalues(nb),
            subnets,
            adam: AdamHyper {
                lr: 1e-3,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            bn_momentum: 0.1,
            param_layout,
            bn_layout,
            masks,
            files: BTreeMap::new(),
            dir: std::env::temp_dir().join("uivim_fixture"),
        };
        man.validate().expect("fixture manifest is self-consistent");
        let weights = Weights::init_random(&man, cfg.weight_seed);
        (man, weights)
    }

    /// The default small fixture (nb=11, 4 mask samples, scale 2.0).
    pub fn tiny_fixture() -> (Manifest, Weights) {
        build(&FixtureConfig::default())
    }

    /// A paper-scale fixture (nb=104, the Table II shape) for perf tests
    /// and benches.
    pub fn paper_fixture() -> (Manifest, Weights) {
        build(&FixtureConfig {
            nb: 104,
            batch_infer: 64,
            ..Default::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, Gen::usize_in(1, 64), |&n| (1..=64).contains(&n));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(200, Gen::usize_in(0, 100), |&n| n < 90);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            forall(200, Gen::usize_in(0, 1000), |&n| n < 500)
        });
        let msg = match result {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("expected failure"),
        };
        // greedy shrink should walk down to exactly the boundary 500
        assert!(msg.contains("shrunk to 500"), "{msg}");
    }

    #[test]
    fn zip_generates_pairs() {
        forall(
            100,
            zip(Gen::usize_in(1, 8), Gen::f64_in(0.0, 1.0)),
            |&(n, x)| n >= 1 && n <= 8 && (0.0..1.0).contains(&x),
        );
    }

    #[test]
    fn vec_gen_respects_bounds() {
        forall(100, Gen::<Vec<f64>>::f64_vec(1, 16, -1.0, 1.0), |v| {
            (1..=16).contains(&v.len()) && v.iter().all(|x| (-1.0..1.0).contains(x))
        });
    }

    #[test]
    fn fixture_manifest_is_valid_and_parity_checked() {
        let (man, w) = fixture::tiny_fixture();
        man.validate().unwrap();
        man.verify_mask_parity().unwrap();
        assert_eq!(man.nb, 11);
        assert_eq!(man.bvalues.len(), man.nb);
        assert_eq!(man.masks.len(), 8); // 4 subnets x 2 layers
        assert_eq!(w.params.len(), man.param_count);
        assert_eq!(w.bn.len(), man.bn_count);
        // subnet views resolve with the right shapes
        for sn in &man.subnets {
            let s = w.subnet(&man, sn);
            assert_eq!(s.w1.len(), man.nb * man.nb);
            assert_eq!(s.b3.len(), 1);
            assert_eq!(s.v2.len(), man.nb);
        }
    }

    #[test]
    fn fixture_is_deterministic() {
        let (a_man, a_w) = fixture::tiny_fixture();
        let (b_man, b_w) = fixture::tiny_fixture();
        assert_eq!(a_man.masks, b_man.masks);
        assert_eq!(a_w.params, b_w.params);
        assert_eq!(a_w.bn, b_w.bn);
    }

    #[test]
    fn fixture_custom_shapes() {
        let (man, w) = fixture::build(&fixture::FixtureConfig {
            nb: 21,
            n_samples: 6,
            batch_infer: 5,
            ..Default::default()
        });
        assert_eq!(man.nb, 21);
        assert_eq!(man.n_samples, 6);
        assert_eq!(man.batch_train % man.n_samples, 0);
        assert_eq!(w.params.len(), man.param_count);
        // an engine built on the fixture actually runs
        let mut eng = crate::infer::native::NativeEngine::new(&man, &w).unwrap();
        let ds = crate::ivim::synth::synth_dataset(man.batch_infer, &man.bvalues, 20.0, 1);
        let out = crate::infer::Engine::infer_batch(&mut eng, &ds.signals).unwrap();
        assert_eq!(out.batch, 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let g1 = Gen::usize_in(0, 1_000_000);
        let g2 = Gen::usize_in(0, 1_000_000);
        let mut r1 = Pcg32::new(77);
        let mut r2 = Pcg32::new(77);
        for _ in 0..10 {
            a.push((g1.gen)(&mut r1));
            b.push((g2.gen)(&mut r2));
        }
        assert_eq!(a, b);
    }
}
