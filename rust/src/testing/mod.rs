//! Mini property-testing framework (proptest substitute, DESIGN.md §6).
//!
//! Provides seeded generators and a `forall` runner with greedy shrinking:
//! when a case fails, the runner re-tries progressively "smaller" variants
//! produced by the generator's `shrink` and reports the smallest failure.
//!
//! Usage:
//! ```no_run
//! use uivim::testing::{forall, Gen};
//! forall(100, Gen::usize_in(1, 64), |&n| n >= 1 && n <= 64);
//! ```

use crate::util::rng::Pcg32;

/// A seeded generator of values of `T` plus a shrinking strategy.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Pcg32) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + std::fmt::Debug + 'static> Gen<T> {
    /// Build from explicit generate/shrink closures.
    pub fn new(
        gen: impl Fn(&mut Pcg32) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    /// Generator with no shrinking.
    pub fn no_shrink(gen: impl Fn(&mut Pcg32) -> T + 'static) -> Self {
        Gen::new(gen, |_| Vec::new())
    }

    /// Map the generated value (shrinks are mapped too — requires the
    /// mapping to be cheap and pure).
    pub fn map<U: Clone + std::fmt::Debug + 'static>(
        self,
        f: impl Fn(T) -> U + Clone + 'static,
    ) -> Gen<U> {
        let f2 = f.clone();
        let gen = self.gen;
        let shrink = self.shrink;
        // Shrinking through a map needs the inverse; we instead shrink in
        // the source domain by regenerating: keep a copy of the source via
        // pairing. For simplicity, mapped generators do not shrink.
        let _ = shrink;
        Gen::no_shrink(move |rng| f2((gen)(rng)))
    }
}

impl Gen<usize> {
    /// Uniform usize in `[lo, hi]` inclusive; shrinks toward `lo`.
    pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
        assert!(lo <= hi);
        Gen::new(
            move |rng| lo + rng.below((hi - lo + 1) as u32) as usize,
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2);
                    out.push(v - 1);
                }
                out.dedup();
                out
            },
        )
    }
}

impl Gen<f64> {
    /// Uniform f64 in `[lo, hi)`; shrinks toward `lo` and 0/1 landmarks.
    pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
        assert!(lo < hi);
        Gen::new(
            move |rng| rng.uniform(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if v != lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2.0);
                }
                out
            },
        )
    }
}

impl Gen<Vec<f64>> {
    /// Vector of given length range with elements in `[lo, hi)`; shrinks by
    /// halving the length.
    pub fn f64_vec(len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> Gen<Vec<f64>> {
        Gen::new(
            move |rng| {
                let n = len_lo + rng.below((len_hi - len_lo + 1) as u32) as usize;
                (0..n).map(|_| rng.uniform(lo, hi)).collect()
            },
            move |v: &Vec<f64>| {
                let mut out = Vec::new();
                if v.len() > len_lo {
                    out.push(v[..len_lo.max(v.len() / 2)].to_vec());
                    out.push(v[..v.len() - 1].to_vec());
                }
                out
            },
        )
    }
}

/// Pair two generators.
pub fn zip<A: Clone + std::fmt::Debug + 'static, B: Clone + std::fmt::Debug + 'static>(
    a: Gen<A>,
    b: Gen<B>,
) -> Gen<(A, B)> {
    let (ga, sa) = (a.gen, a.shrink);
    let (gb, sb) = (b.gen, b.shrink);
    Gen::new(
        move |rng| ((ga)(rng), (gb)(rng)),
        move |(x, y)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for xs in (sa)(x) {
                out.push((xs, y.clone()));
            }
            for ys in (sb)(y) {
                out.push((x.clone(), ys));
            }
            out
        },
    )
}

/// Run `cases` random cases of `prop`; on failure, shrink greedily and
/// panic with the smallest failing input.  Seeded deterministically so CI
/// failures reproduce.
pub fn forall<T: Clone + std::fmt::Debug>(cases: usize, gen: Gen<T>, prop: impl Fn(&T) -> bool) {
    forall_seeded(0xC0FFEE, cases, gen, prop)
}

/// `forall` with an explicit seed.
pub fn forall_seeded<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let input = (gen.gen)(&mut rng);
        if !prop(&input) {
            // Greedy shrink: repeatedly take the first failing shrink.
            let mut smallest = input.clone();
            let mut budget = 20_000;
            'outer: while budget > 0 {
                for cand in (gen.shrink)(&smallest) {
                    budget -= 1;
                    if !prop(&cand) {
                        smallest = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case}: input {input:?} (shrunk to {smallest:?})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, Gen::usize_in(1, 64), |&n| (1..=64).contains(&n));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(200, Gen::usize_in(0, 100), |&n| n < 90);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            forall(200, Gen::usize_in(0, 1000), |&n| n < 500)
        });
        let msg = match result {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("expected failure"),
        };
        // greedy shrink should walk down to exactly the boundary 500
        assert!(msg.contains("shrunk to 500"), "{msg}");
    }

    #[test]
    fn zip_generates_pairs() {
        forall(
            100,
            zip(Gen::usize_in(1, 8), Gen::f64_in(0.0, 1.0)),
            |&(n, x)| n >= 1 && n <= 8 && (0.0..1.0).contains(&x),
        );
    }

    #[test]
    fn vec_gen_respects_bounds() {
        forall(100, Gen::<Vec<f64>>::f64_vec(1, 16, -1.0, 1.0), |v| {
            (1..=16).contains(&v.len()) && v.iter().all(|x| (-1.0..1.0).contains(x))
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let g1 = Gen::usize_in(0, 1_000_000);
        let g2 = Gen::usize_in(0, 1_000_000);
        let mut r1 = Pcg32::new(77);
        let mut r2 = Pcg32::new(77);
        for _ in 0..10 {
            a.push((g1.gen)(&mut r1));
            b.push((g2.gen)(&mut r2));
        }
        assert_eq!(a, b);
    }
}
