//! `repro` — the uIVIM-NET leader binary: training, inference, serving
//! and every paper experiment behind one CLI.
//!
//! Python never runs here: all compute comes from the AOT artifacts
//! (PJRT), the native engine or the accelerator simulator.

use uivim::accel::{AccelConfig, AccelSimulator, Scheme};
use uivim::bench;
use uivim::cli::{flag, opt, Args, Cli, CommandSpec};
use uivim::coordinator::{Coordinator, CoordinatorConfig, NetClient, NetConfig, NetServer};
use uivim::experiments::{self, fig67, fig8, tables};
use uivim::infer::registry::{self, EngineOpts};
use uivim::ivim::synth::synth_dataset;
use uivim::ivim::Param;
use uivim::masks;
use uivim::metrics::report::write_report;
use uivim::model::Weights;
use uivim::runtime::Runtime;
use uivim::train::{train, TrainConfig};
use uivim::util::frame::Status;
use uivim::util::Timer;

fn cli() -> Cli {
    let variant = || opt("variant", "artifact variant (tiny|paper)", Some("tiny"));
    let engine = || {
        opt(
            "engine",
            "registry engine (native|accel|accel-mc|mc-dropout|mc-dropout-ll|ensemble|pjrt)",
            Some("native"),
        )
    };
    let weights_opt = || opt("weights", "weights stem (<stem>.params.bin/.bn.bin)", None);
    let train_steps = || {
        opt(
            "train-steps",
            "steps to train before eval (0 = init weights)",
            Some("300"),
        )
    };
    Cli {
        program: "repro",
        about: "uIVIM-NET: mask-based Bayesian MRI uncertainty estimation (paper reproduction)",
        commands: vec![
            CommandSpec {
                name: "info",
                help: "show artifact, platform and mask-parity status",
                opts: vec![variant()],
            },
            CommandSpec {
                name: "train",
                help: "train uIVIM-NET via the AOT train-step executable",
                opts: vec![
                    variant(),
                    opt("steps", "training steps", Some("500")),
                    opt("snr", "training data SNR", Some("20")),
                    opt("seed", "data stream seed", Some("1")),
                    opt("out", "output weights stem", Some("reports/weights")),
                ],
            },
            CommandSpec {
                name: "infer",
                help: "run batch inference with uncertainty on synthetic voxels",
                opts: vec![
                    variant(),
                    engine(),
                    weights_opt(),
                    opt("n", "number of voxels", Some("64")),
                    opt("snr", "noise level", Some("20")),
                ],
            },
            CommandSpec {
                name: "serve",
                help: "demo the serving coordinator on a synthetic request stream",
                opts: vec![
                    variant(),
                    engine(),
                    weights_opt(),
                    opt("requests", "number of requests", Some("1000")),
                    opt("batch", "dynamic batch size (default: variant batch)", None),
                    opt("shards", "worker shards (engines) in the pool", Some("1")),
                    opt("threads", "GEMM worker lanes per engine (bit-exact)", Some("1")),
                    flag(
                        "overlap",
                        "prepare MC mask plans on a background worker (bit-exact)",
                    ),
                    opt(
                        "listen",
                        "serve framed TCP requests on this address (e.g. 127.0.0.1:7070; \
                         port 0 = ephemeral) and run the demo stream through a loopback client",
                        None,
                    ),
                    opt("max-conns", "live TCP connection cap for --listen", Some("64")),
                ],
            },
            CommandSpec {
                name: "client",
                help: "framed-TCP smoke client: send synthetic voxels to a running \
                       `serve --listen` front door",
                opts: vec![
                    variant(),
                    opt("connect", "server address (host:port)", Some("127.0.0.1:7070")),
                    opt("requests", "number of requests", Some("16")),
                    opt(
                        "deadline-us",
                        "per-request deadline in µs (0 = none; overloaded servers shed \
                         deadlines they cannot meet)",
                        Some("0"),
                    ),
                    opt("snr", "noise level", Some("20")),
                    opt("seed", "data stream seed", Some("18")),
                ],
            },
            CommandSpec {
                name: "volume",
                help: "stream a full 3-D volume through the coordinator, assembling \
                       parameter/uncertainty maps slice by slice",
                opts: vec![
                    variant(),
                    engine(),
                    weights_opt(),
                    train_steps(),
                    opt("dim", "volume dimensions X,Y,Z", Some("16,16,8")),
                    opt(
                        "slices-in-flight",
                        "max slices awaiting completion (backpressure cap)",
                        Some("2"),
                    ),
                    opt("snr", "noise level", Some("20")),
                    opt("seed", "volume generation seed", Some("11")),
                    opt("batch", "dynamic batch size (default: variant batch)", None),
                    opt("shards", "worker shards (engines) in the pool", Some("1")),
                    opt("threads", "GEMM worker lanes per engine (bit-exact)", Some("1")),
                    flag(
                        "overlap",
                        "prepare MC mask plans on a background worker (bit-exact)",
                    ),
                    opt(
                        "out",
                        "PGM stem: writes D mean/relative map stacks under this path",
                        None,
                    ),
                    flag(
                        "sweep",
                        "run the clinical scenario sweep (protocol x corruption grid)",
                    ),
                ],
            },
            CommandSpec {
                name: "fig6",
                help: "Fig. 6 — RMSE vs evaluation SNR",
                opts: vec![
                    variant(),
                    engine(),
                    weights_opt(),
                    train_steps(),
                    opt("voxels", "voxels per SNR", Some("2000")),
                    opt("out", "CSV output path", Some("reports/fig6_fig7.csv")),
                ],
            },
            CommandSpec {
                name: "fig7",
                help: "Fig. 7 — relative uncertainty vs evaluation SNR",
                opts: vec![
                    variant(),
                    engine(),
                    weights_opt(),
                    train_steps(),
                    opt("voxels", "voxels per SNR", Some("2000")),
                    opt("out", "CSV output path", Some("reports/fig6_fig7.csv")),
                ],
            },
            CommandSpec {
                name: "fig8",
                help: "Fig. 8 — resource utilisation & speed vs PE count",
                opts: vec![
                    variant(),
                    weights_opt(),
                    flag("check-model", "assert eq. (2) matches the simulator"),
                    opt(
                        "keep-rates",
                        "comma-separated mask keep rates in (0,1] — sweep the PE grid per rate",
                        None,
                    ),
                    opt("mask-seed", "mask resampling seed for --keep-rates", Some("17")),
                ],
            },
            CommandSpec {
                name: "table1",
                help: "Table I — energy efficiency vs prior FPGA designs",
                opts: vec![variant(), weights_opt()],
            },
            CommandSpec {
                name: "table2",
                help: "Table II — latency/power/energy: CPU vs GPU vs FPGA",
                opts: vec![variant(), weights_opt()],
            },
            CommandSpec {
                name: "schemes",
                help: "ablation: batch-level vs sampling-level weight loading",
                opts: vec![variant(), weights_opt()],
            },
            CommandSpec {
                name: "flow",
                help: "run the Fig. 1 co-design flow: train, check uncertainty requirements, map to hardware",
                opts: vec![
                    variant(),
                    opt("steps", "phase-2 training steps", Some("200")),
                    opt("realtime-ms", "phase-3 real-time budget (ms/batch)", Some("0.8")),
                ],
            },
            CommandSpec {
                name: "gridsearch",
                help: "Phase-2 grid search: dropout rate x sampling number (paper §III)",
                opts: vec![
                    variant(),
                    weights_opt(),
                    train_steps(),
                    opt("rates", "comma-separated dropout rates", Some("0.1,0.3,0.5,0.7,0.9")),
                    opt("samples", "comma-separated sampling numbers", Some("4,8,16")),
                    opt("voxels", "evaluation voxels per candidate", Some("256")),
                ],
            },
            CommandSpec {
                name: "ablation",
                help: "Masksembles vs MC-Dropout vs Deep-Ensembles uncertainty/hardware trade-off",
                opts: vec![variant(), weights_opt(), train_steps()],
            },
            CommandSpec {
                name: "bench-diff",
                help: "compare a fresh BENCH_*.json against a committed baseline (CI perf gate)",
                opts: vec![
                    opt("baseline", "baseline BENCH json (committed)", None),
                    opt("current", "freshly emitted BENCH json", None),
                    opt(
                        "max-regress",
                        "allowed p50 regression fraction before failing",
                        Some("0.20"),
                    ),
                ],
            },
            CommandSpec {
                name: "lint",
                help: "run the repo-invariant static analyzer over src/ and benches/",
                opts: vec![
                    opt(
                        "root",
                        "crate dir containing src/ and benches/ (default: auto-detect)",
                        None,
                    ),
                    flag("json", "emit machine-readable findings as JSON"),
                ],
            },
            CommandSpec {
                name: "masks",
                help: "generate and inspect Masksembles masks",
                opts: vec![
                    opt("width", "layer width", Some("11")),
                    opt("n", "number of masks", Some("4")),
                    opt("scale", "Masksembles scale", Some("2.0")),
                    opt("seed", "generator seed", Some("2024")),
                ],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(if argv.is_empty() { 0 } else { 2 });
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn engine_and_weights(
    args: &Args,
    rt: Option<&Runtime>,
) -> anyhow::Result<(uivim::model::Manifest, Weights, String)> {
    let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
    let kind = args.get_or("engine", "native").to_string();
    // fail fast (registry's own error message) before resolving weights
    registry::default_registry().validate(&kind)?;
    let steps = args.get_usize("train-steps")?.unwrap_or(0);
    let w = experiments::resolve_weights(&man, rt, args.get("weights"), steps, 20.0)?;
    Ok((man, w, kind))
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.command.as_str() {
        "info" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            println!("variant        : {}", man.variant);
            println!("b-values       : {} (nb)", man.nb);
            println!("mask samples   : {}", man.n_samples);
            println!("batch (infer)  : {}", man.batch_infer);
            println!("parameters     : {}", man.param_count);
            let rt = Runtime::cpu();
            match &rt {
                Ok(rt) => println!(
                    "platform       : {} ({} devices)",
                    rt.platform(),
                    rt.device_count()
                ),
                Err(e) => println!("platform       : PJRT unavailable ({e})"),
            }
            man.verify_mask_parity()?;
            println!("mask parity    : OK (Rust generator == python artifacts)");
            let w = Weights::load_init(&man)?;
            match rt.and_then(|rt| {
                uivim::runtime::InferExecutable::load(&rt, &man, &w)?.verify_golden()
            }) {
                Ok(()) => println!("golden check   : OK (PJRT output == python gold)"),
                Err(e) => println!("golden check   : SKIPPED ({e})"),
            }
        }
        "train" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            let rt = Runtime::cpu()?;
            let cfg = TrainConfig {
                steps: args.get_usize("steps")?.unwrap_or(500),
                snr: args.get_f64("snr")?.unwrap_or(20.0),
                seed: args.get_usize("seed")?.unwrap_or(1) as u64,
                log_every: 50,
                early_stop_rel: 0.0,
            };
            println!("training {} steps at SNR {} ...", cfg.steps, cfg.snr);
            let rep = train(&rt, &man, &cfg, None)?;
            println!(
                "loss {:.6} -> {:.6} over {} steps in {:.1}s ({:.1} steps/s)",
                rep.initial_loss(),
                rep.final_loss(),
                rep.steps_run,
                rep.seconds,
                rep.steps_run as f64 / rep.seconds
            );
            let stem = std::path::PathBuf::from(args.get_or("out", "reports/weights"));
            if let Some(p) = stem.parent() {
                std::fs::create_dir_all(p)?;
            }
            rep.final_weights.save(&stem)?;
            println!("weights saved to {}.params.bin / .bn.bin", stem.display());
            let curve: String = rep
                .losses
                .iter()
                .enumerate()
                .map(|(i, l)| format!("{i},{l}\n"))
                .collect();
            write_report(
                &stem.with_extension("loss.csv"),
                &format!("step,loss\n{curve}"),
            )?;
        }
        "infer" => {
            let rt = Runtime::cpu().ok();
            let (man, w, kind) = engine_and_weights(args, rt.as_ref())?;
            let n = args.get_usize("n")?.unwrap_or(64);
            let snr = args.get_f64("snr")?.unwrap_or(20.0);
            let ds = synth_dataset(n, &man.bvalues, snr, 17);
            // the registry owns runtime creation for pjrt
            let mut engine = registry::build(&kind, &man, &w, &EngineOpts::default())?;
            let t = Timer::start();
            let outs = fig67::run_batches(engine.as_mut(), &ds)?;
            let el = t.elapsed_ms();
            println!(
                "{} voxels on {} in {:.2} ms ({:.0} voxels/s)",
                n,
                engine.name(),
                el,
                n as f64 / (el / 1e3)
            );
            for p in Param::ALL {
                let rmse = uivim::metrics::rmse_by_param(&outs, &ds, p);
                let unc = uivim::metrics::mean_relative_uncertainty(&outs, p, ds.len());
                println!(
                    "  {:<6} rmse {:.6}  rel-uncertainty {:.4}",
                    p.name(),
                    rmse,
                    unc
                );
            }
        }
        "serve" => {
            let rt = Runtime::cpu().ok();
            let (man, w, kind) = engine_and_weights(args, rt.as_ref())?;
            let n = args.get_usize("requests")?.unwrap_or(1000);
            let batch = args.get_usize("batch")?.unwrap_or(man.batch_infer).max(1);
            let shards = args.get_usize("shards")?.unwrap_or(1).max(1);
            let cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
            let opts = EngineOpts {
                batch: Some(batch),
                threads: args.get_usize("threads")?.unwrap_or(1).max(1),
                overlap: args.flag("overlap"),
                ..Default::default()
            };
            let coord = Coordinator::start(cfg, registry::factory(&kind, man.clone(), w, opts)?)?;
            let ds = synth_dataset(n, &man.bvalues, 20.0, 18);
            if let Some(listen) = args.get("listen") {
                // TCP front door + loopback smoke client: the same demo
                // stream, but framed over a real socket.
                let coord = std::sync::Arc::new(coord);
                let net_cfg = NetConfig {
                    max_conns: args.get_usize("max-conns")?.unwrap_or(64).max(1),
                    ..Default::default()
                };
                let server = NetServer::start(std::sync::Arc::clone(&coord), listen, net_cfg)?;
                println!(
                    "serving framed TCP on {} ({shards} shards, batch {batch})",
                    server.addr()
                );
                let mut client = NetClient::connect(&server.addr().to_string())?;
                let t = Timer::start();
                let (mut confident, mut not_ok) = (0usize, 0usize);
                for i in 0..n {
                    let reply = client.request(i as u64, 0, ds.voxel(i))?;
                    anyhow::ensure!(
                        reply.id == i as u64,
                        "reply {} routed to request {i}",
                        reply.id
                    );
                    if reply.status == Status::Ok {
                        if reply.report.is_some_and(|r| r.confident) {
                            confident += 1;
                        }
                    } else {
                        not_ok += 1;
                    }
                }
                let el = t.elapsed_s();
                let snap = coord.snapshot();
                println!(
                    "{n} framed requests in {el:.2}s -> {:.0} vox/s | frames {} | shed {} | \
                     bad {} | expired {} | connections {} | non-OK {not_ok} | \
                     confident {:.1}%",
                    n as f64 / el,
                    snap.net_frames,
                    snap.net_shed,
                    snap.net_bad_frames,
                    snap.net_expired,
                    snap.net_connections,
                    100.0 * confident as f64 / n as f64
                );
                println!(
                    "admission: est queue delay {} µs | ewma batch {:.0} µs | lease \
                     high-water {}",
                    coord.estimated_queue_delay_us(),
                    snap.ewma_batch_us,
                    coord.lease_high_water()
                );
                server.shutdown();
                if let Ok(c) = std::sync::Arc::try_unwrap(coord) {
                    c.shutdown();
                }
                return Ok(());
            }
            let t = Timer::start();
            // the zero-alloc client path: leased buffers, reclaimed by
            // the dispatcher at batch-cut time
            let rxs: Vec<_> = (0..n)
                .map(|i| {
                    let mut lease = coord.lease();
                    lease.copy_from(ds.voxel(i));
                    coord
                        .submit_leased(i as u64, lease)
                        .expect("no backpressure expected in demo")
                })
                .collect();
            let mut confident = 0usize;
            for rx in rxs {
                let resp = rx.recv()?;
                if resp.report.confident {
                    confident += 1;
                }
            }
            let el = t.elapsed_s();
            let snap = coord.snapshot();
            println!(
                "{n} requests in {:.2}s -> {:.0} vox/s | batches {} | padded rows {} | \
                 mean request latency {:.2} ms | p99 {:.2} ms | confident {:.1}%",
                el,
                n as f64 / el,
                snap.batches,
                snap.padded_rows,
                snap.mean_request_us / 1e3,
                snap.p99_request_us / 1e3,
                100.0 * confident as f64 / n as f64
            );
            println!(
                "gauges: pooled outputs {} | pooled signal buffers {} | leased request \
                 buffers {} (high-water {}) | pending queue {}",
                snap.pooled_outputs,
                snap.pooled_signals,
                snap.pooled_requests,
                coord.lease_high_water(),
                snap.queue_depth
            );
            println!(
                "steals: {} local / {} stolen batch claims",
                snap.local_batches(),
                snap.stolen_batches()
            );
            for (k, s) in snap.per_shard.iter().enumerate() {
                println!(
                    "  shard {k}: {} batches ({} local, {} stolen), {} responses, \
                     busy {:.1} ms, deque depth {}",
                    s.batches,
                    s.local_batches,
                    s.stolen_batches,
                    s.responses,
                    s.busy_us as f64 / 1e3,
                    s.deque_depth
                );
            }
            coord.shutdown();
        }
        "client" => {
            let addr = args.get_or("connect", "127.0.0.1:7070").to_string();
            let n = args.get_usize("requests")?.unwrap_or(16);
            let deadline = args.get_usize("deadline-us")?.unwrap_or(0) as u64;
            let snr = args.get_f64("snr")?.unwrap_or(20.0);
            let seed = args.get_usize("seed")?.unwrap_or(18) as u64;
            // Only the protocol (b-values) is needed client-side; fall
            // back to the in-tree fixture when artifacts are absent.
            let man = match experiments::load_manifest(args.get_or("variant", "tiny")) {
                Ok(man) => man,
                Err(e) => {
                    eprintln!("no artifacts ({e}); using the built-in tiny fixture protocol");
                    uivim::testing::fixture::tiny_fixture().0
                }
            };
            let ds = synth_dataset(n, &man.bvalues, snr, seed);
            let mut client = NetClient::connect(&addr)?;
            let t = Timer::start();
            let (mut ok, mut shed, mut expired, mut other) = (0usize, 0usize, 0usize, 0usize);
            let mut confident = 0usize;
            for i in 0..n {
                let reply = client.request(i as u64, deadline, ds.voxel(i))?;
                anyhow::ensure!(
                    reply.id == i as u64,
                    "reply {} routed to request {i}",
                    reply.id
                );
                match reply.status {
                    Status::Ok => {
                        ok += 1;
                        if reply.report.is_some_and(|r| r.confident) {
                            confident += 1;
                        }
                    }
                    Status::Overloaded => shed += 1,
                    Status::Expired => expired += 1,
                    _ => other += 1,
                }
            }
            let el = t.elapsed_s();
            println!(
                "{n} requests to {addr} in {el:.2}s -> {:.0} req/s | OK {ok} \
                 (confident {confident}) | OVERLOADED {shed} | EXPIRED {expired} | \
                 other {other}",
                n as f64 / el
            );
        }
        "volume" => {
            use uivim::volume::scenario::{scenario_grid, Corruption};
            use uivim::volume::stream::{self, StreamConfig};
            use uivim::volume::{parse_dim, VolumeSpec};
            let rt = Runtime::cpu().ok();
            let kind = args.get_or("engine", "native").to_string();
            registry::default_registry().validate(&kind)?;
            // CI and fresh checkouts have no AOT artifacts: fall back to
            // the built-in tiny fixture (same pattern as the benches).
            let (man, w) = match experiments::load_manifest(args.get_or("variant", "tiny")) {
                Ok(man) => {
                    let steps = args.get_usize("train-steps")?.unwrap_or(0);
                    let w = experiments::resolve_weights(
                        &man,
                        rt.as_ref(),
                        args.get("weights"),
                        steps,
                        20.0,
                    )?;
                    (man, w)
                }
                Err(e) => {
                    eprintln!("no artifacts ({e}); using the built-in tiny fixture");
                    uivim::testing::fixture::tiny_fixture()
                }
            };
            let dim = parse_dim(args.get_or("dim", "16,16,8"))?;
            let slices_in_flight = args.get_usize("slices-in-flight")?.unwrap_or(2).max(1);
            let snr = args.get_f64("snr")?.unwrap_or(20.0);
            let seed = args.get_usize("seed")?.unwrap_or(11) as u64;
            let batch = args.get_usize("batch")?.unwrap_or(man.batch_infer).max(1);
            let shards = args.get_usize("shards")?.unwrap_or(1).max(1);
            let mut cfg = CoordinatorConfig::sharded(man.nb, batch, shards);
            // Bound the pending queue to the in-flight slice budget so
            // backpressure is real, not just configured.
            cfg.batcher.queue_capacity =
                (slices_in_flight * dim.0 * dim.1 + 1).max(batch + 1);
            let opts = EngineOpts {
                batch: Some(batch),
                threads: args.get_usize("threads")?.unwrap_or(1).max(1),
                overlap: args.flag("overlap"),
                ..Default::default()
            };
            let coord =
                Coordinator::start(cfg, registry::factory(&kind, man.clone(), w, opts)?)?;
            let scfg = StreamConfig {
                slices_in_flight,
                ..Default::default()
            };
            if args.flag("sweep") {
                let grid = scenario_grid(
                    &man.bvalues,
                    &[snr],
                    &[
                        Corruption::Clean,
                        Corruption::ExtraNoise { std: 0.05 },
                        Corruption::Motion { max_shift: 2 },
                    ],
                );
                println!(
                    "scenario sweep: {} scenarios over a {}x{}x{} volume",
                    grid.len(),
                    dim.0,
                    dim.1,
                    dim.2
                );
                for (i, sc) in grid.iter().enumerate() {
                    let spec = VolumeSpec {
                        dim,
                        bvals: sc.bvals.clone(),
                        snr: sc.snr,
                        seed: seed + i as u64,
                    };
                    let vol = stream::stream_volume(&coord, &spec, sc.corruption, &scfg)?;
                    let m = stream::volume_metrics(&vol);
                    let mean_unc = m.uncertainty.iter().sum::<f64>() / 4.0;
                    let mean_cal = m.calibration.iter().sum::<f64>() / 4.0;
                    println!(
                        "  {:<28} {:>9.0} vox/s | rel-unc {:.4} | calib {:+.3} | \
                         stalls {} | confident {:.1}%",
                        sc.name,
                        vol.stats.voxels_per_s,
                        mean_unc,
                        mean_cal,
                        vol.stats.stalls,
                        100.0 * vol.confident_voxels as f64 / vol.n_voxels() as f64
                    );
                }
            } else {
                let spec = VolumeSpec {
                    dim,
                    bvals: man.bvalues.clone(),
                    snr,
                    seed,
                };
                let vol = stream::stream_volume(&coord, &spec, Corruption::Clean, &scfg)?;
                let m = stream::volume_metrics(&vol);
                println!(
                    "{}x{}x{} volume ({} voxels, {} slices) in {:.2}s -> {:.0} vox/s",
                    dim.0,
                    dim.1,
                    dim.2,
                    vol.stats.voxels,
                    vol.stats.slices,
                    vol.stats.elapsed_s,
                    vol.stats.voxels_per_s
                );
                println!(
                    "backpressure: max {} slices in flight (cap {}) | max queue {} | \
                     max deque depth {} | {} stalls",
                    vol.stats.max_inflight_slices,
                    slices_in_flight,
                    vol.stats.max_queue_depth,
                    vol.stats.max_deque_depth,
                    vol.stats.stalls
                );
                println!(
                    "memory: lease high-water {} buffers (volume-depth independent)",
                    vol.stats.lease_high_water
                );
                for p in Param::ALL {
                    let i = p.index();
                    let st = vol.maps[i].relative.stats();
                    println!(
                        "  {:<6} rmse {:.6} | rel-uncertainty {:.4} (map: min {:.4} \
                         max {:.4}) | calib {:+.3}",
                        p.name(),
                        m.rmse[i],
                        m.uncertainty[i],
                        st.min,
                        st.max,
                        m.calibration[i]
                    );
                }
                if let Some(out) = args.get("out") {
                    let stem = std::path::PathBuf::from(out);
                    let d = &vol.maps[Param::D.index()];
                    let mut written =
                        d.mean.write_pgm_stack(&stem.with_file_name(format!(
                            "{}_d_mean",
                            stem.file_name().and_then(|s| s.to_str()).unwrap_or("map")
                        )))?;
                    written.extend(d.relative.write_pgm_stack(&stem.with_file_name(
                        format!(
                            "{}_d_relative",
                            stem.file_name().and_then(|s| s.to_str()).unwrap_or("map")
                        ),
                    ))?);
                    println!("wrote {} PGM slices under {}", written.len(), stem.display());
                }
            }
            let snap = coord.snapshot();
            println!(
                "coordinator: {} slices ingested | {} volumes completed | {} stalls | \
                 {} local / {} stolen batch claims",
                snap.slices_ingested,
                snap.volumes_completed,
                snap.stream_stalls,
                snap.local_batches(),
                snap.stolen_batches()
            );
            coord.shutdown();
        }
        "fig6" | "fig7" => {
            let rt = Runtime::cpu().ok();
            let (man, w, kind) = engine_and_weights(args, rt.as_ref())?;
            let cfg = fig67::SweepConfig {
                n_voxels: args.get_usize("voxels")?.unwrap_or(2000),
                engine: kind,
                ..Default::default()
            };
            let rows = fig67::snr_sweep(&man, &w, &cfg)?;
            if args.command == "fig6" {
                println!("{}", fig67::render_fig6(&rows));
            } else {
                println!("{}", fig67::render_fig7(&rows));
            }
            let out = std::path::PathBuf::from(args.get_or("out", "reports/fig6_fig7.csv"));
            write_report(&out, &fig67::to_csv(&rows))?;
            println!("CSV written to {}", out.display());
        }
        "fig8" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            let rt = Runtime::cpu().ok();
            let w = experiments::resolve_weights(&man, rt.as_ref(), args.get("weights"), 0, 20.0)?;
            if let Some(spec) = args.get("keep-rates") {
                // the eq. (2) cross-check assumes the manifest's masks,
                // not resampled ones — the two options are exclusive
                anyhow::ensure!(
                    !args.flag("check-model"),
                    "--check-model applies to the manifest-mask sweep; drop it or --keep-rates"
                );
                let rates = fig8::parse_keep_rates(spec)?;
                let seed = args.get_usize("mask-seed")?.unwrap_or(17) as u64;
                let points = fig8::fig8_grid(&man, &w, &fig8::PAPER_PE_COUNTS, &rates, seed)?;
                println!("{}", fig8::render(&points, &[]));
            } else {
                let (points, ok) = fig8::fig8(&man, &w, &fig8::PAPER_PE_COUNTS)?;
                println!("{}", fig8::render(&points, &ok));
                if args.flag("check-model") {
                    anyhow::ensure!(
                        ok.iter().all(|&b| b),
                        "eq. (2) model diverged from simulator"
                    );
                    println!("eq. (2) analytic model matches the cycle simulator on all points");
                }
            }
        }
        "table1" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            let rt = Runtime::cpu().ok();
            let w = experiments::resolve_weights(&man, rt.as_ref(), args.get("weights"), 0, 20.0)?;
            let rows = tables::table1(&man, &w)?;
            println!("{}", tables::render_table1(&rows));
        }
        "table2" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            // Table II benches the PJRT engine itself; the registry
            // surfaces a clear error when the runtime is unavailable.
            let w = experiments::resolve_weights(&man, None, args.get("weights"), 0, 20.0)?;
            let t = tables::table2(&man, &w, &bench::config_from_env())?;
            println!("{}", tables::render_table2(&t));
        }
        "schemes" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            let rt = Runtime::cpu().ok();
            let w = experiments::resolve_weights(&man, rt.as_ref(), args.get("weights"), 0, 20.0)?;
            let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 19);
            let cfg = AccelConfig {
                batch: man.batch_infer,
                ..Default::default()
            };
            for scheme in [Scheme::BatchLevel, Scheme::SamplingLevel] {
                let mut sim = AccelSimulator::new(&man, &w, cfg, scheme)?;
                let (_, stats) = sim.infer_batch_stats(&ds.signals)?;
                let u = uivim::accel::resource::usage(
                    &cfg,
                    man.nb,
                    man.n_samples,
                    &sim.weight_stores(),
                );
                let p = uivim::accel::power::estimate(&cfg, &u, &stats, uivim::accel::MaskSampler::Offline);
                println!(
                    "{:<16} cycles {:>9}  weight loads {:>6}  words {:>9}  {:.3} ms/batch  {:.2} W  {:.3} mJ/batch",
                    scheme.name(),
                    stats.cycles,
                    stats.weight_loads,
                    stats.weight_words_loaded,
                    stats.seconds(cfg.clock_hz) * 1e3,
                    p.watts,
                    p.energy_mj()
                );
            }
        }
        "flow" => {
            let man = experiments::load_manifest(args.get_or("variant", "tiny"))?;
            let rt = Runtime::cpu()?;
            let req = uivim::flow::UncertaintyRequirements::default();
            let steps = args.get_usize("steps")?.unwrap_or(200);
            let rt_ms = args.get_f64("realtime-ms")?.unwrap_or(0.8);
            println!("Phase 1: requirements = caps {:?} @ SNR {}, monotone-in-SNR", req.max_relative, req.reference_snr);
            let rep = uivim::flow::run_flow(&man, &rt, &req, steps, rt_ms)?;
            println!(
                "Phase 2: trained {} steps (final loss {:.5}); requirements {}",
                steps,
                rep.phase2.final_loss,
                if rep.phase2.satisfied { "SATISFIED" } else { "VIOLATED" }
            );
            for v in &rep.phase2.violations {
                println!("  violation: {v}");
            }
            match rep.phase3 {
                Some(p3) => println!(
                    "Phase 3: {} PEs ({:.1}% DSP) -> {:.4} ms/batch at {:.2} W; real-time {} ms budget: {}",
                    p3.chosen_pe, p3.dsp_pct, p3.batch_ms, p3.power_w, rt_ms,
                    if p3.meets_realtime { "MET" } else { "MISSED" }
                ),
                None => println!("Phase 3: skipped — iterate the model/hyper-parameters (Fig. 1 loop)"),
            }
        }
        "gridsearch" => {
            let rt = Runtime::cpu().ok();
            let (man, w, _) = engine_and_weights(args, rt.as_ref())?;
            let parse_list = |s: &str| -> Vec<f64> {
                s.split(',').filter_map(|x| x.trim().parse().ok()).collect()
            };
            let rates = parse_list(args.get_or("rates", "0.1,0.3,0.5,0.7,0.9"));
            let samples: Vec<usize> = args
                .get_or("samples", "4,8,16")
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect();
            let voxels = args.get_usize("voxels")?.unwrap_or(256);
            let pts = uivim::flow::gridsearch::grid_search(&man, &w, &rates, &samples, 20.0, voxels)?;
            println!("{}", uivim::flow::gridsearch::render(&pts));
        }
        "ablation" => {
            let rt = Runtime::cpu().ok();
            let (man, w, _) = engine_and_weights(args, rt.as_ref())?;
            let rows = experiments::ablation::ablation(&man, &w)?;
            println!("{}", experiments::ablation::render(&rows));
        }
        "bench-diff" => {
            let baseline = args
                .get("baseline")
                .ok_or_else(|| anyhow::anyhow!("--baseline is required"))?;
            let current = args
                .get("current")
                .ok_or_else(|| anyhow::anyhow!("--current is required"))?;
            let max_regress = args.get_f64("max-regress")?.unwrap_or(0.20);
            let report = bench::compare_bench_files(
                std::path::Path::new(baseline),
                std::path::Path::new(current),
                max_regress,
            )?;
            println!("{report}");
            println!("no p50 regressions beyond {:.0}%", max_regress * 100.0);
        }
        "lint" => {
            let root = args
                .get("root")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(uivim::analysis::default_crate_dir);
            let findings = uivim::analysis::lint_crate(&root)?;
            if args.flag("json") {
                println!("{}", uivim::analysis::findings_json(&findings));
            } else {
                for f in &findings {
                    println!("{f}");
                }
            }
            if !findings.is_empty() {
                anyhow::bail!("lint failed: {} finding(s)", findings.len());
            }
            if !args.flag("json") {
                println!("lint clean: {} rules over {}", uivim::analysis::rules::RULES.len(), root.display());
            }
        }
        "masks" => {
            let width = args.get_usize("width")?.unwrap_or(11);
            let n = args.get_usize("n")?.unwrap_or(4);
            let scale = args.get_f64("scale")?.unwrap_or(2.0);
            let seed = args.get_usize("seed")?.unwrap_or(2024) as u64;
            let m = masks::for_width(width, n, scale, seed)?;
            println!("masks {}x{} (scale {scale}, seed {seed}):", m.n, m.width);
            for i in 0..m.n {
                let row: String = m
                    .row(i)
                    .iter()
                    .map(|&b| if b == 1 { '#' } else { '.' })
                    .collect();
                println!("  [{i}] {row}  ({} kept)", m.ones(i));
            }
            println!("pairwise overlap (IoU): {:.3}", m.overlap());
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
    Ok(())
}
