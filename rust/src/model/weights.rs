//! Typed views into the flat parameter / BN-state vectors.
//!
//! `Weights` owns the two flat `Vec<f32>`s (exactly the buffers the PJRT
//! executables consume) and exposes per-sub-network slices for the native
//! engine and the accelerator simulator.

use super::manifest::Manifest;
use crate::util::rng::Pcg32;

/// One sub-network's tensors, borrowed out of the flat vectors.
#[derive(Debug, Clone, Copy)]
pub struct SubnetWeights<'a> {
    pub nb: usize,
    /// `w1[nb][nb]` row-major (input-major: `w1[i*nb + o]` maps input i to
    /// output o — matches the jax `x @ W` convention).
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub g1: &'a [f32],
    pub be1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: &'a [f32],
    pub g2: &'a [f32],
    pub be2: &'a [f32],
    pub w3: &'a [f32],
    pub b3: &'a [f32],
    pub m1: &'a [f32],
    pub v1: &'a [f32],
    pub m2: &'a [f32],
    pub v2: &'a [f32],
}

/// Owned model state: flat trainable params + flat BN running stats.
#[derive(Debug, Clone)]
pub struct Weights {
    pub params: Vec<f32>,
    pub bn: Vec<f32>,
}

impl Weights {
    /// Load the initial state shipped in the artifacts.
    pub fn load_init(man: &Manifest) -> anyhow::Result<Weights> {
        let params = crate::util::read_f32_file(&man.file("params_init")?)?;
        let bn = crate::util::read_f32_file(&man.file("bn_init")?)?;
        anyhow::ensure!(params.len() == man.param_count, "params size mismatch");
        anyhow::ensure!(bn.len() == man.bn_count, "bn size mismatch");
        Ok(Weights { params, bn })
    }

    /// Load trained weights from a pair of binary files.
    pub fn load_files(
        man: &Manifest,
        params_path: &std::path::Path,
        bn_path: &std::path::Path,
    ) -> anyhow::Result<Weights> {
        let params = crate::util::read_f32_file(params_path)?;
        let bn = crate::util::read_f32_file(bn_path)?;
        anyhow::ensure!(params.len() == man.param_count, "params size mismatch");
        anyhow::ensure!(bn.len() == man.bn_count, "bn size mismatch");
        Ok(Weights { params, bn })
    }

    /// Save to `<stem>.params.bin` / `<stem>.bn.bin` next to each other.
    pub fn save(&self, stem: &std::path::Path) -> anyhow::Result<()> {
        let p = stem.with_extension("params.bin");
        let b = stem.with_extension("bn.bin");
        crate::util::write_f32_file(&p, &self.params)?;
        crate::util::write_f32_file(&b, &self.bn)?;
        Ok(())
    }

    /// He-initialised fresh weights (native twin of
    /// `model.init_params`; same *distribution*, independent stream).
    pub fn init_random(man: &Manifest, seed: u64) -> Weights {
        let mut rng = Pcg32::new(seed);
        let mut params = vec![0.0f32; man.param_count];
        for e in &man.param_layout {
            let base = e.name.rsplit('.').next().unwrap_or("");
            let slice = &mut params[e.offset..e.offset + e.len()];
            match base {
                "w1" | "w2" | "w3" => {
                    let fan_in = e.shape[0] as f64;
                    let std = (2.0 / fan_in).sqrt();
                    for v in slice.iter_mut() {
                        *v = (rng.normal() * std) as f32;
                    }
                }
                "g1" | "g2" => slice.fill(1.0),
                _ => slice.fill(0.0),
            }
        }
        let mut bn = vec![0.0f32; man.bn_count];
        for e in &man.bn_layout {
            if e.name.rsplit('.').next().unwrap_or("").starts_with('v') {
                bn[e.offset..e.offset + e.len()].fill(1.0);
            }
        }
        Weights { params, bn }
    }

    fn pslice<'a>(&'a self, man: &Manifest, name: &str) -> &'a [f32] {
        let e = man
            .param_entry(name)
            .unwrap_or_else(|| panic!("missing param entry {name}"));
        &self.params[e.offset..e.offset + e.len()]
    }

    fn bslice<'a>(&'a self, man: &Manifest, name: &str) -> &'a [f32] {
        let e = man
            .bn_entry(name)
            .unwrap_or_else(|| panic!("missing bn entry {name}"));
        &self.bn[e.offset..e.offset + e.len()]
    }

    /// Borrow one sub-network's tensors.
    pub fn subnet<'a>(&'a self, man: &Manifest, sn: &str) -> SubnetWeights<'a> {
        SubnetWeights {
            nb: man.nb,
            w1: self.pslice(man, &format!("{sn}.w1")),
            b1: self.pslice(man, &format!("{sn}.b1")),
            g1: self.pslice(man, &format!("{sn}.g1")),
            be1: self.pslice(man, &format!("{sn}.be1")),
            w2: self.pslice(man, &format!("{sn}.w2")),
            b2: self.pslice(man, &format!("{sn}.b2")),
            g2: self.pslice(man, &format!("{sn}.g2")),
            be2: self.pslice(man, &format!("{sn}.be2")),
            w3: self.pslice(man, &format!("{sn}.w3")),
            b3: self.pslice(man, &format!("{sn}.b3")),
            m1: self.bslice(man, &format!("{sn}.m1")),
            v1: self.bslice(man, &format!("{sn}.v1")),
            m2: self.bslice(man, &format!("{sn}.m2")),
            v2: self.bslice(man, &format!("{sn}.v2")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::artifacts_root;

    fn tiny() -> Option<Manifest> {
        let dir = artifacts_root().join("tiny");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn subnet_views_have_right_sizes() {
        let Some(man) = tiny() else { return };
        let w = Weights::load_init(&man).unwrap();
        for sn in &man.subnets {
            let s = w.subnet(&man, sn);
            assert_eq!(s.w1.len(), man.nb * man.nb);
            assert_eq!(s.b1.len(), man.nb);
            assert_eq!(s.w3.len(), man.nb);
            assert_eq!(s.b3.len(), 1);
            assert_eq!(s.m1.len(), man.nb);
            assert_eq!(s.v2.len(), man.nb);
        }
    }

    #[test]
    fn init_random_statistics() {
        let Some(man) = tiny() else { return };
        let w = Weights::init_random(&man, 1);
        let s = w.subnet(&man, "d");
        assert!(s.g1.iter().all(|&g| g == 1.0));
        assert!(s.b1.iter().all(|&b| b == 0.0));
        let std = {
            let m: f32 = s.w1.iter().sum::<f32>() / s.w1.len() as f32;
            (s.w1.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / s.w1.len() as f32).sqrt()
        };
        assert!(std > 0.2 && std < 0.8, "std {std}");
        assert!(s.v1.iter().all(|&v| v == 1.0));
        assert!(s.m1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn save_load_roundtrip() {
        let Some(man) = tiny() else { return };
        let w = Weights::init_random(&man, 2);
        let dir = std::env::temp_dir().join("uivim_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let stem = dir.join("model");
        w.save(&stem).unwrap();
        let back = Weights::load_files(
            &man,
            &stem.with_extension("params.bin"),
            &stem.with_extension("bn.bin"),
        )
        .unwrap();
        assert_eq!(back.params, w.params);
        assert_eq!(back.bn, w.bn);
    }
}
