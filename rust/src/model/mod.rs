//! uIVIM-NET model description on the Rust side: the artifact manifest,
//! flat parameter-vector layout and typed tensor views.
//!
//! The layout is defined by `python/compile/model.py` and shipped in
//! `manifest.json`; this module parses it and provides named access into
//! the flat `Vec<f32>` weight vectors, so every engine (PJRT, native f32,
//! fixed-point accelerator sim) addresses the identical storage.

pub mod manifest;
pub mod weights;

pub use manifest::Manifest;
pub use weights::{SubnetWeights, Weights};
