//! `manifest.json` — the contract between the Python compile path and the
//! Rust runtime.  Everything the coordinator needs to run a variant
//! (shapes, layouts, masks, hyper-parameters, file names) is in here; no
//! Python is consulted at runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::masks::MaskSet;
use crate::util::json::Json;

/// One entry of a flat-vector layout: a named tensor at `offset` with
/// `shape` (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Adam hyper-parameters exported by the compile path.
#[derive(Debug, Clone, Copy)]
pub struct AdamHyper {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// Parsed artifact manifest for one variant.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub nb: usize,
    pub n_samples: usize,
    pub scale: f64,
    pub mask_seed: u64,
    pub batch_infer: usize,
    pub batch_train: usize,
    pub param_count: usize,
    pub bn_count: usize,
    pub bvalues: Vec<f64>,
    pub subnets: Vec<String>,
    pub adam: AdamHyper,
    pub bn_momentum: f64,
    pub param_layout: Vec<LayoutEntry>,
    pub bn_layout: Vec<LayoutEntry>,
    /// Mask sets keyed `"{subnet}.mask{1|2}"`.
    pub masks: BTreeMap<String, MaskSet>,
    pub files: BTreeMap<String, String>,
    /// Directory the manifest was loaded from (for resolving `files`).
    pub dir: PathBuf,
}

fn layout_from(j: &Json) -> anyhow::Result<Vec<LayoutEntry>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("layout is not an array"))?;
    arr.iter()
        .map(|e| {
            Ok(LayoutEntry {
                name: e
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("layout entry missing name"))?
                    .to_string(),
                offset: e
                    .get("offset")
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("layout entry missing offset"))?,
                shape: e
                    .get("shape")
                    .to_f64_vec()
                    .iter()
                    .map(|&v| v as usize)
                    .collect(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;

        let req_usize = |key: &str| {
            j.get(key)
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest missing '{key}'"))
        };
        let nb = req_usize("nb")?;
        let n_samples = req_usize("n_samples")?;

        let mut masks = BTreeMap::new();
        if let Some(obj) = j.get("masks").as_obj() {
            for (k, v) in obj {
                let flat: Vec<u8> = v.to_f64_vec().iter().map(|&x| x as u8).collect();
                anyhow::ensure!(
                    flat.len() == n_samples * nb,
                    "mask {k} has {} entries, want {}",
                    flat.len(),
                    n_samples * nb
                );
                masks.insert(
                    k.clone(),
                    MaskSet {
                        n: n_samples,
                        width: nb,
                        bits: flat,
                    },
                );
            }
        }

        let adam = AdamHyper {
            lr: j.get("adam").get("lr").as_f64().unwrap_or(1e-3),
            beta1: j.get("adam").get("beta1").as_f64().unwrap_or(0.9),
            beta2: j.get("adam").get("beta2").as_f64().unwrap_or(0.999),
            eps: j.get("adam").get("eps").as_f64().unwrap_or(1e-8),
        };

        let files = j
            .get("files")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();

        let m = Manifest {
            variant: j
                .get("variant")
                .as_str()
                .unwrap_or("unknown")
                .to_string(),
            nb,
            n_samples,
            scale: j.get("scale").as_f64().unwrap_or(2.0),
            mask_seed: j.get("mask_seed").as_f64().unwrap_or(2024.0) as u64,
            batch_infer: req_usize("batch_infer")?,
            batch_train: req_usize("batch_train")?,
            param_count: req_usize("param_count")?,
            bn_count: req_usize("bn_count")?,
            bvalues: j.get("bvalues").to_f64_vec(),
            subnets: j
                .get("subnets")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_else(|| vec!["d".into(), "dstar".into(), "f".into(), "s0".into()]),
            adam,
            bn_momentum: j.get("bn_momentum").as_f64().unwrap_or(0.1),
            param_layout: layout_from(j.get("param_layout"))?,
            bn_layout: layout_from(j.get("bn_layout"))?,
            masks,
            files,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    /// Internal consistency checks (layout contiguity, sizes, masks).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.bvalues.len() == self.nb, "bvalues/nb mismatch");
        let mut off = 0;
        for e in &self.param_layout {
            anyhow::ensure!(e.offset == off, "param layout gap at {}", e.name);
            off += e.len();
        }
        anyhow::ensure!(off == self.param_count, "param_count mismatch");
        off = 0;
        for e in &self.bn_layout {
            anyhow::ensure!(e.offset == off, "bn layout gap at {}", e.name);
            off += e.len();
        }
        anyhow::ensure!(off == self.bn_count, "bn_count mismatch");
        anyhow::ensure!(
            self.batch_train % self.n_samples == 0,
            "batch_train must divide into n_samples groups"
        );
        for (k, m) in &self.masks {
            anyhow::ensure!(
                m.n == self.n_samples && m.width == self.nb,
                "mask {k} shape mismatch"
            );
            anyhow::ensure!(m.bits.iter().all(|&b| b <= 1), "mask {k} non-binary");
        }
        Ok(())
    }

    /// Path of a named artifact file.
    pub fn file(&self, key: &str) -> anyhow::Result<PathBuf> {
        self.files
            .get(key)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("manifest has no file '{key}'"))
    }

    /// Find a layout entry by qualified name (e.g. `"d.w1"`).
    pub fn param_entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.param_layout.iter().find(|e| e.name == name)
    }
    pub fn bn_entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.bn_layout.iter().find(|e| e.name == name)
    }

    /// Mask set for `"{subnet}.mask{layer}"`.
    pub fn mask(&self, subnet: &str, layer: usize) -> Option<&MaskSet> {
        self.masks.get(&format!("{subnet}.mask{layer}"))
    }

    /// Regenerate the masks from `mask_seed` with the Rust generator and
    /// compare with the shipped bytes — the cross-language parity check.
    pub fn verify_mask_parity(&self) -> anyhow::Result<()> {
        for (si, sn) in self.subnets.iter().enumerate() {
            for layer in 1..=2usize {
                let seed = crate::masks::subnet_layer_seed(self.mask_seed, si, layer);
                let regen = crate::masks::for_width(self.nb, self.n_samples, self.scale, seed)?;
                let shipped = self
                    .mask(sn, layer)
                    .ok_or_else(|| anyhow::anyhow!("missing mask {sn}.mask{layer}"))?;
                anyhow::ensure!(
                    &regen == shipped,
                    "mask parity failure for {sn}.mask{layer}: Rust generator disagrees \
                     with python-shipped masks"
                );
            }
        }
        Ok(())
    }
}

/// Locate the artifacts root: `$UIVIM_ARTIFACTS`, else `./artifacts`,
/// else walking up from the current dir (so tests work from target/).
pub fn artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("UIVIM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("tiny").join("manifest.json").exists() || cand.exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Option<Manifest> {
        let dir = artifacts_root().join("tiny");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).expect("load tiny manifest"))
        } else {
            None
        }
    }

    #[test]
    fn loads_tiny_manifest() {
        let Some(m) = tiny() else { return };
        assert_eq!(m.variant, "tiny");
        assert_eq!(m.nb, 11);
        assert_eq!(m.n_samples, 4);
        assert_eq!(m.bvalues.len(), 11);
        assert_eq!(m.subnets, vec!["d", "dstar", "f", "s0"]);
        assert_eq!(m.masks.len(), 8); // 4 subnets x 2 layers
    }

    #[test]
    fn mask_parity_with_python() {
        let Some(m) = tiny() else { return };
        m.verify_mask_parity().expect("cross-language mask parity");
    }

    #[test]
    fn file_paths_resolve() {
        let Some(m) = tiny() else { return };
        for key in ["infer", "train", "params_init", "bn_init", "golden_in", "golden_out"] {
            let p = m.file(key).unwrap();
            assert!(p.exists(), "{} missing", p.display());
        }
        assert!(m.file("nope").is_err());
    }

    #[test]
    fn entries_lookup() {
        let Some(m) = tiny() else { return };
        let e = m.param_entry("d.w1").unwrap();
        assert_eq!(e.offset, 0);
        assert_eq!(e.shape, vec![11, 11]);
        assert!(m.param_entry("zzz").is_none());
        let b = m.bn_entry("s0.v2").unwrap();
        assert_eq!(b.shape, vec![11]);
    }

    #[test]
    fn init_files_sizes_match() {
        let Some(m) = tiny() else { return };
        let p = crate::util::read_f32_file(&m.file("params_init").unwrap()).unwrap();
        let b = crate::util::read_f32_file(&m.file("bn_init").unwrap()).unwrap();
        assert_eq!(p.len(), m.param_count);
        assert_eq!(b.len(), m.bn_count);
    }
}
