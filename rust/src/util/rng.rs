//! PCG32 (XSH-RR 64/32) — bit-exact mirror of `python/compile/pcg.py`.
//!
//! The mask-based BayesNN depends on *fixed, pre-generated* masks; the Rust
//! coordinator and the Python compile path must agree on them exactly, so
//! both sides implement the same PCG32 stream and the same partial
//! Fisher-Yates sampler.  Golden vectors are shared with
//! `python/tests/test_pcg.py`.

use rand_core::RngCore;

const MUL: u64 = 6364136223846793005;
const DEFAULT_SEQ: u64 = 0xDA3E_39CB_94B9_5BDB;

/// Deterministic PCG32 generator (the reference O'Neill variant).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with the reference seeding procedure (stream = default).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, DEFAULT_SEQ)
    }

    /// Seed with an explicit stream selector.
    pub fn with_stream(seed: u64, seq: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (seq << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform integer in `[0, n)`, debiased via rejection sampling
    /// (`pcg32_boundedrand`).  Mirrors `Pcg32.below` in Python.
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n >= 1, "below() needs n >= 1");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of randomness (f32-exact).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` built from two 32-bit draws.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        let hi = (self.next_u32() >> 6) as u64; // 26 bits
        let lo = (self.next_u32() >> 5) as u64; // 27 bits
        ((hi << 27) | lo) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (two uniforms per pair; caches none
    /// to stay trivially reproducible).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// `k` distinct indices from `0..total` via partial Fisher-Yates —
    /// identical swap order to the Python implementation.
    pub fn choose(&mut self, total: usize, k: usize) -> Vec<usize> {
        assert!(k <= total, "cannot choose more than total");
        let mut idx: Vec<usize> = (0..total).collect();
        for i in 0..k {
            let j = i + self.below((total - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher-Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

impl RngCore for Pcg32 {
    fn next_u32(&mut self) -> u32 {
        Pcg32::next_u32(self)
    }
    fn next_u64(&mut self) -> u64 {
        let lo = Pcg32::next_u32(self) as u64;
        let hi = Pcg32::next_u32(self) as u64;
        (hi << 32) | lo
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        for chunk in dest.chunks_mut(4) {
            let v = Pcg32::next_u32(self).to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden stream shared with python/tests/test_pcg.py.
    const GOLDEN_SEED_42: [u32; 8] = [
        0x7130_66EA,
        0x3C7A_0D56,
        0xF424_216A,
        0x25C8_9145,
        0x43E7_EF3E,
        0x90CF_F60C,
        0x5232_0591,
        0x53DF_BCB8,
    ];

    #[test]
    fn golden_stream_matches_python() {
        let mut r = Pcg32::new(42);
        for want in GOLDEN_SEED_42 {
            assert_eq!(r.next_u32(), want);
        }
    }

    #[test]
    fn golden_choose_matches_python() {
        let mut r = Pcg32::new(42);
        assert_eq!(r.choose(10, 4), vec![2, 9, 4, 0]);
    }

    #[test]
    fn golden_below_matches_python() {
        let mut r = Pcg32::new(7);
        assert_eq!(r.below(5), 3);
    }

    #[test]
    fn below_in_range_and_complete() {
        let mut r = Pcg32::new(123);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let v = r.below(5) as usize;
            assert!(v < 5);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct() {
        let mut r = Pcg32::new(9);
        for &(total, k) in &[(1usize, 1usize), (5, 5), (20, 7), (104, 52)] {
            let got = r.choose(total, k);
            assert_eq!(got.len(), k);
            let mut sorted = got.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k);
            assert!(got.iter().all(|&g| g < total));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Pcg32::new(1);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn f32_f64_unit_interval() {
        let mut r = Pcg32::new(5);
        for _ in 0..1000 {
            let a = r.next_f32();
            let b = r.next_f64();
            assert!((0.0..1.0).contains(&a));
            assert!((0.0..1.0).contains(&b));
        }
    }

    #[test]
    fn streams_differ_by_seed() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(1);
            (0..4).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(2);
            (0..4).map(|_| r.next_u32()).collect()
        };
        assert_ne!(a, b);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
