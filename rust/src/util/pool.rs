//! Fixed-size thread pool on std threads + channels, plus a bounded
//! recycling buffer pool.
//!
//! tokio is unavailable in the offline registry (DESIGN.md §7); the
//! coordinator and benches use this pool for fan-out work.  Jobs are
//! `FnOnce` closures; `scope`-style joining is provided by waiting on a
//! completion counter.  [`VecPool`] is the f32-buffer twin of
//! `infer::OutputPool`: the coordinator's batcher takes recycled signal
//! buffers from it when cutting batches, and shards hand the buffers
//! back after serving — closing the last per-batch allocation on the
//! serving hot path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool.  Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("uivim-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*inflight;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                cv.notify_all();
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            inflight,
        }
    }

    /// Submit a job; returns immediately.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.inflight;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("pool worker alive");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded recycling pool of `Vec<f32>` buffers.
///
/// `take` hands out a **cleared** buffer (recycled capacity when one is
/// pooled, freshly reserved otherwise); `put` returns a buffer for
/// reuse, dropping it when the pool already holds `cap` idle buffers so
/// a burst cannot hoard memory forever.
pub struct VecPool {
    slots: Mutex<Vec<Vec<f32>>>,
    cap: usize,
    /// Fresh allocations handed out because no recycled buffer was
    /// idle.  This is the pool's **high-water signature**: in a steady
    /// state where every taken buffer comes back, `created` stops
    /// growing — the capacity-stability property the lease-lifecycle
    /// tests pin down.
    created: AtomicUsize,
}

impl VecPool {
    /// Pool keeping at most `cap` idle buffers (min 1).
    pub fn new(cap: usize) -> Self {
        VecPool {
            slots: Mutex::new(Vec::new()),
            cap: cap.max(1),
            created: AtomicUsize::new(0),
        }
    }

    /// Take an empty buffer with at least `capacity_hint` reserved.
    pub fn take(&self, capacity_hint: usize) -> Vec<f32> {
        let recycled = self.slots.lock().expect("pool lock").pop();
        match recycled {
            Some(mut v) => {
                v.clear();
                v.reserve(capacity_hint);
                v
            }
            None => {
                // relaxed: monotonic high-water counter, telemetry only
                self.created.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(capacity_hint)
            }
        }
    }

    /// Return a buffer to the pool (dropped when the pool is full).
    pub fn put(&self, v: Vec<f32>) {
        let mut slots = self.slots.lock().expect("pool lock");
        if slots.len() < self.cap {
            slots.push(v);
        }
    }

    /// Idle buffers currently pooled.
    pub fn idle(&self) -> usize {
        self.slots.lock().expect("pool lock").len()
    }

    /// Total fresh allocations so far (the high-water mark of buffers
    /// in circulation; stable once recycling reaches steady state).
    pub fn created(&self) -> usize {
        // relaxed: telemetry snapshot read, no ordering needed
        self.created.load(Ordering::Relaxed)
    }
}

/// Run a closure over each item of a slice in parallel, collecting results
/// in order.  Convenience built on `std::thread::scope` (no pool needed
/// for one-shot fan-out).
pub fn par_map<T: Sync, R: Send>(items: &[T], threads: usize, f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let threads = threads.max(1).min(items.len().max(1));
    // relaxed: the counter only hands out unique indices; result
    // visibility is ordered by the per-slot mutexes and the scope join.
    let counter = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<R>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    drop(slots);
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_survives_reuse_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn pool_size_clamped() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..50).collect();
        let out = par_map(&items, 4, |&x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<u64> = vec![];
        assert!(par_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn vec_pool_recycles_capacity_and_bounds_idle() {
        let pool = VecPool::new(2);
        let mut a = pool.take(64);
        assert!(a.is_empty() && a.capacity() >= 64);
        a.extend_from_slice(&[1.0; 64]);
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        // recycled: same allocation, cleared
        let b = pool.take(64);
        assert_eq!(b.as_ptr(), ptr);
        assert!(b.is_empty() && b.capacity() >= 64);
        // cap bounds idle buffers
        pool.put(b);
        pool.put(Vec::with_capacity(8));
        pool.put(Vec::with_capacity(8)); // beyond cap: dropped
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn vec_pool_created_counts_only_fresh_allocations() {
        let pool = VecPool::new(4);
        assert_eq!(pool.created(), 0);
        let a = pool.take(16);
        let b = pool.take(16);
        assert_eq!(pool.created(), 2);
        pool.put(a);
        pool.put(b);
        // steady state: recycled takes never move the high-water mark
        for _ in 0..50 {
            let v = pool.take(16);
            pool.put(v);
        }
        assert_eq!(pool.created(), 2, "recycling must not allocate");
    }

    #[test]
    fn vec_pool_take_grows_small_recycled_buffers() {
        let pool = VecPool::new(1);
        pool.put(Vec::with_capacity(4));
        let v = pool.take(128);
        assert!(v.capacity() >= 128);
    }
}
