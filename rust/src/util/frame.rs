//! Length-prefixed binary frame codec for the coordinator's TCP front
//! door (`coordinator::net`).
//!
//! No external dependencies (DESIGN.md §7): the wire format is a fixed
//! 28-byte little-endian header followed by a typed payload.
//!
//! ```text
//! offset  size  field
//!      0     4  magic        b"UIVM"
//!      4     2  version      u16 (currently 1)
//!      6     1  kind         1 = request, 2 = response
//!      7     1  status       response status code (0 on requests)
//!      8     8  id           caller-chosen request id (echoed back)
//!     16     8  deadline_us  relative deadline in µs (0 = none)
//!     24     4  n_values     payload element count
//!     28     …  payload      request: n_values × f32 LE (the voxel
//!                            signals); response: n_values × f64 LE
//! ```
//!
//! Parsing is **hardened**: [`FrameAssembler`] owns a fixed-capacity
//! buffer sized at construction, validates the header the instant 28
//! bytes are available (bad magic / version / kind / oversized
//! `n_values` are rejected *before* any payload is awaited — the
//! declared length is never trusted and never drives an allocation),
//! and only ever reads bytes it has itself buffered, so no input can
//! make it panic or over-read.  Every rejection is a typed
//! [`FrameError`].

use std::fmt;

/// Frame magic: first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"UIVM";
/// Protocol version this build speaks.
pub const VERSION: u16 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 28;

/// Payload element width per frame kind (f32 requests, f64 responses).
const REQ_ELEM: usize = 4;
const RESP_ELEM: usize = 8;

/// Frame kind discriminant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client → server: one voxel's signals.
    Request,
    /// Server → client: a status + the aggregated report values.
    Response,
}

impl FrameKind {
    fn from_u8(v: u8) -> Option<FrameKind> {
        match v {
            1 => Some(FrameKind::Request),
            2 => Some(FrameKind::Response),
            _ => None,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            FrameKind::Request => 1,
            FrameKind::Response => 2,
        }
    }

    /// Payload element width in bytes.
    pub fn elem_size(self) -> usize {
        match self {
            FrameKind::Request => REQ_ELEM,
            FrameKind::Response => RESP_ELEM,
        }
    }
}

/// Response status codes (the `status` header byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Served: payload carries the report values.
    Ok,
    /// Shed by admission control (quota, queue, or estimated delay past
    /// the deadline) — retry later or relax the deadline.
    Overloaded,
    /// The deadline passed before the response could be delivered.
    Expired,
    /// Recoverable request error (wrong signal count, non-finite
    /// payload float) — the connection stays open.
    BadRequest,
    /// The coordinator is shutting down.
    Shutdown,
}

impl Status {
    pub fn from_u8(v: u8) -> Option<Status> {
        match v {
            0 => Some(Status::Ok),
            1 => Some(Status::Overloaded),
            2 => Some(Status::Expired),
            3 => Some(Status::BadRequest),
            4 => Some(Status::Shutdown),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Overloaded => 1,
            Status::Expired => 2,
            Status::BadRequest => 3,
            Status::Shutdown => 4,
        }
    }
}

/// Typed parse rejection.  Every variant means the byte stream is
/// desynchronised (or hostile) and the connection should be closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unsupported protocol version.
    BadVersion(u16),
    /// Unknown frame-kind discriminant.
    BadKind(u8),
    /// Declared `n_values` exceeds the assembler's fixed limit.
    Oversize { n_values: u32, max_values: usize },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported frame version {v} (speak {VERSION})")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Oversize {
                n_values,
                max_values,
            } => write!(
                f,
                "declared payload of {n_values} values exceeds the limit of {max_values}"
            ),
        }
    }
}

impl std::error::Error for FrameError {}

/// Read `N` little-endian bytes at `off` without indexing: zero-filled
/// when out of range.  Callers length-check before parsing, so the
/// fallback never becomes a parsed value — it only makes the parser
/// panic-free by construction (enforced by the `panic-free-net` lint).
fn le_bytes<const N: usize>(b: &[u8], off: usize) -> [u8; N] {
    b.get(off..off + N)
        .and_then(|s| s.try_into().ok())
        .unwrap_or([0u8; N])
}

/// A validated frame header (payload fully buffered when returned by
/// [`FrameAssembler::poll`]).
#[derive(Debug, Clone, Copy)]
pub struct FrameHeader {
    pub kind: FrameKind,
    pub status: u8,
    pub id: u64,
    /// Relative deadline in µs (0 = no deadline).
    pub deadline_us: u64,
    pub n_values: usize,
}

impl FrameHeader {
    /// Total frame length (header + payload) in bytes.
    pub fn frame_len(&self) -> usize {
        HEADER_LEN + self.n_values * self.kind.elem_size()
    }
}

fn put_header(buf: &mut Vec<u8>, kind: FrameKind, status: u8, id: u64, deadline_us: u64, n: u32) {
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.push(kind.as_u8());
    buf.push(status);
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&deadline_us.to_le_bytes());
    buf.extend_from_slice(&n.to_le_bytes());
}

/// Encode a request frame into `buf` (cleared first; capacity is
/// reused, so a connection's encode buffer allocates once).
pub fn encode_request(buf: &mut Vec<u8>, id: u64, deadline_us: u64, signals: &[f32]) {
    buf.clear();
    buf.reserve(HEADER_LEN + signals.len() * REQ_ELEM);
    put_header(
        buf,
        FrameKind::Request,
        0,
        id,
        deadline_us,
        signals.len() as u32,
    );
    for v in signals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a response frame into `buf` (cleared first).
pub fn encode_response(buf: &mut Vec<u8>, id: u64, status: Status, values: &[f64]) {
    buf.clear();
    buf.reserve(HEADER_LEN + values.len() * RESP_ELEM);
    put_header(
        buf,
        FrameKind::Response,
        status.as_u8(),
        id,
        0,
        values.len() as u32,
    );
    for v in values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Incremental frame reassembler over a fixed-capacity buffer.
///
/// Feed bytes in (any fragmentation — byte-at-a-time is fine), call
/// [`poll`](Self::poll) until it yields a complete frame, decode, then
/// [`consume`](Self::consume).  The buffer is sized once at
/// construction for the largest legal frame plus read slack; the
/// declared payload length can never grow it.
pub struct FrameAssembler {
    buf: Vec<u8>,
    len: usize,
    max_values: usize,
}

impl FrameAssembler {
    /// Assembler accepting at most `max_values` payload elements per
    /// frame.  Capacity covers one worst-case response frame (the wider
    /// element) plus one header, so a full frame and the start of the
    /// next fit without stalling the reader.
    pub fn new(max_values: usize) -> Self {
        let cap = HEADER_LEN + max_values.max(1) * RESP_ELEM + HEADER_LEN;
        FrameAssembler {
            buf: vec![0u8; cap],
            len: 0,
            max_values: max_values.max(1),
        }
    }

    /// Bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.len
    }

    /// Largest legal `n_values`.
    pub fn max_values(&self) -> usize {
        self.max_values
    }

    /// Writable tail for a socket read (`read(spare())` then
    /// [`commit`](Self::commit) the byte count).  Empty only when the
    /// buffer is full — which, with the construction-time sizing, means
    /// the peer sent a full frame we have not consumed yet.
    pub fn spare(&mut self) -> &mut [u8] {
        &mut self.buf[self.len..]
    }

    /// Mark `n` bytes of [`spare`](Self::spare) as filled.
    pub fn commit(&mut self, n: usize) {
        self.len = (self.len + n).min(self.buf.len());
    }

    /// Copy as much of `bytes` as fits; returns the count consumed.
    pub fn feed(&mut self, bytes: &[u8]) -> usize {
        let room = self.buf.len() - self.len;
        let n = bytes.len().min(room);
        self.buf[self.len..self.len + n].copy_from_slice(&bytes[..n]);
        self.len += n;
        n
    }

    // hot-path: frame decode — poll/decode/consume run once per framed
    // request on the serving path; lease buffers are pre-sized, so no
    // allocation is tolerated here.

    /// Parse the buffered bytes.  `Ok(None)` = incomplete (feed more);
    /// `Ok(Some(h))` = one whole validated frame is buffered;
    /// `Err` = the stream is invalid at the current position (close the
    /// connection — resynchronising an adversarial stream is hopeless).
    ///
    /// Header fields are validated as soon as the header itself is
    /// buffered: an oversized or malformed declaration is rejected
    /// without waiting for (or trusting) its payload.
    pub fn poll(&self) -> Result<Option<FrameHeader>, FrameError> {
        if self.len < HEADER_LEN {
            return Ok(None);
        }
        let b = &self.buf[..self.len];
        let magic: [u8; 4] = le_bytes(b, 0);
        if magic != MAGIC {
            return Err(FrameError::BadMagic(magic));
        }
        let version = u16::from_le_bytes(le_bytes(b, 4));
        if version != VERSION {
            return Err(FrameError::BadVersion(version));
        }
        let [kind_byte, status] = le_bytes::<2>(b, 6);
        let Some(kind) = FrameKind::from_u8(kind_byte) else {
            return Err(FrameError::BadKind(kind_byte));
        };
        let id = u64::from_le_bytes(le_bytes(b, 8));
        let deadline_us = u64::from_le_bytes(le_bytes(b, 16));
        let n_values = u32::from_le_bytes(le_bytes(b, 24));
        if n_values as usize > self.max_values {
            return Err(FrameError::Oversize {
                n_values,
                max_values: self.max_values,
            });
        }
        let header = FrameHeader {
            kind,
            status,
            id,
            deadline_us,
            n_values: n_values as usize,
        };
        if self.len < header.frame_len() {
            return Ok(None); // payload still in flight
        }
        Ok(Some(header))
    }

    /// Decode a request frame's payload into `dst` (which must be
    /// exactly `n_values` long — the caller checks the width *before*
    /// taking a lease).  Returns `false`, leaving `dst` unspecified,
    /// when any payload float is NaN or infinite.
    pub fn decode_request_into(&self, header: &FrameHeader, dst: &mut [f32]) -> bool {
        assert_eq!(header.kind, FrameKind::Request, "not a request frame");
        assert_eq!(dst.len(), header.n_values, "destination width mismatch");
        // A hard assert: a debug_assert here would vanish in release and
        // let a short buffer decode a truncated payload silently (the
        // `release-vanishing-guard` lint's bug class).
        assert!(self.len >= header.frame_len(), "frame not fully buffered");
        let payload = &self.buf[HEADER_LEN..header.frame_len()];
        for (slot, chunk) in dst.iter_mut().zip(payload.chunks_exact(REQ_ELEM)) {
            let v = f32::from_le_bytes(le_bytes(chunk, 0));
            if !v.is_finite() {
                return false;
            }
            *slot = v;
        }
        true
    }

    /// Decode a response frame's payload into `dst` (must be exactly
    /// `n_values` long).
    pub fn decode_response_into(&self, header: &FrameHeader, dst: &mut [f64]) {
        assert_eq!(header.kind, FrameKind::Response, "not a response frame");
        assert_eq!(dst.len(), header.n_values, "destination width mismatch");
        // Hard assert for the same reason as in `decode_request_into`.
        assert!(self.len >= header.frame_len(), "frame not fully buffered");
        let payload = &self.buf[HEADER_LEN..header.frame_len()];
        for (slot, chunk) in dst.iter_mut().zip(payload.chunks_exact(RESP_ELEM)) {
            *slot = f64::from_le_bytes(le_bytes(chunk, 0));
        }
    }

    /// Drop a decoded frame's bytes, compacting any following bytes to
    /// the front (no allocation).
    pub fn consume(&mut self, header: &FrameHeader) {
        let n = header.frame_len().min(self.len);
        self.buf.copy_within(n..self.len, 0);
        self.len -= n;
    }

    // hot-path: end

    /// Discard everything buffered (post-error reset in tests).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn signals(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32 * 0.25 - 1.0).collect()
    }

    #[test]
    fn request_roundtrip_bit_exact() {
        let sig = signals(9);
        let mut wire = Vec::new();
        encode_request(&mut wire, 77, 1500, &sig);
        assert_eq!(wire.len(), HEADER_LEN + 9 * 4);

        let mut asm = FrameAssembler::new(16);
        assert_eq!(asm.feed(&wire), wire.len());
        let h = asm.poll().unwrap().expect("complete frame");
        assert_eq!(h.kind, FrameKind::Request);
        assert_eq!(h.id, 77);
        assert_eq!(h.deadline_us, 1500);
        assert_eq!(h.n_values, 9);
        let mut out = vec![0.0f32; 9];
        assert!(asm.decode_request_into(&h, &mut out));
        assert_eq!(out, sig, "payload must roundtrip bit-exactly");
        asm.consume(&h);
        assert_eq!(asm.buffered(), 0);
        assert!(asm.poll().unwrap().is_none());
    }

    #[test]
    fn response_roundtrip_bit_exact() {
        let vals: Vec<f64> = (0..13).map(|i| (i as f64).sqrt() - 2.0).collect();
        let mut wire = Vec::new();
        encode_response(&mut wire, 5, Status::Ok, &vals);
        let mut asm = FrameAssembler::new(13);
        asm.feed(&wire);
        let h = asm.poll().unwrap().unwrap();
        assert_eq!(h.kind, FrameKind::Response);
        assert_eq!(Status::from_u8(h.status), Some(Status::Ok));
        let mut out = vec![0.0f64; 13];
        asm.decode_response_into(&h, &mut out);
        assert_eq!(out, vals);
    }

    #[test]
    fn byte_at_a_time_reassembly() {
        let sig = signals(5);
        let mut wire = Vec::new();
        encode_request(&mut wire, 1, 0, &sig);
        let mut asm = FrameAssembler::new(8);
        for (i, b) in wire.iter().enumerate() {
            // incomplete at every prefix…
            assert!(asm.poll().unwrap().is_none(), "premature frame at byte {i}");
            assert_eq!(asm.feed(std::slice::from_ref(b)), 1);
        }
        // …complete only on the final byte
        let h = asm.poll().unwrap().expect("complete");
        assert_eq!(h.n_values, 5);
    }

    #[test]
    fn two_frames_back_to_back_compact() {
        let mut wire = Vec::new();
        let mut all = Vec::new();
        encode_request(&mut wire, 1, 0, &signals(4));
        all.extend_from_slice(&wire);
        encode_request(&mut wire, 2, 9, &signals(4));
        all.extend_from_slice(&wire);

        let mut asm = FrameAssembler::new(4);
        let mut fed = 0;
        let mut ids = Vec::new();
        while ids.len() < 2 {
            fed += asm.feed(&all[fed..]);
            while let Some(h) = asm.poll().unwrap() {
                ids.push(h.id);
                asm.consume(&h);
            }
        }
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(fed, all.len());
        assert_eq!(asm.buffered(), 0);
    }

    #[test]
    fn bad_magic_version_kind_are_typed_errors() {
        let mut wire = Vec::new();
        encode_request(&mut wire, 1, 0, &signals(2));

        let mut bad = wire.clone();
        bad[0] = b'X';
        let mut asm = FrameAssembler::new(4);
        asm.feed(&bad);
        assert!(matches!(asm.poll(), Err(FrameError::BadMagic(_))));

        let mut bad = wire.clone();
        bad[4] = 0xFF;
        let mut asm = FrameAssembler::new(4);
        asm.feed(&bad);
        assert!(matches!(asm.poll(), Err(FrameError::BadVersion(_))));

        let mut bad = wire.clone();
        bad[6] = 42;
        let mut asm = FrameAssembler::new(4);
        asm.feed(&bad);
        assert!(matches!(asm.poll(), Err(FrameError::BadKind(42))));
    }

    #[test]
    fn oversize_declaration_rejected_before_payload() {
        // Header declares u32::MAX values; only the header is sent.
        // The assembler must reject from the header alone — never wait
        // for (or try to buffer) the impossible payload.
        let mut wire = Vec::new();
        encode_request(&mut wire, 1, 0, &signals(2));
        wire.truncate(HEADER_LEN);
        wire[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut asm = FrameAssembler::new(104);
        asm.feed(&wire);
        match asm.poll() {
            Err(FrameError::Oversize { n_values, .. }) => assert_eq!(n_values, u32::MAX),
            other => panic!("expected Oversize, got {other:?}"),
        }
    }

    #[test]
    fn declared_length_never_grows_the_buffer() {
        let mut asm = FrameAssembler::new(8);
        let cap = asm.buf.len();
        // a legal-looking header followed by a flood of garbage
        let mut wire = Vec::new();
        encode_request(&mut wire, 3, 0, &signals(8));
        wire.extend_from_slice(&[0xAA; 4096]);
        let mut fed = 0;
        loop {
            let n = asm.feed(&wire[fed..]);
            fed += n;
            if n == 0 {
                break; // buffer full: backpressure, not growth
            }
        }
        assert_eq!(asm.buf.len(), cap, "fixed capacity must never grow");
        assert!(fed < wire.len(), "flood must hit the cap");
        // the real frame at the front still parses
        let h = asm.poll().unwrap().expect("frame");
        assert_eq!(h.id, 3);
    }

    #[test]
    fn nonfinite_payload_rejected() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut sig = signals(4);
            sig[2] = bad;
            let mut wire = Vec::new();
            encode_request(&mut wire, 1, 0, &sig);
            let mut asm = FrameAssembler::new(4);
            asm.feed(&wire);
            let h = asm.poll().unwrap().unwrap();
            let mut out = vec![0.0f32; 4];
            assert!(
                !asm.decode_request_into(&h, &mut out),
                "non-finite {bad} must be rejected"
            );
        }
    }

    #[test]
    fn spare_commit_socket_style_path() {
        let sig = signals(6);
        let mut wire = Vec::new();
        encode_request(&mut wire, 12, 7, &sig);
        let mut asm = FrameAssembler::new(6);
        let mut off = 0;
        while off < wire.len() {
            let spare = asm.spare();
            assert!(!spare.is_empty());
            let n = spare.len().min(3).min(wire.len() - off); // 3-byte reads
            spare[..n].copy_from_slice(&wire[off..off + n]);
            asm.commit(n);
            off += n;
        }
        let h = asm.poll().unwrap().expect("complete");
        assert_eq!((h.id, h.deadline_us), (12, 7));
    }

    /// Random bytes can never panic the parser, make it read beyond
    /// what was fed, or produce a frame that validates falsely.
    #[test]
    fn random_bytes_never_panic_or_overread() {
        let mut rng = Pcg32::new(0xF8A3);
        let mut asm = FrameAssembler::new(104);
        for _ in 0..2000 {
            let n = rng.below(96) as usize;
            let chunk: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            asm.feed(&chunk);
            match asm.poll() {
                Ok(Some(h)) => {
                    // complete frame: decoding must stay in bounds
                    match h.kind {
                        FrameKind::Request => {
                            let mut out = vec![0.0f32; h.n_values];
                            let _ = asm.decode_request_into(&h, &mut out);
                        }
                        FrameKind::Response => {
                            let mut out = vec![0.0f64; h.n_values];
                            asm.decode_response_into(&h, &mut out);
                        }
                    }
                    asm.consume(&h);
                }
                Ok(None) => {}
                Err(_) => asm.clear(), // typed rejection: connection would close
            }
        }
    }
}
