//! Persistent worker pool for batch-parallel kernels.
//!
//! A dependency-free fork/join pool over `std::thread`: built **once**
//! (spawning `threads - 1` OS threads; the caller participates as lane
//! 0), then reused for every [`WorkerPool::run`] call with zero
//! steady-state allocation — the same capacity-stability contract as
//! the engines it serves.  Parking uses the `Mutex` + `Condvar`
//! recheck-under-lock idiom from `coordinator/deque.rs`: a worker only
//! sleeps after re-checking the epoch under the lock, so a wakeup
//! posted between the check and the wait can never be lost.
//!
//! Work is handed out as a **deterministic strided partition**: task
//! `t` always runs on lane `t % threads`, independent of scheduling.
//! Combined with the [`tile`] helper (contiguous index ranges, no
//! cross-tile reductions) this is what lets callers split a batch
//! dimension across lanes while staying **bit-exact** with the
//! single-threaded path: every output element is computed by the same
//! scalar code on the same inputs, only on a different thread.
//!
//! Panic containment: each task runs under `catch_unwind`.  A
//! panicking task fails that `run` call with an error, but the pool —
//! and its threads — stay usable for the next call.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// One posted fork/join job.  The closure reference is lifetime-erased
/// to `'static` by [`WorkerPool::run`]; soundness rests on `run` not
/// returning until every lane has finished with it (completion
/// barrier), so workers never observe it dangling.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    n_tasks: usize,
    stride: usize,
}

struct State {
    /// Monotone job counter; workers run a job when `epoch` passes
    /// their last-seen value.  Posted together with `job` under the
    /// lock, so a worker that observes the new epoch observes the job.
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
    /// Spawned workers still running the current job.
    active: usize,
    /// Panicking tasks observed by spawned workers this job.
    panics: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The caller parks here waiting for `active` to reach zero.
    done_cv: Condvar,
}

/// A persistent pool of `threads` lanes (the calling thread plus
/// `threads - 1` spawned workers).  `threads <= 1` spawns nothing and
/// [`WorkerPool::run`] degenerates to the exact inline loop.
pub struct WorkerPool {
    threads: usize,
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Build a pool with `threads` total lanes (clamped to >= 1).
    /// This is the only allocating call; `run` never allocates.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                shutdown: false,
                active: 0,
                panics: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, lane))
            })
            .collect();
        WorkerPool {
            threads,
            shared,
            handles,
        }
    }

    /// Total lanes, including the calling thread.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Spawned OS threads (0 for an inline pool).
    pub fn worker_threads(&self) -> usize {
        self.handles.len()
    }

    /// Owned-buffer capacities (no-allocation witness: stable across
    /// `run` calls).
    pub fn alloc_signature(&self) -> Vec<usize> {
        vec![self.threads, self.handles.capacity()]
    }

    /// Run tasks `0..n_tasks`, task `t` on lane `t % threads`, and
    /// block until all have finished.  Errors if any task panicked;
    /// the pool stays usable afterwards.
    pub fn run<F>(&self, n_tasks: usize, f: F) -> anyhow::Result<()>
    where
        F: Fn(usize) + Sync,
    {
        if n_tasks == 0 {
            return Ok(());
        }
        if self.handles.is_empty() {
            // threads=1: exactly the inline path (same task order, same
            // panic accounting) with no synchronisation at all.
            let mut panics = 0usize;
            for t in 0..n_tasks {
                if catch_unwind(AssertUnwindSafe(|| f(t))).is_err() {
                    panics += 1;
                }
            }
            anyhow::ensure!(panics == 0, "{panics} worker task(s) panicked");
            return Ok(());
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the reference only outlives this frame in the eyes of
        // the type system.  `run` does not return until every spawned
        // lane has decremented `active` for this epoch (the wait loop
        // below), and `job` is cleared before returning, so no worker
        // can touch `f` after it goes out of scope.
        let f_static = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_ref)
        };
        let job = Job {
            f: f_static,
            n_tasks,
            stride: self.threads,
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.active = self.handles.len();
            st.panics = 0;
            self.shared.work_cv.notify_all();
        }
        // The caller is lane 0 — it works instead of idling.
        let own_panics = run_lane(job, 0);
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None; // drop the erased-lifetime reference
        let total = st.panics + own_panics;
        drop(st);
        anyhow::ensure!(total == 0, "{total} worker task(s) panicked");
        Ok(())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run one lane's strided share of a job, counting contained panics.
fn run_lane(job: Job, lane: usize) -> usize {
    let mut panics = 0usize;
    let mut t = lane;
    while t < job.n_tasks {
        if catch_unwind(AssertUnwindSafe(|| (job.f)(t))).is_err() {
            panics += 1;
        }
        t += job.stride;
    }
    panics
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    seen = st.epoch;
                    break *st.job.as_ref().expect("job posted with epoch");
                }
                // Recheck-under-lock park (deque idiom): the wait
                // atomically releases the lock, so a notify between the
                // epoch check and the wait cannot be lost.
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let panics = run_lane(job, lane);
        let mut st = shared.state.lock().unwrap();
        st.panics += panics;
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

/// Deterministic contiguous partition of `0..n` into `parts` tiles:
/// tile `k` is `[lo, hi)`.  The first `n % parts` tiles get one extra
/// element; tiles are disjoint and exhaustive for every `(n, parts)`.
pub fn tile(n: usize, parts: usize, k: usize) -> (usize, usize) {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let lo = k * base + k.min(rem);
    let hi = lo + base + usize::from(k < rem);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn tile_partition_is_disjoint_and_exhaustive() {
        for n in [0usize, 1, 2, 7, 16, 17, 104] {
            for parts in [1usize, 2, 3, 4, 8, 16] {
                let mut next = 0usize;
                for k in 0..parts {
                    let (lo, hi) = tile(n, parts, k);
                    assert_eq!(lo, next, "tile {k} of {n}/{parts} not contiguous");
                    assert!(hi >= lo);
                    next = hi;
                }
                assert_eq!(next, n, "tiles of {n}/{parts} do not cover 0..n");
            }
        }
    }

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.run(37, |t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {t}");
        }
    }

    #[test]
    fn threads_one_is_exactly_the_inline_path() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.worker_threads(), 0);
        let caller = std::thread::current().id();
        let order = Mutex::new(Vec::new());
        pool.run(8, |t| {
            assert_eq!(std::thread::current().id(), caller);
            order.lock().unwrap().push(t);
        })
        .unwrap();
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_task_fails_the_call_but_pool_survives() {
        let pool = WorkerPool::new(4);
        let err = pool
            .run(8, |t| {
                if t == 3 {
                    panic!("injected task failure");
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "got: {err}");
        // The pool is still fully usable after the poisoned job.
        let n = AtomicUsize::new(0);
        pool.run(16, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(n.load(Ordering::SeqCst), 16);
        // ... including on the inline (threads=1) accounting path.
        let inline = WorkerPool::new(1);
        assert!(inline.run(4, |t| assert!(t != 2, "boom")).is_err());
        assert!(inline.run(4, |_| {}).is_ok());
    }

    #[test]
    fn drop_joins_all_threads() {
        for round in 0..8 {
            let pool = WorkerPool::new(3);
            let n = AtomicUsize::new(0);
            pool.run(round + 1, |_| {
                n.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
            assert_eq!(n.load(Ordering::SeqCst), round + 1);
            drop(pool); // must join, not leak or hang
        }
    }

    #[test]
    fn strided_writes_match_serial_for_every_thread_count() {
        let n = 103usize;
        let serial: Vec<f32> = (0..n).map(|t| (t as f32).sin() * 3.0 + 1.0).collect();
        for threads in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0.0f32; n];
            {
                // Disjoint per-task writes through a raw-pointer
                // wrapper, the same pattern the tiled engine kernels
                // use: task t owns exactly slot t.
                struct SendPtr(*mut f32);
                // SAFETY: `out` outlives the pool.run barrier and each
                // task writes a distinct slot, so sharing is race-free.
                unsafe impl Send for SendPtr {}
                unsafe impl Sync for SendPtr {}
                let ptr = SendPtr(out.as_mut_ptr());
                pool.run(n, |t| {
                    // SAFETY: task t writes only slot t; tasks are disjoint.
                    unsafe { *ptr.0.add(t) = (t as f32).sin() * 3.0 + 1.0 };
                })
                .unwrap();
            }
            assert_eq!(out, serial, "threads={threads}");
        }
    }

    #[test]
    fn alloc_signature_is_stable_across_runs() {
        let pool = WorkerPool::new(4);
        pool.run(32, |_| {}).unwrap();
        let sig = pool.alloc_signature();
        for _ in 0..20 {
            pool.run(32, |_| {}).unwrap();
            assert_eq!(pool.alloc_signature(), sig);
        }
    }
}
