//! SIMD kernels for the two hot dot-product paths, gated behind the
//! `simd` cargo feature.  All `std::arch` code in the crate lives here,
//! wrapped in safe functions that assert their length preconditions;
//! dispatch policy (which kernel runs when) lives in
//! `crate::infer::kernels` and `crate::accel::pu` — this module only
//! provides the implementations.
//!
//! Three kernel families:
//!
//! * **SSE2 f32, exact order** — [`dot_one_f32`] / [`dot_rows_f32`].
//!   The scalar hot path accumulates into 4 independent chains `a0..a3`
//!   (chain `k` sums `x[4i+k] * w[4i+k]`) and combines them as
//!   `(a0+a1)+(a2+a3)`.  A single 4-lane vector accumulator updated with
//!   separate multiply and add performs *exactly* those four chains, lane
//!   for lane: IEEE-754 ops are deterministic and `_mm_mul_ps` /
//!   `_mm_add_ps` neither fuse nor reassociate.  The vector path is
//!   therefore **bit-exact** with the scalar oracle, which is what lets
//!   it be the default backend.  SSE2 is part of the x86_64 baseline, so
//!   it needs no runtime detection.
//! * **AVX2 f32, reordered** — [`dot_one_f32_reordered`] /
//!   [`dot_rows_f32_reordered`].  Eight chains instead of four — a
//!   *different* summation order, reachable only through the opt-in
//!   `DotMode::Reordered` dispatch and golden-tested at a tolerance.
//!   Runtime-gated on [`avx2_available`].  The lane structure and final
//!   reduction mirror `infer::kernels::dot_one_reordered_scalar` exactly,
//!   so reordered results are bit-identical whether the AVX2 unit or the
//!   portable fallback computed them.
//! * **AVX2 fixed-point** — [`fx_dot_acc`]: i16 × i16 → i32 products
//!   accumulated in four i64 lanes.  Integer addition is associative and
//!   commutative, so any summation order is bit-exact with the PU
//!   adder-tree scalar path, and this kernel is dispatched by default.
//!   `_mm256_madd_epi16` (pmaddwd) is deliberately **not** used: it adds
//!   adjacent product pairs in i32, and two neighbouring `(-32768)²`
//!   terms overflow to exactly `i32::MIN`; Q4.12's `-8.0` *is* `-32768`
//!   (reachable through `Fx::from_f32` saturation), so the wrap is a
//!   real input.  Products are instead sign-extended to i32, multiplied
//!   exactly in 32 bits (|p| ≤ 2^30), then widened to i64.

/// True when the AVX2 kernels may be dispatched: the `simd` feature is
/// compiled in, the target is x86_64 and the CPU reports AVX2.  Always
/// false otherwise — dispatchers then select a scalar fallback, which is
/// what the runtime-dispatch tests pin.
#[inline]
pub fn avx2_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use std::arch::x86_64::*;

    /// SSE2 dot product in the canonical 4-chain accumulation order —
    /// bit-exact with `infer::kernels::dot_one_scalar`.
    pub fn dot_one_f32(nb: usize, x: &[f32], w: &[f32]) -> f32 {
        assert!(
            x.len() >= nb && w.len() >= nb,
            "dot_one: slices shorter than nb"
        );
        let chunks = nb / 4 * 4;
        // SAFETY: SSE2 is unconditionally available on x86_64; every
        // load stays inside the asserted `nb` prefix.
        unsafe {
            let mut acc = _mm_setzero_ps();
            let mut i = 0;
            while i < chunks {
                let xv = _mm_loadu_ps(x.as_ptr().add(i));
                let wv = _mm_loadu_ps(w.as_ptr().add(i));
                acc = _mm_add_ps(acc, _mm_mul_ps(xv, wv));
                i += 4;
            }
            let mut lanes = [0.0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
            for j in chunks..nb {
                s += x[j] * w[j];
            }
            s
        }
    }

    /// SSE2 four-row dot product sharing the `x` loads — each row's
    /// accumulation order is identical to [`dot_one_f32`] (bit-exact with
    /// `infer::kernels::dot_rows_scalar`).
    pub fn dot_rows_f32(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
        assert!(x.len() >= nb, "dot_rows: x shorter than nb");
        for w in &ws {
            assert!(w.len() >= nb, "dot_rows: weight row shorter than nb");
        }
        let chunks = nb / 4 * 4;
        let mut out = [0.0f32; 4];
        // SAFETY: as in dot_one_f32.
        unsafe {
            let mut acc = [_mm_setzero_ps(); 4];
            let mut i = 0;
            while i < chunks {
                let xv = _mm_loadu_ps(x.as_ptr().add(i));
                for (r, a) in acc.iter_mut().enumerate() {
                    let wv = _mm_loadu_ps(ws[r].as_ptr().add(i));
                    *a = _mm_add_ps(*a, _mm_mul_ps(xv, wv));
                }
                i += 4;
            }
            for (r, a) in acc.iter().enumerate() {
                let mut lanes = [0.0f32; 4];
                _mm_storeu_ps(lanes.as_mut_ptr(), *a);
                let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
                for j in chunks..nb {
                    s += x[j] * ws[r][j];
                }
                out[r] = s;
            }
        }
        out
    }

    /// # Safety
    /// Caller must have verified AVX2 support and that `x`/`w` hold at
    /// least `nb` elements (the tail loop reads up to `nb`).
    #[target_feature(enable = "avx2")]
    unsafe fn dot_one_f32_avx2(nb: usize, x: &[f32], w: &[f32]) -> f32 {
        let chunks = nb / 8 * 8;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < chunks {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let wv = _mm256_loadu_ps(w.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, wv));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        // Must stay textually in sync with dot_one_reordered_scalar's
        // final reduction — that is what makes the two bit-identical.
        let mut s = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
        for j in chunks..nb {
            s += x[j] * w[j];
        }
        s
    }

    /// AVX2 dot product in the 8-chain reordered accumulation order —
    /// bit-exact with `infer::kernels::dot_one_reordered_scalar`, *not*
    /// with the canonical 4-chain order.
    pub fn dot_one_f32_reordered(nb: usize, x: &[f32], w: &[f32]) -> f32 {
        assert!(
            x.len() >= nb && w.len() >= nb,
            "dot_one: slices shorter than nb"
        );
        assert!(
            super::avx2_available(),
            "AVX2 kernel dispatched without CPU support"
        );
        // SAFETY: AVX2 presence asserted above; loads stay inside `nb`.
        unsafe { dot_one_f32_avx2(nb, x, w) }
    }

    /// AVX2 four-row variant of [`dot_one_f32_reordered`].
    pub fn dot_rows_f32_reordered(nb: usize, x: &[f32], ws: [&[f32]; 4]) -> [f32; 4] {
        assert!(x.len() >= nb, "dot_rows: x shorter than nb");
        for w in &ws {
            assert!(w.len() >= nb, "dot_rows: weight row shorter than nb");
        }
        assert!(
            super::avx2_available(),
            "AVX2 kernel dispatched without CPU support"
        );
        let mut out = [0.0f32; 4];
        for (r, o) in out.iter_mut().enumerate() {
            // SAFETY: AVX2 presence asserted above; loads stay inside `nb`.
            *o = unsafe { dot_one_f32_avx2(nb, x, ws[r]) };
        }
        out
    }

    /// # Safety
    /// Caller must have verified AVX2 support and that `w` is at least
    /// as long as `x` (loads index both up to `x.len()`).
    #[target_feature(enable = "avx2")]
    unsafe fn fx_dot_acc_avx2(x: &[i16], w: &[i16]) -> i64 {
        let n = x.len();
        let chunks = n / 8 * 8;
        let mut acc_lo = _mm256_setzero_si256();
        let mut acc_hi = _mm256_setzero_si256();
        let mut i = 0;
        while i < chunks {
            let xv = _mm_loadu_si128(x.as_ptr().add(i) as *const __m128i);
            let wv = _mm_loadu_si128(w.as_ptr().add(i) as *const __m128i);
            // sign-extend to i32, multiply exactly (|p| <= 2^30), widen
            // to i64 — see the module docs for why NOT pmaddwd.
            let prod = _mm256_mullo_epi32(_mm256_cvtepi16_epi32(xv), _mm256_cvtepi16_epi32(wv));
            let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(prod));
            let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(prod));
            acc_lo = _mm256_add_epi64(acc_lo, lo);
            acc_hi = _mm256_add_epi64(acc_hi, hi);
            i += 8;
        }
        let acc = _mm256_add_epi64(acc_lo, acc_hi);
        let mut lanes = [0i64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        while i < n {
            s += (x[i] as i32 * w[i] as i32) as i64;
            i += 1;
        }
        s
    }

    /// AVX2 fixed-point chunk-MAC: Σ (x[i] as i32 * w[i] as i32) as i64.
    /// Bit-exact with the scalar PU adder tree for any summation order
    /// (i64 addition is associative; no overflow — |product| ≤ 2^30 and
    /// reaching i64 range would need more than 2^33 terms).
    pub fn fx_dot_acc(x: &[i16], w: &[i16]) -> i64 {
        assert_eq!(
            x.len(),
            w.len(),
            "fx_dot_acc: input length {} != weight length {}",
            x.len(),
            w.len()
        );
        assert!(
            super::avx2_available(),
            "AVX2 kernel dispatched without CPU support"
        );
        // SAFETY: AVX2 presence asserted above; equal-length slices.
        unsafe { fx_dot_acc_avx2(x, w) }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub use x86::{dot_one_f32, dot_one_f32_reordered, dot_rows_f32, dot_rows_f32_reordered, fx_dot_acc};

#[cfg(all(test, feature = "simd", target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::infer::kernels::{dot_one_reordered_scalar, dot_one_scalar, dot_rows_scalar};
    use crate::util::rng::Pcg32;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed);
        let x = (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let w = (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        (x, w)
    }

    const SIZES: [usize; 12] = [0, 1, 2, 3, 4, 5, 7, 8, 17, 33, 104, 300];

    #[test]
    fn sse2_dot_one_is_bit_exact_vs_scalar() {
        for nb in SIZES {
            let (x, w) = vecs(nb, 100 + nb as u64);
            let got = dot_one_f32(nb, &x, &w);
            let want = dot_one_scalar(nb, &x, &w);
            assert_eq!(got.to_bits(), want.to_bits(), "nb={nb}: {got} vs {want}");
        }
    }

    #[test]
    fn sse2_dot_rows_is_bit_exact_vs_scalar() {
        for nb in SIZES {
            let (x, _) = vecs(nb, 200 + nb as u64);
            let (wflat, _) = vecs(nb * 4, 300 + nb as u64);
            let ws = [
                &wflat[..nb],
                &wflat[nb..2 * nb],
                &wflat[2 * nb..3 * nb],
                &wflat[3 * nb..4 * nb],
            ];
            let got = dot_rows_f32(nb, &x, ws);
            let want = dot_rows_scalar(nb, &x, ws);
            for r in 0..4 {
                assert_eq!(got[r].to_bits(), want[r].to_bits(), "nb={nb} row {r}");
            }
        }
    }

    #[test]
    fn avx2_reordered_is_bit_exact_vs_reordered_scalar() {
        if !avx2_available() {
            return; // covered by the dispatch fallback tests instead
        }
        for nb in SIZES {
            let (x, w) = vecs(nb, 400 + nb as u64);
            let got = dot_one_f32_reordered(nb, &x, &w);
            let want = dot_one_reordered_scalar(nb, &x, &w);
            assert_eq!(got.to_bits(), want.to_bits(), "nb={nb}: {got} vs {want}");
        }
    }

    #[test]
    fn avx2_fx_dot_acc_is_bit_exact_vs_linear_sum() {
        if !avx2_available() {
            return;
        }
        let mut rng = Pcg32::new(9);
        for n in SIZES {
            let x: Vec<i16> = (0..n).map(|_| rng.below(1 << 16) as u16 as i16).collect();
            let w: Vec<i16> = (0..n).map(|_| rng.below(1 << 16) as u16 as i16).collect();
            let want: i64 = x
                .iter()
                .zip(&w)
                .map(|(&a, &b)| (a as i32 * b as i32) as i64)
                .sum();
            assert_eq!(fx_dot_acc(&x, &w), want, "n={n}");
        }
        // extremes: (-32768)^2 pairs are exactly the pmaddwd trap
        let x = vec![i16::MIN; 20];
        assert_eq!(fx_dot_acc(&x, &x), 20 * (1i64 << 30));
    }
}
