//! Small statistics helpers used across metrics, benches and the
//! coordinator's latency tracking.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 items.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Root mean squared error between two equal-length slices.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "rmse needs equal lengths");
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (s / a.len() as f64).sqrt()
}

/// Median (linear interpolation between middle elements for even n).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Percentile in [0, 100] with linear interpolation; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Pearson correlation coefficient; 0.0 when undefined.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let ma = mean(a);
    let mb = mean(b);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..n {
        let xa = a[i] - ma;
        let xb = b[i] - mb;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da.sqrt() * db.sqrt())
}

/// Streaming mean/min/max/std accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
    }

    #[test]
    fn rmse_basic() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn pearson_signs() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&a, &[1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        assert_eq!(acc.count(), xs.len() as u64);
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 9.0);
    }

    #[test]
    fn empty_accumulator_is_zeroed() {
        let acc = Accumulator::new();
        assert_eq!(acc.mean(), 0.0);
        assert_eq!(acc.std(), 0.0);
        assert_eq!(acc.min(), 0.0);
        assert_eq!(acc.max(), 0.0);
    }
}
