//! Minimal JSON value model, parser and writer.
//!
//! The offline crate registry has no `serde`/`serde_json` (DESIGN.md §7),
//! so artifact manifests and report files are handled by this module.  It
//! implements the full JSON grammar (RFC 8259) minus some exotic number
//! edge cases, which is all the manifest needs, plus a pretty writer used
//! by the report generators.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Numbers are kept as f64 (the manifest's integers
/// are all well within the 2^53 exact range).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------------- access
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array of f64s (errors ignored -> empty).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
            .unwrap_or_default()
    }

    // ---------------------------------------------------------------- parse
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------------- write
    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }
    /// Pretty serialization with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *n as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// Convenience constructors used by report writers.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from key/value pairs.
#[macro_export]
macro_rules! json_obj {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::util::json::Json::from($v)); )*
        $crate::util::json::Json::Obj(m)
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-3}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn json_obj_macro() {
        let v = json_obj! {"a" => 1.0, "b" => "x", "c" => vec![1.0, 2.0]};
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").to_f64_vec(), vec![1.0, 2.0]);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "variant": "tiny", "nb": 11,
          "bvalues": [0, 5, 10],
          "param_layout": [{"name": "d.w1", "offset": 0, "shape": [11, 11]}],
          "masks": {"d.mask1": [1, 0, 1]}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("nb").as_usize(), Some(11));
        let lay = v.get("param_layout").as_arr().unwrap();
        assert_eq!(lay[0].get("shape").to_f64_vec(), vec![11.0, 11.0]);
    }
}
