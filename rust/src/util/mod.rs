//! Shared infrastructure: deterministic RNG, statistics, JSON, thread
//! pool, wire-frame codec, timing and binary I/O helpers.

pub mod frame;
pub mod json;
pub mod pool;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod workers;

use std::io::{Read, Write};
use std::path::Path;
use std::time::Instant;

/// Read a little-endian f32 binary file (the artifact format for weight
/// vectors and golden tensors).
pub fn read_f32_file(path: &Path) -> anyhow::Result<Vec<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?
        .read_to_end(&mut bytes)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a slice of f32 as little-endian binary (inverse of
/// [`read_f32_file`]).
pub fn write_f32_file(path: &Path, data: &[f32]) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("create {}: {e}", path.display()))?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Wall-clock timer returning seconds.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("uivim_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.bin");
        let data = vec![0.0f32, 1.5, -2.25, f32::MIN_POSITIVE, 1.0e30];
        write_f32_file(&path, &data).unwrap();
        let back = read_f32_file(&path).unwrap();
        assert_eq!(back, data);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn read_rejects_misaligned() {
        let dir = std::env::temp_dir().join("uivim_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8, 1, 2]).unwrap();
        assert!(read_f32_file(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
        assert!(t.elapsed_us() >= t.elapsed_ms());
    }
}
