//! 3-D anatomical phantom generator for the adaptive-radiotherapy example.
//!
//! The MR-Linac workflow (paper §I) images a tumour and surrounding organs
//! immediately before radiation delivery.  This module builds a simple but
//! structured digital phantom: a volume of tissue classes (background,
//! healthy parenchyma, tumour core, tumour rim, vessel) with
//! class-specific IVIM parameter distributions taken from the IVIM
//! literature (tumours: restricted diffusion / elevated perfusion
//! fraction; vessels: high D* and f).  Each voxel then gets a noisy signal
//! via the synthetic protocol, giving the serving examples a spatially
//! coherent, clinically shaped workload rather than i.i.d. voxels.

use super::{signal, IvimParams};
use crate::util::rng::Pcg32;

/// Tissue classes of the phantom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tissue {
    Background,
    Healthy,
    TumourCore,
    TumourRim,
    Vessel,
}

impl Tissue {
    /// Mean IVIM parameters per tissue class (loosely following pancreatic
    /// IVIM literature values).
    pub fn mean_params(self) -> IvimParams {
        match self {
            Tissue::Background => IvimParams {
                d: 0.0005,
                dstar: 0.01,
                f: 0.05,
                s0: 0.85,
            },
            Tissue::Healthy => IvimParams {
                d: 0.0016,
                dstar: 0.05,
                f: 0.25,
                s0: 1.0,
            },
            Tissue::TumourCore => IvimParams {
                d: 0.0009,
                dstar: 0.03,
                f: 0.12,
                s0: 1.05,
            },
            Tissue::TumourRim => IvimParams {
                d: 0.0012,
                dstar: 0.08,
                f: 0.35,
                s0: 1.1,
            },
            Tissue::Vessel => IvimParams {
                d: 0.0025,
                dstar: 0.15,
                f: 0.6,
                s0: 1.15,
            },
        }
    }
}

/// A 3-D digital phantom with per-voxel tissue class, ground truth and
/// noisy normalised signals.
pub struct Phantom {
    pub dim: (usize, usize, usize),
    pub tissue: Vec<Tissue>,
    pub truth: Vec<IvimParams>,
    /// Row-major `[voxel][nb]` normalised signals.
    pub signals: Vec<f32>,
    pub nb: usize,
}

impl Phantom {
    pub fn len(&self) -> usize {
        self.tissue.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tissue.is_empty()
    }
    pub fn voxel_signals(&self, i: usize) -> &[f32] {
        &self.signals[i * self.nb..(i + 1) * self.nb]
    }
    fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.dim.1 + y) * self.dim.0 + x
    }
    pub fn tissue_at(&self, x: usize, y: usize, z: usize) -> Tissue {
        self.tissue[self.idx(x, y, z)]
    }
    /// Count voxels of a class (for reporting).
    pub fn count(&self, t: Tissue) -> usize {
        self.tissue.iter().filter(|&&x| x == t).count()
    }
}

/// Geometry/noise configuration for phantom generation.
#[derive(Debug, Clone)]
pub struct PhantomConfig {
    pub dim: (usize, usize, usize),
    /// Tumour centre (fractions of the volume in [0,1]).
    pub tumour_centre: (f64, f64, f64),
    /// Tumour radius as a fraction of the smallest dimension.
    pub tumour_radius: f64,
    pub snr: f64,
    pub seed: u64,
}

impl Default for PhantomConfig {
    fn default() -> Self {
        PhantomConfig {
            dim: (16, 16, 8),
            tumour_centre: (0.5, 0.5, 0.5),
            tumour_radius: 0.25,
            snr: 20.0,
            seed: 7,
        }
    }
}

/// Generate a phantom: an ellipsoidal body of healthy tissue containing a
/// two-shell tumour and a straight vessel, embedded in background.
pub fn generate(cfg: &PhantomConfig, bvals: &[f64]) -> Phantom {
    let (nx, ny, nz) = cfg.dim;
    let mut rng = Pcg32::new(cfg.seed);
    let n = nx * ny * nz;
    let mut tissue = Vec::with_capacity(n);
    let mut truth = Vec::with_capacity(n);
    let mut signals = Vec::with_capacity(n * bvals.len());

    let min_dim = nx.min(ny).min(nz) as f64;
    let tc = (
        cfg.tumour_centre.0 * nx as f64,
        cfg.tumour_centre.1 * ny as f64,
        cfg.tumour_centre.2 * nz as f64,
    );
    let r_core = cfg.tumour_radius * min_dim * 0.6;
    let r_rim = cfg.tumour_radius * min_dim;

    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let fx = (x as f64 + 0.5) / nx as f64 - 0.5;
                let fy = (y as f64 + 0.5) / ny as f64 - 0.5;
                let fz = (z as f64 + 0.5) / nz as f64 - 0.5;
                // Ellipsoidal body occupying ~80% of the volume.
                let body = (fx / 0.45).powi(2) + (fy / 0.45).powi(2) + (fz / 0.48).powi(2)
                    <= 1.0;
                let dx = x as f64 + 0.5 - tc.0;
                let dy = y as f64 + 0.5 - tc.1;
                let dz = z as f64 + 0.5 - tc.2;
                let rt = (dx * dx + dy * dy + dz * dz).sqrt();
                // A straight vessel along z at 1/4, 1/4.
                let vessel = ((x as f64 - nx as f64 * 0.25).powi(2)
                    + (y as f64 - ny as f64 * 0.25).powi(2))
                .sqrt()
                    < 1.2;

                let t = if !body {
                    Tissue::Background
                } else if rt <= r_core {
                    Tissue::TumourCore
                } else if rt <= r_rim {
                    Tissue::TumourRim
                } else if vessel {
                    Tissue::Vessel
                } else {
                    Tissue::Healthy
                };

                // Per-voxel parameter jitter (10% relative) around the
                // class mean, clamped to the clinical ranges.
                let m = t.mean_params();
                let jit = |rng: &mut Pcg32, v: f64, (lo, hi): (f64, f64)| {
                    (v * (1.0 + 0.1 * rng.normal())).clamp(lo, hi)
                };
                let p = IvimParams {
                    d: jit(&mut rng, m.d, super::Param::D.range()),
                    dstar: jit(&mut rng, m.dstar, super::Param::DStar.range()),
                    f: jit(&mut rng, m.f, super::Param::F.range()),
                    s0: jit(&mut rng, m.s0, super::Param::S0.range()),
                };

                let noise_std = p.s0 / cfg.snr;
                let noisy: Vec<f64> = bvals
                    .iter()
                    .map(|&b| signal(b, &p) + noise_std * rng.normal())
                    .collect();
                let b0 = noisy
                    .iter()
                    .zip(bvals)
                    .filter(|(_, &b)| b == 0.0)
                    .map(|(s, _)| *s)
                    .next()
                    .unwrap_or(p.s0)
                    .max(1e-6);
                signals.extend(noisy.iter().map(|&v| (v / b0) as f32));
                tissue.push(t);
                truth.push(p);
            }
        }
    }

    Phantom {
        dim: cfg.dim,
        tissue,
        truth,
        signals,
        nb: bvals.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::bvalues_tiny;

    #[test]
    fn phantom_has_all_structures() {
        let cfg = PhantomConfig::default();
        let ph = generate(&cfg, &bvalues_tiny());
        assert_eq!(ph.len(), 16 * 16 * 8);
        assert!(ph.count(Tissue::TumourCore) > 0, "no tumour core");
        assert!(ph.count(Tissue::TumourRim) > 0, "no tumour rim");
        assert!(ph.count(Tissue::Healthy) > 0);
        assert!(ph.count(Tissue::Background) > 0);
        assert!(ph.count(Tissue::Vessel) > 0);
    }

    #[test]
    fn tumour_is_where_requested() {
        let cfg = PhantomConfig::default();
        let ph = generate(&cfg, &bvalues_tiny());
        assert_eq!(ph.tissue_at(8, 8, 4), Tissue::TumourCore);
        assert_eq!(ph.tissue_at(0, 0, 0), Tissue::Background);
    }

    #[test]
    fn signals_shape_and_normalisation() {
        let cfg = PhantomConfig {
            snr: 100.0,
            ..Default::default()
        };
        let b = bvalues_tiny();
        let ph = generate(&cfg, &b);
        assert_eq!(ph.signals.len(), ph.len() * b.len());
        // near-noiseless: b=0 column close to 1 after normalisation
        let v = ph.voxel_signals(ph.len() / 2);
        assert!((v[0] as f64 - 1.0).abs() < 0.1);
    }

    #[test]
    fn deterministic_in_seed() {
        let b = bvalues_tiny();
        let a = generate(&PhantomConfig::default(), &b);
        let c = generate(&PhantomConfig::default(), &b);
        assert_eq!(a.signals, c.signals);
    }

    #[test]
    fn tumour_params_differ_from_healthy() {
        let core = Tissue::TumourCore.mean_params();
        let healthy = Tissue::Healthy.mean_params();
        assert!(core.d < healthy.d, "tumour restricts diffusion");
        assert!(Tissue::Vessel.mean_params().f > healthy.f);
    }
}
