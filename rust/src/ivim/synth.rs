//! Synthetic dataset generator — the paper's Phase-1 protocol (§III, §VI-A).
//!
//! Draw (S0, D, D*, f) uniformly from the clinical ranges, evaluate
//! eq. (1) over the b-value protocol, add Gaussian noise with std
//! `S0 / SNR`, and normalise by the measured b=0 signal, exactly like the
//! Python generator (`ivim.synth_dataset`) — though with an independent
//! RNG (both produce *statistically identical* datasets; golden-vector
//! parity is only required for masks, not data).

use super::{signal, IvimParams, Param};
use crate::util::rng::Pcg32;

/// A generated dataset: normalised signals (row-major `[n][nb]`) plus
/// ground truth parameters per voxel.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub signals: Vec<f32>,
    pub truth: Vec<IvimParams>,
    pub nb: usize,
    pub snr: f64,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.truth.len()
    }
    pub fn is_empty(&self) -> bool {
        self.truth.is_empty()
    }
    /// Row view of voxel `i`'s signals.
    pub fn voxel(&self, i: usize) -> &[f32] {
        &self.signals[i * self.nb..(i + 1) * self.nb]
    }
}

/// Draw one parameter tuple uniformly from the clinical ranges.
pub fn draw_params(rng: &mut Pcg32) -> IvimParams {
    let u = |rng: &mut Pcg32, p: Param| {
        let (lo, hi) = p.range();
        rng.uniform(lo, hi)
    };
    IvimParams {
        d: u(rng, Param::D),
        dstar: u(rng, Param::DStar),
        f: u(rng, Param::F),
        s0: u(rng, Param::S0),
    }
}

/// Indices of the b == 0 acquisitions in a protocol (precompute once,
/// share across every voxel of a dataset or streamed volume).
pub fn b0_indices(bvals: &[f64]) -> Vec<usize> {
    bvals
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == 0.0)
        .map(|(i, _)| i)
        .collect()
}

/// Generate ONE voxel into `out` (length = `bvals.len()`) and return its
/// ground-truth parameters: draw the tuple, evaluate eq. (1) per
/// b-value, add `S0/SNR` Gaussian noise, normalise by the measured b=0
/// mean.  This is the single per-voxel generation step — `synth_dataset`
/// and the streaming volume generator (`volume::SliceStream`) both call
/// it against one sequential `Pcg32`, which is what makes a streamed
/// volume **bit-identical** to the batch dataset at the same seed.
/// `noisy` is caller-owned scratch (cleared here) so the streaming path
/// allocates nothing per voxel.
pub fn synth_voxel_into(
    rng: &mut Pcg32,
    bvals: &[f64],
    b0_idx: &[usize],
    snr: f64,
    noisy: &mut Vec<f64>,
    out: &mut [f32],
) -> IvimParams {
    assert_eq!(out.len(), bvals.len());
    let p = draw_params(rng);
    let noise_std = p.s0 / snr;
    noisy.clear();
    noisy.extend(bvals.iter().map(|&b| signal(b, &p) + noise_std * rng.normal()));
    // Normalise by the measured b=0 signal (mean over b==0 rows).
    let s_b0 = if b0_idx.is_empty() {
        p.s0
    } else {
        let m = b0_idx.iter().map(|&i| noisy[i]).sum::<f64>() / b0_idx.len() as f64;
        if m.abs() < 1e-6 {
            1e-6
        } else {
            m
        }
    };
    for (slot, &v) in out.iter_mut().zip(noisy.iter()) {
        *slot = (v / s_b0) as f32;
    }
    p
}

/// Generate `n` voxels at the given SNR (paper: 10,000 per SNR level).
pub fn synth_dataset(n: usize, bvals: &[f64], snr: f64, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed);
    let nb = bvals.len();
    let mut signals = vec![0.0f32; n * nb];
    let mut truth = Vec::with_capacity(n);
    let b0_idx = b0_indices(bvals);
    let mut noisy = Vec::with_capacity(nb);

    for i in 0..n {
        let row = &mut signals[i * nb..(i + 1) * nb];
        truth.push(synth_voxel_into(&mut rng, bvals, &b0_idx, snr, &mut noisy, row));
    }

    Dataset {
        signals,
        truth,
        nb,
        snr,
    }
}

/// Ground-truth values of one parameter across a dataset.
pub fn truth_column(ds: &Dataset, p: Param) -> Vec<f64> {
    ds.truth.iter().map(|t| t.get(p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::{bvalues_tiny, signal_curve};
    use crate::util::stats;

    #[test]
    fn shapes_and_ranges() {
        let b = bvalues_tiny();
        let ds = synth_dataset(100, &b, 20.0, 0);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.signals.len(), 100 * b.len());
        for t in &ds.truth {
            for p in Param::ALL {
                let (lo, hi) = p.range();
                let v = t.get(p);
                assert!(v >= lo && v <= hi, "{p:?}={v}");
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let b = bvalues_tiny();
        let a = synth_dataset(10, &b, 20.0, 3);
        let c = synth_dataset(10, &b, 20.0, 3);
        let d = synth_dataset(10, &b, 20.0, 4);
        assert_eq!(a.signals, c.signals);
        assert_ne!(a.signals, d.signals);
    }

    #[test]
    fn noise_scales_with_snr() {
        let b = bvalues_tiny();
        let resid = |snr: f64| {
            let ds = synth_dataset(2000, &b, snr, 1);
            let mut errs = Vec::new();
            for i in 0..ds.len() {
                let clean = signal_curve(&b, &ds.truth[i]);
                let s0 = ds.truth[i].s0;
                for (j, &v) in ds.voxel(i).iter().enumerate() {
                    errs.push((v as f64 - clean[j] / s0).abs());
                }
            }
            stats::mean(&errs)
        };
        let r5 = resid(5.0);
        let r15 = resid(15.0);
        let r50 = resid(50.0);
        assert!(r50 < r15 && r15 < r5, "{r5} {r15} {r50}");
    }

    #[test]
    fn normalised_b0_near_one() {
        let b = bvalues_tiny();
        let ds = synth_dataset(500, &b, 50.0, 2);
        // first column is the (self-normalised) b=0 acquisition
        let col0: Vec<f64> = (0..ds.len()).map(|i| ds.voxel(i)[0] as f64).collect();
        assert!((stats::mean(&col0) - 1.0).abs() < 0.05);
    }

    /// The streaming contract: generating voxel-by-voxel through
    /// `synth_voxel_into` against one sequential RNG — in arbitrary
    /// chunk sizes — reproduces `synth_dataset` bit for bit.  This is
    /// what lets `volume::SliceStream` stream slices without ever
    /// materialising the full signal volume while staying equal to the
    /// batch generator at the same seed.
    #[test]
    fn chunked_per_voxel_generation_is_bit_identical_to_dataset() {
        let b = bvalues_tiny();
        let nb = b.len();
        let n = 23;
        let ds = synth_dataset(n, &b, 15.0, 42);
        let mut rng = crate::util::rng::Pcg32::new(42);
        let b0 = b0_indices(&b);
        let mut noisy = Vec::new();
        let mut signals = Vec::new();
        let mut truth = Vec::new();
        let mut row = vec![0.0f32; nb];
        // uneven chunks: 7 + 7 + 7 + 2 voxels
        let mut done = 0;
        for chunk in [7usize, 7, 7, 2] {
            for _ in 0..chunk {
                truth.push(synth_voxel_into(&mut rng, &b, &b0, 15.0, &mut noisy, &mut row));
                signals.extend_from_slice(&row);
                done += 1;
            }
        }
        assert_eq!(done, n);
        assert_eq!(signals, ds.signals, "chunked generation must be bit-identical");
        assert_eq!(truth, ds.truth);
    }

    #[test]
    fn truth_column_extracts() {
        let b = bvalues_tiny();
        let ds = synth_dataset(5, &b, 20.0, 0);
        let col = truth_column(&ds, Param::F);
        assert_eq!(col.len(), 5);
        assert!((col[0] - ds.truth[0].f).abs() < 1e-15);
    }
}
