//! IVIM physics substrate: signal model (paper eq. 1), clinical parameter
//! ranges, synthetic data protocol (paper §III Phase 1 / §VI-A) and a 3-D
//! anatomical phantom for the adaptive-radiotherapy example.

pub mod phantom;
pub mod synth;

/// The four IVIM parameters, in the canonical sub-network order shared
/// with the Python layout (`ivim.SUBNETS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Param {
    /// Diffusion coefficient D (Brownian motion of water), mm^2/s.
    D,
    /// Pseudo-diffusion D* (perfusion / blood flow), mm^2/s.
    DStar,
    /// Perfusion fraction f.
    F,
    /// Normalised S(b=0).
    S0,
}

impl Param {
    pub const ALL: [Param; 4] = [Param::D, Param::DStar, Param::F, Param::S0];

    /// Canonical lowercase name (matches the manifest's subnet names).
    pub fn name(self) -> &'static str {
        match self {
            Param::D => "d",
            Param::DStar => "dstar",
            Param::F => "f",
            Param::S0 => "s0",
        }
    }

    /// Clinical range (min, max) — must match `python/compile/ivim.py`.
    pub fn range(self) -> (f64, f64) {
        match self {
            Param::D => (0.0, 0.005),
            Param::DStar => (0.005, 0.2),
            Param::F => (0.0, 0.7),
            Param::S0 => (0.8, 1.2),
        }
    }

    /// The conversion function C(.) of the paper (Fig. 2): map a sigmoid
    /// output in (0,1) into the clinical range.
    pub fn convert(self, sigmoid: f64) -> f64 {
        let (lo, hi) = self.range();
        lo + sigmoid * (hi - lo)
    }

    pub fn index(self) -> usize {
        match self {
            Param::D => 0,
            Param::DStar => 1,
            Param::F => 2,
            Param::S0 => 3,
        }
    }
}

/// A single voxel's ground-truth IVIM parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IvimParams {
    pub d: f64,
    pub dstar: f64,
    pub f: f64,
    pub s0: f64,
}

impl IvimParams {
    pub fn get(&self, p: Param) -> f64 {
        match p {
            Param::D => self.d,
            Param::DStar => self.dstar,
            Param::F => self.f,
            Param::S0 => self.s0,
        }
    }
}

/// Paper eq. (1): `S(b) = S0 * (f * exp(-b D*) + (1-f) * exp(-b D))`.
#[inline]
pub fn signal(b: f64, p: &IvimParams) -> f64 {
    p.s0 * (p.f * (-b * p.dstar).exp() + (1.0 - p.f) * (-b * p.d).exp())
}

/// Evaluate eq. (1) over a b-value protocol.
pub fn signal_curve(bvals: &[f64], p: &IvimParams) -> Vec<f64> {
    bvals.iter().map(|&b| signal(b, p)).collect()
}

/// The evaluation SNR grid from the paper (§VI-A).
pub const PAPER_SNRS: [f64; 5] = [5.0, 15.0, 20.0, 30.0, 50.0];

/// 11-point clinical protocol for the `tiny` variant (s/mm^2).
pub fn bvalues_tiny() -> Vec<f64> {
    vec![0.0, 5.0, 10.0, 20.0, 30.0, 40.0, 60.0, 150.0, 300.0, 500.0, 800.0]
}

/// 104-acquisition protocol shaped like the pancreatic dataset [43]-[45]
/// (must match `python/compile/ivim.py::bvalues_paper`).
pub fn bvalues_paper() -> Vec<f64> {
    let shells = [
        0.0, 10.0, 20.0, 30.0, 40.0, 50.0, 75.0, 100.0, 150.0, 200.0, 300.0, 400.0, 500.0,
        600.0, 700.0, 800.0,
    ];
    let reps = [8, 8, 8, 8, 8, 8, 6, 6, 6, 6, 6, 6, 5, 5, 5, 5];
    let mut out = Vec::with_capacity(104);
    for (b, r) in shells.iter().zip(reps.iter()) {
        for _ in 0..*r {
            out.push(*b);
        }
    }
    assert_eq!(out.len(), 104);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> IvimParams {
        IvimParams {
            d: 0.002,
            dstar: 0.05,
            f: 0.3,
            s0: 1.1,
        }
    }

    #[test]
    fn signal_at_b0_is_s0() {
        assert!((signal(0.0, &p()) - 1.1).abs() < 1e-12);
    }

    #[test]
    fn signal_monotone_decreasing() {
        let c = signal_curve(&bvalues_tiny(), &p());
        for w in c.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn biexponential_limits() {
        let mut q = p();
        q.f = 0.0;
        q.s0 = 1.0;
        for &b in &[0.0, 100.0, 500.0] {
            assert!((signal(b, &q) - (-b * q.d).exp()).abs() < 1e-12);
        }
        q.f = 1.0;
        for &b in &[0.0, 100.0, 500.0] {
            assert!((signal(b, &q) - (-b * q.dstar).exp()).abs() < 1e-12);
        }
    }

    #[test]
    fn protocols_match_python() {
        assert_eq!(bvalues_tiny().len(), 11);
        let bp = bvalues_paper();
        assert_eq!(bp.len(), 104);
        assert_eq!(bp[0], 0.0);
        assert_eq!(*bp.last().unwrap(), 800.0);
        assert!(bp.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn conversion_maps_ranges() {
        for prm in Param::ALL {
            let (lo, hi) = prm.range();
            assert!((prm.convert(0.0) - lo).abs() < 1e-12);
            assert!((prm.convert(1.0) - hi).abs() < 1e-12);
            let mid = prm.convert(0.5);
            assert!(mid > lo && mid < hi);
        }
    }

    #[test]
    fn param_names_match_manifest_order() {
        let names: Vec<&str> = Param::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names, vec!["d", "dstar", "f", "s0"]);
    }
}
