//! Volume-scale streaming workloads (ROADMAP direction #2).
//!
//! A clinical IVIM acquisition is a full 3D multi-slice volume —
//! millions of voxels per patient — not the paper-scale flat batches the
//! synth generator produces. This module opens that workload *without*
//! ever materialising a volume's f32 signal block: [`SliceStream`]
//! generates one z-slice at a time into caller-owned scratch, so peak
//! signal memory is `slice_voxels × nb` floats regardless of depth.
//!
//! The streaming contract (pinned by tests here and in `ivim::synth`):
//! a `SliceStream` over `VolumeSpec { dim: (x, y, z), .. }` drives the
//! same sequential `Pcg32::new(seed)` through the same per-voxel
//! generator (`ivim::synth::synth_voxel_into`) as
//! `synth_dataset(x*y*z, bvals, snr, seed)` — so the streamed volume is
//! **bit-identical** to the batch dataset at the same seed, voxel `v` of
//! slice `z` mapping to flat index `z * slice_voxels + v`. That identity
//! is what lets `experiments::fig67` re-express an SNR point over the
//! streaming path and assert equality against the batch sweep.
//!
//! Submodules: [`scenario`] (SNR × protocol × corruption grid) and
//! [`stream`] (the coordinator-backed streaming driver with bounded
//! in-flight depth and incremental map assembly).

pub mod scenario;
pub mod stream;

use crate::ivim::synth::{b0_indices, synth_voxel_into};
use crate::ivim::IvimParams;
use crate::util::rng::Pcg32;

/// Geometry + acquisition protocol of one synthetic volume.
#[derive(Debug, Clone)]
pub struct VolumeSpec {
    /// (x, y, z) — x·y voxels per slice, z slices.
    pub dim: (usize, usize, usize),
    /// b-value protocol (one acquisition per entry).
    pub bvals: Vec<f64>,
    pub snr: f64,
    pub seed: u64,
}

impl VolumeSpec {
    pub fn n_voxels(&self) -> usize {
        self.dim.0 * self.dim.1 * self.dim.2
    }
    /// Voxels per z-slice — the streaming chunk size.
    pub fn slice_voxels(&self) -> usize {
        self.dim.0 * self.dim.1
    }
    pub fn slices(&self) -> usize {
        self.dim.2
    }
    /// Flat (row-major, z-major) voxel index of `(slice z, in-slice v)`;
    /// matches `metrics::maps::VolumeMap` layout and `synth_dataset`
    /// generation order.
    pub fn flat_index(&self, z: usize, v: usize) -> usize {
        z * self.slice_voxels() + v
    }
}

/// Chunked slice generator: yields one z-slice of normalised signals +
/// ground truth per call, never holding more than one slice of f32
/// signal data. Bit-identical to `synth_dataset` at the same seed (see
/// module docs).
pub struct SliceStream<'a> {
    spec: &'a VolumeSpec,
    rng: Pcg32,
    b0_idx: Vec<usize>,
    noisy: Vec<f64>,
    next_z: usize,
}

impl<'a> SliceStream<'a> {
    pub fn new(spec: &'a VolumeSpec) -> Self {
        SliceStream {
            spec,
            rng: Pcg32::new(spec.seed),
            b0_idx: b0_indices(&spec.bvals),
            noisy: Vec::with_capacity(spec.bvals.len()),
            next_z: 0,
        }
    }

    /// Index of the slice the next `next_into` call will produce.
    pub fn next_z(&self) -> usize {
        self.next_z
    }

    pub fn remaining(&self) -> usize {
        self.spec.slices() - self.next_z
    }

    /// Generate the next slice into caller-owned buffers (cleared first,
    /// then filled with `slice_voxels` rows of `nb` signals and as many
    /// truth tuples). Returns the slice index, or `None` when the
    /// volume is exhausted. The buffers reach steady-state capacity
    /// after the first call — no per-slice allocation afterwards.
    pub fn next_into(
        &mut self,
        signals: &mut Vec<f32>,
        truth: &mut Vec<IvimParams>,
    ) -> Option<usize> {
        if self.next_z >= self.spec.slices() {
            return None;
        }
        let z = self.next_z;
        let nb = self.spec.bvals.len();
        let nv = self.spec.slice_voxels();
        signals.clear();
        signals.resize(nv * nb, 0.0);
        truth.clear();
        for v in 0..nv {
            let row = &mut signals[v * nb..(v + 1) * nb];
            truth.push(synth_voxel_into(
                &mut self.rng,
                &self.spec.bvals,
                &self.b0_idx,
                self.spec.snr,
                &mut self.noisy,
                row,
            ));
        }
        self.next_z += 1;
        Some(z)
    }
}

/// Parse a `--dim X,Y,Z` argument.
pub fn parse_dim(s: &str) -> anyhow::Result<(usize, usize, usize)> {
    let parts: Vec<&str> = s.split(',').collect();
    if parts.len() != 3 {
        anyhow::bail!("--dim expects X,Y,Z (got {s:?})");
    }
    let p = |t: &str| -> anyhow::Result<usize> {
        let v: usize = t
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("bad dim component {t:?}: {e}"))?;
        if v == 0 {
            anyhow::bail!("dim components must be > 0 (got {t:?})");
        }
        Ok(v)
    };
    Ok((p(parts[0])?, p(parts[1])?, p(parts[2])?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::bvalues_tiny;
    use crate::ivim::synth::synth_dataset;

    fn spec(dim: (usize, usize, usize)) -> VolumeSpec {
        VolumeSpec {
            dim,
            bvals: bvalues_tiny(),
            snr: 20.0,
            seed: 9,
        }
    }

    #[test]
    fn slice_stream_is_bit_identical_to_batch_dataset() {
        let s = spec((3, 4, 5));
        let ds = synth_dataset(s.n_voxels(), &s.bvals, s.snr, s.seed);
        let mut stream = SliceStream::new(&s);
        let mut signals = Vec::new();
        let mut truth = Vec::new();
        let nb = s.bvals.len();
        let nv = s.slice_voxels();
        let mut seen = 0;
        while let Some(z) = stream.next_into(&mut signals, &mut truth) {
            assert_eq!(signals.len(), nv * nb);
            assert_eq!(truth.len(), nv);
            for v in 0..nv {
                let flat = s.flat_index(z, v);
                assert_eq!(
                    &signals[v * nb..(v + 1) * nb],
                    ds.voxel(flat),
                    "slice {z} voxel {v}"
                );
                assert_eq!(truth[v], ds.truth[flat]);
            }
            seen += 1;
        }
        assert_eq!(seen, s.slices());
        assert!(stream.next_into(&mut signals, &mut truth).is_none());
    }

    #[test]
    fn buffers_hold_exactly_one_slice_and_stop_growing() {
        let s = spec((4, 4, 6));
        let mut stream = SliceStream::new(&s);
        let mut signals = Vec::new();
        let mut truth = Vec::new();
        stream.next_into(&mut signals, &mut truth).unwrap();
        let sig_cap = signals.capacity();
        let truth_cap = truth.capacity();
        assert_eq!(signals.len(), s.slice_voxels() * s.bvals.len());
        while stream.next_into(&mut signals, &mut truth).is_some() {}
        // Steady state: reused scratch, zero growth after the first slice.
        assert_eq!(signals.capacity(), sig_cap);
        assert_eq!(truth.capacity(), truth_cap);
    }

    #[test]
    fn remaining_and_next_z_track_progress() {
        let s = spec((2, 2, 3));
        let mut stream = SliceStream::new(&s);
        let (mut sig, mut tr) = (Vec::new(), Vec::new());
        assert_eq!(stream.next_z(), 0);
        assert_eq!(stream.remaining(), 3);
        stream.next_into(&mut sig, &mut tr);
        assert_eq!(stream.next_z(), 1);
        assert_eq!(stream.remaining(), 2);
    }

    #[test]
    fn parse_dim_accepts_and_rejects() {
        assert_eq!(parse_dim("16,16,8").unwrap(), (16, 16, 8));
        assert_eq!(parse_dim(" 4 , 5 , 6 ").unwrap(), (4, 5, 6));
        assert!(parse_dim("16,16").is_err());
        assert!(parse_dim("16,16,0").is_err());
        assert!(parse_dim("a,b,c").is_err());
    }
}
