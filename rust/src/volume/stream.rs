//! The streaming volume driver: pump a [`SliceStream`] through a
//! running [`Coordinator`] with bounded in-flight depth, assembling
//! per-volume parameter and uncertainty maps incrementally as voxel
//! responses complete **out of order**.
//!
//! Memory contract: at any instant the driver holds one slice of f32
//! signal scratch (the `SliceStream` buffers), at most
//! `slices_in_flight` slices' worth of response receivers, and the
//! output maps (f64 per voxel per map — the deliverable, not a
//! transient). Signal buffers travel through the coordinator as pooled
//! leases, so the lease slab's `created()` high-water mark stays flat
//! after warm-up no matter how many slices the volume has — the
//! capacity-signature test in `tests/volume_stream.rs` pins this.
//!
//! Backpressure: a slice is admitted only when (a) fewer than
//! `slices_in_flight` slices are outstanding, (b) the coordinator's
//! pending queue has room for the whole slice, and (c) no shard deque
//! is deeper than `max_deque_depth` batches. When any gate is closed
//! the driver drains completions instead (counted in
//! `ServingMetrics::stream_stalls`).
//!
//! Single-producer invariant: the admission gate is check-then-submit
//! with no lock between the check and the submits, so it only
//! guarantees "never rejects" when exactly one driver feeds the
//! coordinator.  Two concurrent `stream_volume` calls on the same
//! coordinator could both observe queue room and jointly overshoot it,
//! turning backpressure stalls into hard `submit_leased` rejections.
//! Rather than serialise every probe, the driver takes the
//! coordinator's [`StreamDriverGuard`](crate::coordinator::StreamDriverGuard)
//! for the duration of the volume: a second concurrent driver fails
//! fast with an explicit error instead of corrupting the accounting.
//! Run volumes sequentially (as `repro volume` does) or give each its
//! own coordinator.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::time::Instant;

use crate::coordinator::{Coordinator, VoxelResponse};
use crate::ivim::Param;
use crate::metrics::maps::VolumeMap;
use crate::util::rng::Pcg32;
use crate::util::stats;

use super::scenario::Corruption;
use super::{SliceStream, VolumeSpec};

/// RNG stream id for corruption draws — separate from the generation
/// stream so `Corruption::Clean` volumes stay bit-identical to
/// `synth_dataset` at the same seed.
const CORRUPTION_SEQ: u64 = 0xC0;

#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Maximum slices with outstanding responses (the in-flight cap).
    pub slices_in_flight: usize,
    /// Stall admission while any shard's deque holds more than this
    /// many batches (the `deque_depth`-keyed gate).
    pub max_deque_depth: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            slices_in_flight: 2,
            max_deque_depth: usize::MAX,
        }
    }
}

/// Per-parameter map bundle (indexed by `Param::index()` in
/// [`StreamedVolume`]).
pub struct ParamMaps {
    pub mean: VolumeMap,
    pub std: VolumeMap,
    pub relative: VolumeMap,
    pub truth: VolumeMap,
}

/// A fully assembled streamed volume: four map bundles plus the run's
/// performance counters.
pub struct StreamedVolume {
    pub dim: (usize, usize, usize),
    pub maps: [ParamMaps; 4],
    /// Voxels the coordinator flagged confident.
    pub confident_voxels: usize,
    pub stats: StreamStats,
}

impl StreamedVolume {
    pub fn param(&self, p: Param) -> &ParamMaps {
        &self.maps[p.index()]
    }
    pub fn n_voxels(&self) -> usize {
        self.dim.0 * self.dim.1 * self.dim.2
    }
}

/// Performance counters for one streamed volume.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub voxels: usize,
    pub slices: usize,
    pub elapsed_s: f64,
    pub voxels_per_s: f64,
    /// Highest number of slices simultaneously outstanding.
    pub max_inflight_slices: usize,
    /// Highest pending-queue depth observed at admission points.
    pub max_queue_depth: usize,
    /// Deepest per-shard deque observed at slice boundaries.
    pub max_deque_depth: usize,
    /// Backpressure events (drain-before-admit).
    pub stalls: u64,
    /// Lease-slab allocations at the end of the run (`created()`).
    pub lease_high_water: usize,
}

/// The figure-level summary of a streamed volume, computed from the
/// assembled maps exactly as `metrics::{rmse_by_param,
/// mean_relative_uncertainty, calibration}` compute it from batch
/// outputs — same per-voxel values in the same voxel order, so the two
/// paths are bit-identical at the same seed.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamedMetrics {
    pub rmse: [f64; 4],
    pub uncertainty: [f64; 4],
    pub calibration: [f64; 4],
}

/// Compute RMSE / mean relative uncertainty / calibration per parameter
/// from assembled maps. Map data is voxel-ordered (z-major, then y,
/// then x — the generation order), so the vectors fed to `util::stats`
/// match the batch path's iteration order element for element.
pub fn volume_metrics(vol: &StreamedVolume) -> StreamedMetrics {
    let mut rmse = [0.0; 4];
    let mut uncertainty = [0.0; 4];
    let mut calibration = [0.0; 4];
    for (i, _p) in Param::ALL.iter().enumerate() {
        let m = &vol.maps[i];
        rmse[i] = stats::rmse(&m.mean.data, &m.truth.data);
        uncertainty[i] = stats::mean(&m.relative.data);
        let errs: Vec<f64> = m
            .mean
            .data
            .iter()
            .zip(m.truth.data.iter())
            .map(|(&pred, &t)| (pred - t).abs())
            .collect();
        calibration[i] = stats::pearson(&errs, &m.std.data);
    }
    StreamedMetrics {
        rmse,
        uncertainty,
        calibration,
    }
}

/// One slice's outstanding responses.
struct SliceInFlight {
    z: usize,
    /// One receiver per submitted voxel; `None` once received.
    pending: Vec<Option<Receiver<VoxelResponse>>>,
    received: usize,
    submitted: usize,
}

impl SliceInFlight {
    fn complete(&self) -> bool {
        self.received == self.submitted && self.pending.len() == self.submitted
    }
}

/// Stream one volume through the coordinator and assemble its maps.
///
/// The coordinator must have been built with `nb == spec.bvals.len()`.
/// Responses are written into the maps by flat voxel id as they arrive,
/// so completion order is irrelevant to the result.
///
/// Holds the coordinator's stream-driver guard for the whole run: a
/// second concurrent `stream_volume` on the same coordinator errors
/// immediately (see the module docs' single-producer invariant).
pub fn stream_volume(
    coord: &Coordinator,
    spec: &VolumeSpec,
    corruption: Corruption,
    cfg: &StreamConfig,
) -> anyhow::Result<StreamedVolume> {
    // Acquired before any probe or submit; released on every exit path
    // (including errors) by Drop.
    let _driver = coord.stream_driver_guard()?;
    let nb = spec.bvals.len();
    {
        let probe = coord.lease();
        anyhow::ensure!(
            probe.signals().len() == nb,
            "coordinator nb {} != protocol nb {}",
            probe.signals().len(),
            nb
        );
    }
    let nv = spec.slice_voxels();
    let cap = cfg.slices_in_flight.max(1);
    let mut maps: [ParamMaps; 4] = std::array::from_fn(|_| ParamMaps {
        mean: VolumeMap::new(spec.dim),
        std: VolumeMap::new(spec.dim),
        relative: VolumeMap::new(spec.dim),
        truth: VolumeMap::new(spec.dim),
    });
    let mut confident_voxels = 0usize;
    let mut stats_out = StreamStats {
        slices: spec.slices(),
        voxels: spec.n_voxels(),
        ..Default::default()
    };

    let mut stream = SliceStream::new(spec);
    let mut crng = Pcg32::with_stream(spec.seed, CORRUPTION_SEQ);
    let mut signals: Vec<f32> = Vec::new();
    let mut truth = Vec::new();
    let mut in_flight: Vec<SliceInFlight> = Vec::new();

    // Write one response into the maps.
    let absorb = |resp: VoxelResponse,
                  maps: &mut [ParamMaps; 4],
                  confident: &mut usize| {
        let id = resp.id as usize;
        let (z, v) = (id / nv, id % nv);
        for (i, p) in Param::ALL.iter().enumerate() {
            let e = resp.report.get(*p);
            maps[i].mean.set_flat(z, v, e.mean);
            maps[i].std.set_flat(z, v, e.std);
            maps[i].relative.set_flat(z, v, e.relative);
        }
        if resp.report.confident {
            *confident += 1;
        }
    };

    // Non-blocking sweep over every in-flight slice; retains only
    // incomplete slices. Returns how many responses were absorbed.
    let drain_ready = |in_flight: &mut Vec<SliceInFlight>,
                       maps: &mut [ParamMaps; 4],
                       confident: &mut usize|
     -> anyhow::Result<usize> {
        let mut absorbed = 0usize;
        for slice in in_flight.iter_mut() {
            for slot in slice.pending.iter_mut() {
                if let Some(rx) = slot {
                    match rx.try_recv() {
                        Ok(resp) => {
                            absorb(resp, maps, confident);
                            *slot = None;
                            slice.received += 1;
                            absorbed += 1;
                        }
                        Err(TryRecvError::Empty) => {}
                        Err(TryRecvError::Disconnected) => {
                            anyhow::bail!(
                                "coordinator dropped a voxel of slice {}",
                                slice.z
                            );
                        }
                    }
                }
            }
        }
        in_flight.retain(|s| !s.complete());
        Ok(absorbed)
    };

    // Blocking drain: wait for the oldest outstanding voxel.
    let drain_one_blocking = |in_flight: &mut Vec<SliceInFlight>,
                              maps: &mut [ParamMaps; 4],
                              confident: &mut usize|
     -> anyhow::Result<()> {
        if let Some(slice) = in_flight.first_mut() {
            if let Some(slot) = slice.pending.iter_mut().find(|s| s.is_some()) {
                let rx = slot.take().expect("just matched Some");
                let resp = rx.recv().map_err(|_| {
                    anyhow::anyhow!("coordinator dropped a voxel of slice {}", slice.z)
                })?;
                absorb(resp, maps, confident);
                slice.received += 1;
            }
        }
        in_flight.retain(|s| !s.complete());
        Ok(())
    };

    let start = Instant::now();
    while let Some(z) = stream.next_into(&mut signals, &mut truth) {
        // Ground truth is known at generation time — write it now.
        for (v, t) in truth.iter().enumerate() {
            for (i, p) in Param::ALL.iter().enumerate() {
                maps[i].truth.set_flat(z, v, t.get(*p));
            }
        }
        corruption.apply(&mut crng, &mut signals, nb);

        // Admission gates: in-flight cap, queue room, deque depth.
        loop {
            drain_ready(&mut in_flight, &mut maps, &mut confident_voxels)?;
            stats_out.max_queue_depth = stats_out.max_queue_depth.max(coord.queue_depth());
            let snap = coord.snapshot();
            let deepest = snap
                .per_shard
                .iter()
                .map(|s| s.deque_depth)
                .max()
                .unwrap_or(0);
            stats_out.max_deque_depth = stats_out.max_deque_depth.max(deepest);
            let slice_fits = coord.queue_depth() + nv <= coord.queue_capacity()
                || in_flight.is_empty();
            if in_flight.len() < cap && slice_fits && deepest <= cfg.max_deque_depth {
                break;
            }
            stats_out.stalls += 1;
            // relaxed: the streaming counters here and below
            // (stream_stalls, slices_ingested, volumes_completed) are
            // monotonic telemetry; readers snapshot totals only.
            coord.metrics().stream_stalls.fetch_add(1, Ordering::Relaxed);
            drain_one_blocking(&mut in_flight, &mut maps, &mut confident_voxels)?;
        }

        let mut slice = SliceInFlight {
            z,
            pending: Vec::with_capacity(nv),
            received: 0,
            submitted: 0,
        };
        for v in 0..nv {
            let id = spec.flat_index(z, v) as u64;
            loop {
                let mut lease = coord.lease();
                lease.copy_from(&signals[v * nb..(v + 1) * nb]);
                match coord.submit_leased(id, lease) {
                    Ok(rx) => {
                        slice.pending.push(Some(rx));
                        slice.submitted += 1;
                        break;
                    }
                    Err(_) => {
                        // Queue full mid-slice (capacity < slice size, or
                        // racing drains): free a slot by draining.
                        stats_out.stalls += 1;
                        coord
                            .metrics()
                            .stream_stalls
                            .fetch_add(1, Ordering::Relaxed);
                        if in_flight.is_empty() && slice.pending.iter().all(|s| s.is_none()) {
                            anyhow::bail!(
                                "queue capacity {} cannot absorb any voxel",
                                coord.queue_capacity()
                            );
                        }
                        if drain_ready(&mut in_flight, &mut maps, &mut confident_voxels)? == 0 {
                            // Nothing ready in older slices — wait on this
                            // slice's own oldest outstanding voxel.
                            if in_flight.is_empty() {
                                if let Some(slot) =
                                    slice.pending.iter_mut().find(|s| s.is_some())
                                {
                                    let rx = slot.take().expect("just matched Some");
                                    let resp = rx.recv().map_err(|_| {
                                        anyhow::anyhow!(
                                            "coordinator dropped a voxel of slice {z}"
                                        )
                                    })?;
                                    absorb(resp, &mut maps, &mut confident_voxels);
                                    slice.received += 1;
                                }
                            } else {
                                drain_one_blocking(
                                    &mut in_flight,
                                    &mut maps,
                                    &mut confident_voxels,
                                )?;
                            }
                        }
                    }
                }
            }
        }
        in_flight.push(slice);
        stats_out.max_inflight_slices = stats_out.max_inflight_slices.max(in_flight.len());
        coord
            .metrics()
            .slices_ingested
            .fetch_add(1, Ordering::Relaxed);
    }

    // Tail drain: everything submitted, wait out the stragglers.
    while !in_flight.is_empty() {
        drain_one_blocking(&mut in_flight, &mut maps, &mut confident_voxels)?;
    }
    let elapsed = start.elapsed().as_secs_f64();
    stats_out.elapsed_s = elapsed;
    stats_out.voxels_per_s = if elapsed > 0.0 {
        stats_out.voxels as f64 / elapsed
    } else {
        0.0
    };
    stats_out.lease_high_water = coord.lease_high_water();
    coord
        .metrics()
        .volumes_completed
        .fetch_add(1, Ordering::Relaxed);

    Ok(StreamedVolume {
        dim: spec.dim,
        maps,
        confident_voxels,
        stats: stats_out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_volume(dim: (usize, usize, usize)) -> StreamedVolume {
        let n = dim.0 * dim.1 * dim.2;
        let mut maps: [ParamMaps; 4] = std::array::from_fn(|_| ParamMaps {
            mean: VolumeMap::new(dim),
            std: VolumeMap::new(dim),
            relative: VolumeMap::new(dim),
            truth: VolumeMap::new(dim),
        });
        for i in 0..4 {
            for v in 0..n {
                // mean tracks truth with a voxel-dependent error; std
                // tracks that error so calibration is perfect.
                let t = 1.0 + v as f64;
                let e = 0.1 * v as f64;
                maps[i].truth.data[v] = t;
                maps[i].mean.data[v] = t + e;
                maps[i].std.data[v] = e;
                maps[i].relative.data[v] = 0.25;
            }
        }
        StreamedVolume {
            dim,
            maps,
            confident_voxels: 0,
            stats: StreamStats::default(),
        }
    }

    #[test]
    fn volume_metrics_match_hand_computation() {
        let vol = flat_volume((2, 2, 2));
        let m = volume_metrics(&vol);
        // errors are 0, .1, .2, ..., .7 → rmse = sqrt(mean(e^2))
        let want_rmse =
            ((0..8).map(|v| (0.1 * v as f64).powi(2)).sum::<f64>() / 8.0).sqrt();
        for i in 0..4 {
            assert!((m.rmse[i] - want_rmse).abs() < 1e-12);
            assert!((m.uncertainty[i] - 0.25).abs() < 1e-15);
            // |err| == std exactly → perfect calibration
            assert!((m.calibration[i] - 1.0).abs() < 1e-9, "{}", m.calibration[i]);
        }
    }

    #[test]
    fn param_accessor_indexes_by_param() {
        let mut vol = flat_volume((2, 1, 1));
        vol.maps[Param::F.index()].mean.data[0] = 42.0;
        assert_eq!(vol.param(Param::F).mean.data[0], 42.0);
        assert_eq!(vol.n_voxels(), 2);
    }
}
