//! Clinical scenario sweep: SNR × b-value protocol × corruption.
//!
//! Voxel-wise IVIM UQ frameworks (Casali et al., arXiv 2508.04588)
//! evaluate uncertainty under acquisition sweeps — SNR levels, b-value
//! protocols, and noise/motion corruption. This module generates that
//! grid as `Scenario` values a streaming driver can run one volume at a
//! time.
//!
//! Corruptions are applied to the *normalised* signal slice, after
//! generation, from a corruption RNG stream that is separate from the
//! generation RNG — so `Corruption::Clean` consumes no randomness and a
//! clean streamed volume stays bit-identical to the batch dataset at
//! the same seed (the contract `experiments::fig67` asserts).

use crate::util::rng::Pcg32;

/// Per-slice signal corruption, applied post-normalisation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Corruption {
    /// No corruption; consumes no RNG draws.
    Clean,
    /// Additive Gaussian noise of the given std on the normalised
    /// signal (models scanner/thermal noise beyond the SNR model).
    ExtraNoise { std: f64 },
    /// Bulk in-plane motion: circularly shift the slice's voxels by a
    /// per-slice random offset in `[1, max_shift]`. The truth map is
    /// NOT shifted — the misregistration between signal and truth is
    /// the artifact.
    Motion { max_shift: usize },
}

impl Corruption {
    pub fn name(&self) -> String {
        match self {
            Corruption::Clean => "clean".to_string(),
            Corruption::ExtraNoise { std } => format!("noise{std}"),
            Corruption::Motion { max_shift } => format!("motion{max_shift}"),
        }
    }

    /// Corrupt one slice of normalised signals in place.
    /// `signals` is row-major `[slice_voxels][nb]`.
    pub fn apply(&self, rng: &mut Pcg32, signals: &mut [f32], nb: usize) {
        match *self {
            Corruption::Clean => {}
            Corruption::ExtraNoise { std } => {
                for s in signals.iter_mut() {
                    *s = (*s as f64 + std * rng.normal()) as f32;
                }
            }
            Corruption::Motion { max_shift } => {
                if nb == 0 || signals.is_empty() || max_shift == 0 {
                    return;
                }
                let nv = signals.len() / nb;
                let shift = 1 + rng.below(max_shift.min(u32::MAX as usize) as u32) as usize;
                let shift = shift % nv.max(1);
                if shift == 0 {
                    return;
                }
                // Rotate whole voxel rows so each row stays a coherent
                // acquisition vector.
                signals.rotate_right(shift * nb);
            }
        }
    }
}

/// One cell of the sweep grid: a named (SNR, protocol, corruption)
/// combination.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub snr: f64,
    pub bvals: Vec<f64>,
    pub corruption: Corruption,
}

/// A length-preserving protocol variant: scale every b-value by a
/// factor (b = 0 rows stay 0, so normalisation still finds them). Same
/// `nb` for every variant means one engine build serves the whole grid.
fn scale_protocol(base: &[f64], factor: f64) -> Vec<f64> {
    base.iter().map(|&b| b * factor).collect()
}

/// Build the full scenario grid: for each SNR, the clinical protocol
/// plus low-b (×0.5) and high-b (×1.5) variants, crossed with the
/// given corruptions. Grid size = `snrs.len() × 3 × corruptions.len()`.
pub fn scenario_grid(base_bvals: &[f64], snrs: &[f64], corruptions: &[Corruption]) -> Vec<Scenario> {
    let protocols: [(&str, f64); 3] = [("clinical", 1.0), ("lowb", 0.5), ("highb", 1.5)];
    let mut out = Vec::with_capacity(snrs.len() * protocols.len() * corruptions.len());
    for &snr in snrs {
        for &(pname, factor) in &protocols {
            let bvals = scale_protocol(base_bvals, factor);
            for &c in corruptions {
                out.push(Scenario {
                    name: format!("snr{snr}_{pname}_{}", c.name()),
                    snr,
                    bvals: bvals.clone(),
                    corruption: c,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::bvalues_tiny;

    #[test]
    fn clean_consumes_no_rng_and_changes_nothing() {
        let mut rng = Pcg32::new(7);
        let mut twin = Pcg32::new(7);
        let mut sig = vec![0.5f32; 12];
        let before = sig.clone();
        Corruption::Clean.apply(&mut rng, &mut sig, 3);
        assert_eq!(sig, before);
        // RNG untouched: next draw matches the twin's first draw.
        assert_eq!(rng.normal(), twin.normal());
    }

    #[test]
    fn extra_noise_perturbs_deterministically() {
        let base = vec![1.0f32; 8];
        let mut a = base.clone();
        let mut b = base.clone();
        Corruption::ExtraNoise { std: 0.1 }.apply(&mut Pcg32::new(3), &mut a, 4);
        Corruption::ExtraNoise { std: 0.1 }.apply(&mut Pcg32::new(3), &mut b, 4);
        assert_eq!(a, b, "same seed, same corruption");
        assert_ne!(a, base, "noise must actually perturb");
    }

    #[test]
    fn motion_rotates_whole_rows() {
        let nb = 3;
        // 4 voxels with distinct row signatures.
        let mut sig: Vec<f32> = (0..4 * nb).map(|i| (i / nb) as f32).collect();
        Corruption::Motion { max_shift: 2 }.apply(&mut Pcg32::new(1), &mut sig, nb);
        // Every row still holds one voxel's (constant) signature.
        for v in 0..4 {
            let row = &sig[v * nb..(v + 1) * nb];
            assert!(row.iter().all(|&x| x == row[0]), "row {v} torn: {row:?}");
        }
        // It's a permutation of the original voxel ids.
        let mut ids: Vec<i32> = (0..4).map(|v| sig[v * nb] as i32).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn grid_covers_the_cross_product() {
        let b = bvalues_tiny();
        let grid = scenario_grid(
            &b,
            &[5.0, 20.0],
            &[Corruption::Clean, Corruption::ExtraNoise { std: 0.05 }],
        );
        assert_eq!(grid.len(), 2 * 3 * 2);
        // Every protocol keeps the base length (one engine serves all).
        assert!(grid.iter().all(|s| s.bvals.len() == b.len()));
        // Names are unique.
        let mut names: Vec<&str> = grid.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), grid.len());
        // b=0 rows survive scaling (normalisation depends on them).
        for s in &grid {
            assert_eq!(
                s.bvals.iter().filter(|&&x| x == 0.0).count(),
                b.iter().filter(|&&x| x == 0.0).count()
            );
        }
    }
}
