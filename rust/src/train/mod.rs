//! Rust-driven training: the Adam train-step AOT executable looped from
//! Rust over streaming synthetic batches (paper §IV training protocol,
//! end-to-end validation of the full stack — EXPERIMENTS.md logs the
//! loss curve).
//!
//! Python authored the computation once (`python/compile/aot.py`); this
//! module owns the loop, the data, early stopping and checkpointing.

use crate::ivim::synth::synth_dataset;
use crate::model::{Manifest, Weights};
use crate::runtime::{Runtime, TrainExecutable, TrainState};
use crate::util::Timer;

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    /// SNR of the synthetic training stream (the paper trains per noise
    /// scenario; `train_multi_snr` covers the sweep).
    pub snr: f64,
    pub seed: u64,
    /// Log the loss every `log_every` steps (0 = never).
    pub log_every: usize,
    /// Stop early when the trailing-window mean loss improves by less
    /// than `early_stop_rel` relative (0 disables).
    pub early_stop_rel: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 500,
            snr: 20.0,
            seed: 1,
            log_every: 50,
            early_stop_rel: 0.0,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps_run: usize,
    pub seconds: f64,
    pub final_weights: Weights,
}

impl TrainReport {
    pub fn initial_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }
    /// Mean loss over the last `w` steps (robust final metric).
    pub fn tail_mean(&self, w: usize) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let start = n.saturating_sub(w);
        let tail = &self.losses[start..];
        tail.iter().map(|&l| l as f64).sum::<f64>() / tail.len() as f64
    }
}

/// Run the training loop.  Each step draws a fresh synthetic batch (the
/// paper's protocol: simulation is unlimited, so every batch is new
/// data — no epochs).
pub fn train(
    rt: &Runtime,
    man: &Manifest,
    cfg: &TrainConfig,
    init: Option<Weights>,
) -> anyhow::Result<TrainReport> {
    let exe = TrainExecutable::load(rt, man)?;
    let weights = match init {
        Some(w) => w,
        None => Weights::load_init(man)?,
    };
    let mut state = TrainState::fresh(weights);
    let mut losses = Vec::with_capacity(cfg.steps);
    let timer = Timer::start();
    let window = 25usize;

    for step in 0..cfg.steps {
        let ds = synth_dataset(
            man.batch_train,
            &man.bvalues,
            cfg.snr,
            cfg.seed.wrapping_add(step as u64),
        );
        let loss = exe.step(&mut state, &ds.signals)?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        losses.push(loss);

        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!("step {step}: loss {loss:.6}");
        }
        if cfg.early_stop_rel > 0.0 && losses.len() >= 2 * window {
            let prev: f64 = losses[losses.len() - 2 * window..losses.len() - window]
                .iter()
                .map(|&l| l as f64)
                .sum::<f64>()
                / window as f64;
            let cur: f64 = losses[losses.len() - window..]
                .iter()
                .map(|&l| l as f64)
                .sum::<f64>()
                / window as f64;
            if prev - cur < cfg.early_stop_rel * prev {
                break;
            }
        }
    }

    Ok(TrainReport {
        steps_run: losses.len(),
        seconds: timer.elapsed_s(),
        final_weights: state.weights,
        losses,
    })
}

/// Train one model per SNR level (the paper's per-scenario models for
/// Figs. 6/7).  Returns (snr, report) pairs.
pub fn train_multi_snr(
    rt: &Runtime,
    man: &Manifest,
    base: &TrainConfig,
    snrs: &[f64],
) -> anyhow::Result<Vec<(f64, TrainReport)>> {
    let mut out = Vec::with_capacity(snrs.len());
    for &snr in snrs {
        let cfg = TrainConfig {
            snr,
            ..base.clone()
        };
        out.push((snr, train(rt, man, &cfg, None)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::artifacts_root;

    fn tiny() -> Option<Manifest> {
        let dir = artifacts_root().join("tiny");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn loss_decreases_over_training() {
        let Some(man) = tiny() else { return };
        let Ok(rt) = Runtime::cpu() else { return };
        let cfg = TrainConfig {
            steps: 60,
            snr: 30.0,
            seed: 5,
            log_every: 0,
            early_stop_rel: 0.0,
        };
        let rep = train(&rt, &man, &cfg, None).unwrap();
        assert_eq!(rep.steps_run, 60);
        let head: f64 =
            rep.losses[..10].iter().map(|&l| l as f64).sum::<f64>() / 10.0;
        let tail = rep.tail_mean(10);
        assert!(
            tail < head * 0.9,
            "training failed to reduce loss: {head} -> {tail}"
        );
        // weights actually moved
        let init = Weights::load_init(&man).unwrap();
        assert_ne!(rep.final_weights.params, init.params);
    }

    #[test]
    fn early_stop_halts() {
        let Some(man) = tiny() else { return };
        let Ok(rt) = Runtime::cpu() else { return };
        let cfg = TrainConfig {
            steps: 400,
            snr: 50.0,
            seed: 6,
            log_every: 0,
            early_stop_rel: 0.5, // aggressive: stop as soon as gains < 50%
        };
        let rep = train(&rt, &man, &cfg, None).unwrap();
        assert!(rep.steps_run < 400, "early stop never fired");
    }

    #[test]
    fn resume_from_weights() {
        let Some(man) = tiny() else { return };
        let Ok(rt) = Runtime::cpu() else { return };
        let cfg = TrainConfig {
            steps: 10,
            snr: 20.0,
            seed: 7,
            log_every: 0,
            early_stop_rel: 0.0,
        };
        let rep1 = train(&rt, &man, &cfg, None).unwrap();
        let rep2 = train(&rt, &man, &cfg, Some(rep1.final_weights.clone())).unwrap();
        // continuing from trained weights shouldn't blow the loss up
        assert!(rep2.final_loss() <= rep1.initial_loss());
    }
}
