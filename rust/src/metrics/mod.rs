//! Evaluation metrics and report writers for the paper's experiments.
//!
//! * [`rmse_by_param`] / Fig. 6 — RMSE of predicted parameters vs ground
//!   truth per SNR level.
//! * [`calibration`] / Fig. 7 companion — does uncertainty track error?
//! * [`report`] — CSV / markdown / ASCII-plot writers used by the bench
//!   harness and the CLI.

pub mod maps;
pub mod report;

use crate::coordinator::uncertainty::UncertaintyReport;
use crate::infer::InferOutput;
use crate::ivim::synth::Dataset;
use crate::ivim::Param;
use crate::util::stats;

/// RMSE of the mean prediction vs ground truth for one parameter.
pub fn rmse_by_param(outs: &[InferOutput], ds: &Dataset, p: Param) -> f64 {
    let mut pred = Vec::with_capacity(ds.len());
    let mut truth = Vec::with_capacity(ds.len());
    let mut voxel = 0usize;
    for out in outs {
        for v in 0..out.batch {
            if voxel >= ds.len() {
                break;
            }
            pred.push(out.mean(p, v));
            truth.push(ds.truth[voxel].get(p));
            voxel += 1;
        }
    }
    stats::rmse(&pred, &truth)
}

/// RMSE of the reconstruction against the (noisy) input signals — the
/// paper's "reconstruction" series in Fig. 6.  `recons` are the raw
/// `[N][B][Nb]` planes from the executables, averaged over samples.
pub fn recon_rmse(recons: &[Vec<f32>], n_samples: usize, nb: usize, ds: &Dataset) -> f64 {
    let mut pred = Vec::new();
    let mut meas = Vec::new();
    let mut voxel = 0usize;
    for plane in recons {
        let batch = plane.len() / (n_samples * nb);
        for v in 0..batch {
            if voxel >= ds.len() {
                break;
            }
            for j in 0..nb {
                let mean_over_samples: f64 = (0..n_samples)
                    .map(|s| plane[(s * batch + v) * nb + j] as f64)
                    .sum::<f64>()
                    / n_samples as f64;
                pred.push(mean_over_samples);
                meas.push(ds.voxel(voxel)[j] as f64);
            }
            voxel += 1;
        }
    }
    stats::rmse(&pred, &meas)
}

/// Mean relative uncertainty (std/mean) for one parameter — Fig. 7's
/// series value at one SNR.  Only the first `n_voxels` rows across the
/// batches are read: the tail batch is zero-padded to the engine's batch
/// size (the `coordinator::Batcher` contract) and padding rows must
/// never leak into the metric.
pub fn mean_relative_uncertainty(outs: &[InferOutput], p: Param, n_voxels: usize) -> f64 {
    let mut vals = Vec::with_capacity(n_voxels);
    let mut voxel = 0usize;
    for out in outs {
        for v in 0..out.batch {
            if voxel >= n_voxels {
                break;
            }
            vals.push(out.relative_uncertainty(p, v));
            voxel += 1;
        }
    }
    stats::mean(&vals)
}

/// [`mean_relative_uncertainty`] averaged over all four IVIM parameters
/// — the single-scalar form the ablation, co-design flow and e2e tests
/// score datasets with (one definition, not one closure per caller).
pub fn mean_relative_uncertainty_all(outs: &[InferOutput], n_voxels: usize) -> f64 {
    Param::ALL
        .iter()
        .map(|&p| mean_relative_uncertainty(outs, p, n_voxels))
        .sum::<f64>()
        / Param::ALL.len() as f64
}

/// Calibration: Pearson correlation between per-voxel |error| and
/// per-voxel uncertainty (std).  Positive correlation = the network knows
/// when it is wrong — the qualitative requirement of §III Phase 1.
pub fn calibration(outs: &[InferOutput], ds: &Dataset, p: Param) -> f64 {
    let mut errs = Vec::new();
    let mut stds = Vec::new();
    let mut voxel = 0usize;
    for out in outs {
        for v in 0..out.batch {
            if voxel >= ds.len() {
                break;
            }
            errs.push((out.mean(p, v) - ds.truth[voxel].get(p)).abs());
            stds.push(out.std(p, v));
            voxel += 1;
        }
    }
    stats::pearson(&errs, &stds)
}

/// Fraction of voxels flagged confident by the thresholds.
pub fn confident_fraction(reports: &[UncertaintyReport]) -> f64 {
    if reports.is_empty() {
        return 0.0;
    }
    reports.iter().filter(|r| r.confident).count() as f64 / reports.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivim::IvimParams;

    fn fake_ds(n: usize, nb: usize) -> Dataset {
        Dataset {
            signals: vec![1.0; n * nb],
            truth: (0..n)
                .map(|i| IvimParams {
                    d: 0.001 + 1e-5 * i as f64,
                    dstar: 0.05,
                    f: 0.3,
                    s0: 1.0,
                })
                .collect(),
            nb,
            snr: 20.0,
        }
    }

    fn fake_out(batch: usize, dval: f32, spread: f32) -> InferOutput {
        let mut out = InferOutput::new(2, batch);
        for v in 0..batch {
            out.set(Param::D, 0, v, dval - spread);
            out.set(Param::D, 1, v, dval + spread);
            for p in [Param::DStar, Param::F, Param::S0] {
                out.set(p, 0, v, p.convert(0.5) as f32);
                out.set(p, 1, v, p.convert(0.5) as f32);
            }
        }
        out
    }

    #[test]
    fn rmse_zero_for_perfect_prediction() {
        let ds = fake_ds(4, 3);
        let mut out = InferOutput::new(2, 4);
        for v in 0..4 {
            let t = ds.truth[v].d as f32;
            out.set(Param::D, 0, v, t);
            out.set(Param::D, 1, v, t);
        }
        assert!(rmse_by_param(&[out], &ds, Param::D) < 1e-9);
    }

    #[test]
    fn rmse_positive_for_biased_prediction() {
        let ds = fake_ds(4, 3);
        let out = fake_out(4, 0.003, 0.0);
        let r = rmse_by_param(&[out], &ds, Param::D);
        assert!(r > 1e-3, "rmse {r}");
    }

    #[test]
    fn uncertainty_scales_with_spread() {
        let tight = fake_out(4, 0.003, 0.0001);
        let wide = fake_out(4, 0.003, 0.001);
        let ut = mean_relative_uncertainty(&[tight], Param::D, 4);
        let uw = mean_relative_uncertainty(&[wide], Param::D, 4);
        assert!(uw > ut * 5.0, "{uw} vs {ut}");
    }

    #[test]
    fn uncertainty_all_averages_over_params() {
        let out = fake_out(2, 0.003, 0.001);
        let want: f64 = Param::ALL
            .iter()
            .map(|&p| mean_relative_uncertainty(&[out.clone()], p, 2))
            .sum::<f64>()
            / 4.0;
        assert!((mean_relative_uncertainty_all(&[out], 2) - want).abs() < 1e-12);
    }

    /// Padding regression (ISSUE #5): rows beyond `n_voxels` — the
    /// zero-padded tail of the last batch — must not move the metric.
    #[test]
    fn uncertainty_ignores_rows_beyond_n_voxels() {
        let clean = fake_out(3, 0.003, 0.0001);
        let mut padded = fake_out(4, 0.003, 0.0001);
        // row 3 is "padding": give it a wild spread that would dominate
        padded.set(Param::D, 0, 3, 0.0001);
        padded.set(Param::D, 1, 3, 0.006);
        assert_eq!(
            mean_relative_uncertainty(&[padded], Param::D, 3),
            mean_relative_uncertainty(&[clean], Param::D, 3),
        );
    }

    #[test]
    fn calibration_positive_when_error_tracks_spread() {
        // voxel 0: low error + low spread; voxel 1: high error + spread
        let ds = fake_ds(2, 3);
        let mut out = InferOutput::new(2, 2);
        let t0 = ds.truth[0].d as f32;
        out.set(Param::D, 0, 0, t0 - 1e-5);
        out.set(Param::D, 1, 0, t0 + 1e-5);
        out.set(Param::D, 0, 1, 0.004);
        out.set(Param::D, 1, 1, 0.002);
        let c = calibration(&[out], &ds, Param::D);
        assert!(c > 0.9, "calibration {c}");
    }

    #[test]
    fn recon_rmse_zero_on_exact() {
        let ds = fake_ds(2, 3);
        // recon plane equal to the signals (1.0 everywhere)
        let plane = vec![1.0f32; 2 * 2 * 3];
        assert!(recon_rmse(&[plane], 2, 3, &ds) < 1e-9);
    }
}
