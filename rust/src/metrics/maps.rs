//! Parameter / uncertainty map export: turn per-voxel estimates back into
//! image slices a clinician (or a README) can look at.  Plain binary PGM
//! (P5) — zero dependencies, viewable everywhere.

use std::path::Path;

/// A scalar 3-D map over a phantom-shaped volume.
pub struct VolumeMap {
    pub dim: (usize, usize, usize),
    pub data: Vec<f64>,
}

impl VolumeMap {
    pub fn new(dim: (usize, usize, usize)) -> Self {
        VolumeMap {
            dim,
            data: vec![0.0; dim.0 * dim.1 * dim.2],
        }
    }

    pub fn from_values(dim: (usize, usize, usize), data: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            data.len() == dim.0 * dim.1 * dim.2,
            "volume data length {} != {}x{}x{}",
            data.len(),
            dim.0,
            dim.1,
            dim.2
        );
        Ok(VolumeMap { dim, data })
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.dim.1 + y) * self.dim.0 + x
    }

    /// Voxels per z-slice.
    #[inline]
    pub fn slice_voxels(&self) -> usize {
        self.dim.0 * self.dim.1
    }

    /// Flat index of in-slice voxel `v` (row-major over y then x) of
    /// slice `z` — the incremental-assembly address used by streaming
    /// drivers, matching `volume::VolumeSpec::flat_index`.
    #[inline]
    pub fn flat_index(&self, z: usize, v: usize) -> usize {
        z * self.slice_voxels() + v
    }

    /// Write one voxel by (slice, in-slice) address. Streaming drivers
    /// call this as responses complete out of order.
    #[inline]
    pub fn set_flat(&mut self, z: usize, v: usize, value: f64) {
        let i = self.flat_index(z, v);
        self.data[i] = value;
    }

    pub fn set(&mut self, x: usize, y: usize, z: usize, v: f64) {
        let i = self.idx(x, y, z);
        self.data[i] = v;
    }

    pub fn get(&self, x: usize, y: usize, z: usize) -> f64 {
        self.data[self.idx(x, y, z)]
    }

    /// One z-slice as a row-major `[ny][nx]` copy.
    pub fn slice_z(&self, z: usize) -> Vec<f64> {
        let (nx, ny, _) = self.dim;
        let mut out = Vec::with_capacity(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                out.push(self.get(x, y, z));
            }
        }
        out
    }

    /// Summary statistics over the map, NaN/Inf-aware: `min`/`max`/
    /// `mean` cover the finite values only; `finite` counts them.
    pub fn stats(&self) -> MapStats {
        let mut s = MapStats {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            mean: 0.0,
            finite: 0,
            total: self.data.len(),
        };
        let mut sum = 0.0;
        for &v in &self.data {
            if v.is_finite() {
                s.min = s.min.min(v);
                s.max = s.max.max(v);
                sum += v;
                s.finite += 1;
            }
        }
        if s.finite == 0 {
            s.min = 0.0;
            s.max = 0.0;
        } else {
            s.mean = sum / s.finite as f64;
        }
        s
    }

    /// Write one z-slice as an 8-bit PGM, scaled to the volume's
    /// finite min..max range. The normalisation is defined at every
    /// edge: non-finite voxels render black (0), and a constant or
    /// all-non-finite volume renders its finite voxels mid-grey (128)
    /// instead of dividing by a zero range.
    pub fn write_pgm_slice(&self, z: usize, path: &Path) -> anyhow::Result<()> {
        let (nx, ny, nz) = self.dim;
        anyhow::ensure!(z < nz, "slice {z} out of range (nz={nz})");
        let st = self.stats();
        let span = st.max - st.min;
        let mut bytes = Vec::with_capacity(64 + nx * ny);
        bytes.extend_from_slice(format!("P5\n{nx} {ny}\n255\n").as_bytes());
        for v in self.slice_z(z) {
            let g = if !v.is_finite() {
                0u8
            } else if span <= 0.0 {
                128u8
            } else {
                (255.0 * (v - st.min) / span).round().clamp(0.0, 255.0) as u8
            };
            bytes.push(g);
        }
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Write every z-slice as `<stem>_z<k>.pgm`.
    pub fn write_pgm_stack(&self, stem: &Path) -> anyhow::Result<Vec<std::path::PathBuf>> {
        let mut paths = Vec::new();
        for z in 0..self.dim.2 {
            let p = stem.with_file_name(format!(
                "{}_z{z}.pgm",
                stem.file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or("map")
            ));
            self.write_pgm_slice(z, &p)?;
            paths.push(p);
        }
        Ok(paths)
    }
}

/// NaN/Inf-aware summary of a map (see `VolumeMap::stats`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MapStats {
    /// Minimum over finite values (0.0 when none are finite).
    pub min: f64,
    /// Maximum over finite values (0.0 when none are finite).
    pub max: f64,
    /// Mean over finite values (0.0 when none are finite).
    pub mean: f64,
    /// Number of finite values.
    pub finite: usize,
    /// Total voxel count.
    pub total: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut m = VolumeMap::new((4, 3, 2));
        m.set(1, 2, 1, 0.5);
        assert_eq!(m.get(1, 2, 1), 0.5);
        assert_eq!(m.get(0, 0, 0), 0.0);
    }

    #[test]
    fn from_values_validates() {
        assert!(VolumeMap::from_values((2, 2, 2), vec![0.0; 7]).is_err());
        assert!(VolumeMap::from_values((2, 2, 2), vec![0.0; 8]).is_ok());
    }

    #[test]
    fn pgm_slice_well_formed() {
        let mut m = VolumeMap::new((8, 4, 2));
        for x in 0..8 {
            m.set(x, 0, 0, x as f64);
        }
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("t.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n8 4\n255\n"));
        assert_eq!(bytes.len(), "P5\n8 4\n255\n".len() + 32);
        // gradient row: first pixel darkest, last brightest
        let px = &bytes["P5\n8 4\n255\n".len()..];
        assert_eq!(px[0], 0);
        assert_eq!(px[7], 255);
        assert!(m.write_pgm_slice(5, &path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn constant_volume_mid_grey() {
        let m = VolumeMap::from_values((2, 2, 1), vec![3.0; 4]).unwrap();
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("c.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(*bytes.last().unwrap(), 128);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn set_flat_matches_xyz_addressing() {
        let mut m = VolumeMap::new((3, 2, 2));
        // slice 1, in-slice voxel 4 == (x=1, y=1, z=1)
        m.set_flat(1, 4, 7.5);
        assert_eq!(m.get(1, 1, 1), 7.5);
        assert_eq!(m.flat_index(1, 4), m.idx(1, 1, 1));
        assert_eq!(m.slice_voxels(), 6);
    }

    #[test]
    fn stats_ignore_non_finite() {
        let m = VolumeMap::from_values(
            (2, 2, 1),
            vec![1.0, f64::NAN, 3.0, f64::INFINITY],
        )
        .unwrap();
        let s = m.stats();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.finite, 2);
        assert_eq!(s.total, 4);
    }

    #[test]
    fn stats_of_all_non_finite_are_defined() {
        let m = VolumeMap::from_values((2, 1, 1), vec![f64::NAN, f64::NEG_INFINITY]).unwrap();
        let s = m.stats();
        assert_eq!((s.min, s.max, s.mean, s.finite), (0.0, 0.0, 0.0, 0));
    }

    #[test]
    fn pgm_with_nan_and_inf_still_normalises() {
        // NaN must not poison the range fold: the finite gradient
        // still spans 0..255 and non-finite voxels render black.
        let m = VolumeMap::from_values(
            (4, 1, 1),
            vec![0.0, f64::NAN, 2.0, f64::INFINITY],
        )
        .unwrap();
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("nan.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let px = &bytes["P5\n4 1\n255\n".len()..];
        assert_eq!(px, &[0u8, 0, 255, 0], "finite span scaled, non-finite black");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pgm_of_all_nan_volume_is_defined() {
        let m = VolumeMap::from_values((2, 1, 1), vec![f64::NAN; 2]).unwrap();
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("allnan.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let px = &bytes["P5\n2 1\n255\n".len()..];
        assert_eq!(px, &[0u8, 0], "all-NaN renders black, no div-by-zero");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pgm_constant_with_one_nan_renders_mid_grey_and_black() {
        // Finite values constant (span 0) → 128; the NaN voxel → 0.
        let m = VolumeMap::from_values((3, 1, 1), vec![5.0, f64::NAN, 5.0]).unwrap();
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("constnan.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let px = &bytes["P5\n3 1\n255\n".len()..];
        assert_eq!(px, &[128u8, 0, 128]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stack_writes_all_slices() {
        let m = VolumeMap::new((2, 2, 3));
        let dir = std::env::temp_dir().join("uivim_maps_stack");
        let paths = m.write_pgm_stack(&dir.join("unc")).unwrap();
        assert_eq!(paths.len(), 3);
        for p in paths {
            assert!(p.exists());
            std::fs::remove_file(p).ok();
        }
    }
}
