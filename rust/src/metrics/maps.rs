//! Parameter / uncertainty map export: turn per-voxel estimates back into
//! image slices a clinician (or a README) can look at.  Plain binary PGM
//! (P5) — zero dependencies, viewable everywhere.

use std::path::Path;

/// A scalar 3-D map over a phantom-shaped volume.
pub struct VolumeMap {
    pub dim: (usize, usize, usize),
    pub data: Vec<f64>,
}

impl VolumeMap {
    pub fn new(dim: (usize, usize, usize)) -> Self {
        VolumeMap {
            dim,
            data: vec![0.0; dim.0 * dim.1 * dim.2],
        }
    }

    pub fn from_values(dim: (usize, usize, usize), data: Vec<f64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            data.len() == dim.0 * dim.1 * dim.2,
            "volume data length {} != {}x{}x{}",
            data.len(),
            dim.0,
            dim.1,
            dim.2
        );
        Ok(VolumeMap { dim, data })
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.dim.1 + y) * self.dim.0 + x
    }

    pub fn set(&mut self, x: usize, y: usize, z: usize, v: f64) {
        let i = self.idx(x, y, z);
        self.data[i] = v;
    }

    pub fn get(&self, x: usize, y: usize, z: usize) -> f64 {
        self.data[self.idx(x, y, z)]
    }

    /// One z-slice as a row-major `[ny][nx]` copy.
    pub fn slice_z(&self, z: usize) -> Vec<f64> {
        let (nx, ny, _) = self.dim;
        let mut out = Vec::with_capacity(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                out.push(self.get(x, y, z));
            }
        }
        out
    }

    /// Write one z-slice as an 8-bit PGM, scaled to the volume's
    /// min..max range (constant volumes render mid-grey).
    pub fn write_pgm_slice(&self, z: usize, path: &Path) -> anyhow::Result<()> {
        let (nx, ny, nz) = self.dim;
        anyhow::ensure!(z < nz, "slice {z} out of range (nz={nz})");
        let lo = self.data.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = hi - lo;
        let mut bytes = Vec::with_capacity(64 + nx * ny);
        bytes.extend_from_slice(format!("P5\n{nx} {ny}\n255\n").as_bytes());
        for v in self.slice_z(z) {
            let g = if span <= 0.0 {
                128u8
            } else {
                (255.0 * (v - lo) / span).round().clamp(0.0, 255.0) as u8
            };
            bytes.push(g);
        }
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Write every z-slice as `<stem>_z<k>.pgm`.
    pub fn write_pgm_stack(&self, stem: &Path) -> anyhow::Result<Vec<std::path::PathBuf>> {
        let mut paths = Vec::new();
        for z in 0..self.dim.2 {
            let p = stem.with_file_name(format!(
                "{}_z{z}.pgm",
                stem.file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or("map")
            ));
            self.write_pgm_slice(z, &p)?;
            paths.push(p);
        }
        Ok(paths)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut m = VolumeMap::new((4, 3, 2));
        m.set(1, 2, 1, 0.5);
        assert_eq!(m.get(1, 2, 1), 0.5);
        assert_eq!(m.get(0, 0, 0), 0.0);
    }

    #[test]
    fn from_values_validates() {
        assert!(VolumeMap::from_values((2, 2, 2), vec![0.0; 7]).is_err());
        assert!(VolumeMap::from_values((2, 2, 2), vec![0.0; 8]).is_ok());
    }

    #[test]
    fn pgm_slice_well_formed() {
        let mut m = VolumeMap::new((8, 4, 2));
        for x in 0..8 {
            m.set(x, 0, 0, x as f64);
        }
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("t.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n8 4\n255\n"));
        assert_eq!(bytes.len(), "P5\n8 4\n255\n".len() + 32);
        // gradient row: first pixel darkest, last brightest
        let px = &bytes["P5\n8 4\n255\n".len()..];
        assert_eq!(px[0], 0);
        assert_eq!(px[7], 255);
        assert!(m.write_pgm_slice(5, &path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn constant_volume_mid_grey() {
        let m = VolumeMap::from_values((2, 2, 1), vec![3.0; 4]).unwrap();
        let dir = std::env::temp_dir().join("uivim_maps_test");
        let path = dir.join("c.pgm");
        m.write_pgm_slice(0, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(*bytes.last().unwrap(), 128);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stack_writes_all_slices() {
        let m = VolumeMap::new((2, 2, 3));
        let dir = std::env::temp_dir().join("uivim_maps_stack");
        let paths = m.write_pgm_stack(&dir.join("unc")).unwrap();
        assert_eq!(paths.len(), 3);
        for p in paths {
            assert!(p.exists());
            std::fs::remove_file(p).ok();
        }
    }
}
