//! Report writers: aligned-column tables, CSV, and ASCII line plots used
//! by the bench harness and CLI to print the paper's tables and figures.

/// A simple table builder with aligned columns.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            out.pop();
            out.pop();
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// ASCII line plot of one or more series over a shared x axis — used to
/// render Figs. 6/7/8 in the terminal.
pub fn ascii_plot(
    title: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
    height: usize,
) -> String {
    let height = height.max(4);
    let width = 64usize;
    let mut all: Vec<f64> = series.iter().flat_map(|(_, ys)| ys.clone()).collect();
    all.retain(|v| v.is_finite());
    if all.is_empty() || xs.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let ymin = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let ymax = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let yspan = (ymax - ymin).max(1e-12);
    let xmin = xs[0];
    let xmax = *xs.last().unwrap();
    let xspan = (xmax - xmin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (x, y) in xs.iter().zip(ys) {
            if !y.is_finite() {
                continue;
            }
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = height - 1
                - (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[row][col.min(width - 1)] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>10.4}")
        } else if i == height - 1 {
            format!("{ymin:>10.4}")
        } else {
            " ".repeat(10)
        };
        out.push_str(&format!("{label} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>10}  {xmin:<10.1}{:>width$.1}\n",
        "",
        xmax,
        width = width - 10
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {name}\n", marks[si % marks.len()]));
    }
    out
}

/// Write a string to a file, creating parent dirs.
pub fn write_report(path: &std::path::Path, content: &str) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment_and_csv() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["alpha".into(), "1".into()]);
        t.row(&["b".into(), "12345".into()]);
        let text = t.to_text();
        assert!(text.contains("alpha"));
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("name,value"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a"]);
        t.row(&["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn ascii_plot_renders() {
        let xs = vec![5.0, 15.0, 20.0, 30.0, 50.0];
        let ys = vec![0.5, 0.3, 0.25, 0.2, 0.1];
        let p = ascii_plot("RMSE vs SNR", &xs, &[("d", ys)], 8);
        assert!(p.contains("RMSE vs SNR"));
        assert!(p.contains('*'));
        assert!(p.lines().count() > 8);
    }

    #[test]
    fn ascii_plot_empty_data() {
        let p = ascii_plot("empty", &[], &[("s", vec![])], 8);
        assert!(p.contains("no data"));
    }
}
