//! Figs. 6 & 7: RMSE of predicted parameters and relative uncertainty
//! (std/mean) across the evaluation SNR grid {5, 15, 20, 30, 50}
//! (paper §VI-B), plus the calibration correlation the paper's Phase-1
//! uncertainty requirement implies.
//!
//! Expected shapes (the paper's headline algorithm claims):
//! * RMSE falls as evaluation SNR rises (Fig. 6);
//! * relative uncertainty falls as SNR rises — "less noise … leads to …
//!   low uncertainty (more confident)" (Fig. 7).

use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::infer::registry::{self, factory, EngineOpts};
use crate::infer::{Engine, InferOutput};
use crate::ivim::synth::{synth_dataset, Dataset};
use crate::ivim::{Param, PAPER_SNRS};
use crate::metrics;
use crate::model::{Manifest, Weights};
use crate::volume::scenario::Corruption;
use crate::volume::stream::{self, StreamConfig, StreamedVolume};
use crate::volume::VolumeSpec;

/// One SNR level's evaluation results.
#[derive(Debug, Clone)]
pub struct SnrRow {
    pub snr: f64,
    /// RMSE per parameter, `Param::ALL` order.
    pub rmse: [f64; 4],
    /// Mean relative uncertainty per parameter.
    pub uncertainty: [f64; 4],
    /// Pearson(|error|, std) per parameter.
    pub calibration: [f64; 4],
}

/// Configuration of the sweep.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Voxels per SNR level (paper: 10,000).
    pub n_voxels: usize,
    pub snrs: Vec<f64>,
    /// Registry name of the backend the sweep runs on.
    pub engine: String,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            n_voxels: 2000,
            snrs: PAPER_SNRS.to_vec(),
            engine: "native".into(),
            seed: 11,
        }
    }
}

/// Run one dataset through an engine in engine-sized batches.  The tail
/// batch is **zero-filled** up to the engine batch — the same padding
/// contract as `coordinator::Batcher` (PR 2): zeros make any padding
/// leak deterministic and obvious instead of a silent copy of a
/// neighbouring voxel.  Padded rows never reach the metrics, which read
/// only the first `ds.len()` voxels.
pub fn run_batches(engine: &mut dyn Engine, ds: &Dataset) -> anyhow::Result<Vec<InferOutput>> {
    let b = engine.batch_size();
    let nb = ds.nb;
    let mut outs = Vec::new();
    let mut i = 0;
    while i < ds.len() {
        let take = (ds.len() - i).min(b);
        let mut signals = Vec::with_capacity(b * nb);
        for v in 0..take {
            signals.extend_from_slice(ds.voxel(i + v));
        }
        signals.resize(b * nb, 0.0);
        outs.push(engine.infer_batch(&signals)?);
        i += take;
    }
    Ok(outs)
}

/// The Fig. 6 + Fig. 7 sweep with a single engine/weights pair.
pub fn snr_sweep(
    man: &Manifest,
    weights: &Weights,
    cfg: &SweepConfig,
) -> anyhow::Result<Vec<SnrRow>> {
    let mut rows = Vec::with_capacity(cfg.snrs.len());
    for (i, &snr) in cfg.snrs.iter().enumerate() {
        let ds = synth_dataset(cfg.n_voxels, &man.bvalues, snr, cfg.seed + i as u64);
        let mut engine = registry::build(&cfg.engine, man, weights, &EngineOpts::default())?;
        let outs = run_batches(engine.as_mut(), &ds)?;
        let mut rmse = [0.0; 4];
        let mut unc = [0.0; 4];
        let mut cal = [0.0; 4];
        for p in Param::ALL {
            rmse[p.index()] = metrics::rmse_by_param(&outs, &ds, p);
            unc[p.index()] = metrics::mean_relative_uncertainty(&outs, p, ds.len());
            cal[p.index()] = metrics::calibration(&outs, &ds, p);
        }
        rows.push(SnrRow {
            snr,
            rmse,
            uncertainty: unc,
            calibration: cal,
        });
    }
    Ok(rows)
}

/// One SNR point of the sweep re-expressed over the **streaming volume
/// pipeline**: the same `cfg.n_voxels` voxels, reshaped into a 3-D
/// volume of the given `dim`, streamed slice-by-slice through a sharded
/// coordinator and reassembled into maps — then reduced to the same
/// `SnrRow`. Because the slice stream drives the same sequential RNG as
/// `synth_dataset` (same seed ⇒ same voxels), per-voxel inference is
/// batch-composition-independent, and the map reduction replicates the
/// batch metrics value for value, the returned row is **bit-identical**
/// to `snr_sweep`'s row at the same index — the fig6/fig7 experiments
/// become a special case of the streaming pipeline.
pub fn snr_point_streamed(
    man: &Manifest,
    weights: &Weights,
    cfg: &SweepConfig,
    snr_index: usize,
    dim: (usize, usize, usize),
    shards: usize,
    stream_cfg: &StreamConfig,
) -> anyhow::Result<(SnrRow, StreamedVolume)> {
    anyhow::ensure!(
        dim.0 * dim.1 * dim.2 == cfg.n_voxels,
        "dim {:?} holds {} voxels, sweep expects {}",
        dim,
        dim.0 * dim.1 * dim.2,
        cfg.n_voxels
    );
    let snr = *cfg
        .snrs
        .get(snr_index)
        .ok_or_else(|| anyhow::anyhow!("snr index {snr_index} out of range"))?;
    let mut ccfg = CoordinatorConfig::sharded(man.nb, man.batch_infer, shards);
    // Bound the pending queue to a couple of slices so streaming
    // backpressure is actually exercised, not just configured.
    ccfg.batcher.queue_capacity = stream_cfg.slices_in_flight.max(1) * dim.0 * dim.1 + 1;
    ccfg.batcher.max_wait = std::time::Duration::from_millis(1);
    let coord = Coordinator::start(
        ccfg,
        factory(&cfg.engine, man.clone(), weights.clone(), EngineOpts::default())?,
    )?;
    let spec = VolumeSpec {
        dim,
        bvals: man.bvalues.clone(),
        snr,
        seed: cfg.seed + snr_index as u64,
    };
    let vol = stream::stream_volume(&coord, &spec, Corruption::Clean, stream_cfg)?;
    coord.shutdown();
    let m = stream::volume_metrics(&vol);
    Ok((
        SnrRow {
            snr,
            rmse: m.rmse,
            uncertainty: m.uncertainty,
            calibration: m.calibration,
        },
        vol,
    ))
}

/// Render the Fig. 6 table + ASCII plot.
pub fn render_fig6(rows: &[SnrRow]) -> String {
    use crate::metrics::report::{ascii_plot, Table};
    let mut t = Table::new(&["SNR", "RMSE(D)", "RMSE(D*)", "RMSE(f)", "RMSE(S0)"]);
    for r in rows {
        t.row(&[
            format!("{}", r.snr),
            format!("{:.5}", r.rmse[0]),
            format!("{:.5}", r.rmse[1]),
            format!("{:.5}", r.rmse[2]),
            format!("{:.5}", r.rmse[3]),
        ]);
    }
    let xs: Vec<f64> = rows.iter().map(|r| r.snr).collect();
    let series: Vec<(&str, Vec<f64>)> = Param::ALL
        .iter()
        .map(|&p| {
            // normalise to each parameter's range so the curves share an axis
            let (lo, hi) = p.range();
            (
                p.name(),
                rows.iter().map(|r| r.rmse[p.index()] / (hi - lo)).collect(),
            )
        })
        .collect();
    format!(
        "{}\n{}",
        t.to_text(),
        ascii_plot("Fig. 6 — normalised RMSE vs evaluation SNR", &xs, &series, 10)
    )
}

/// Render the Fig. 7 table + ASCII plot.
pub fn render_fig7(rows: &[SnrRow]) -> String {
    use crate::metrics::report::{ascii_plot, Table};
    let mut t = Table::new(&["SNR", "unc(D)", "unc(D*)", "unc(f)", "unc(S0)", "calib(D)"]);
    for r in rows {
        t.row(&[
            format!("{}", r.snr),
            format!("{:.4}", r.uncertainty[0]),
            format!("{:.4}", r.uncertainty[1]),
            format!("{:.4}", r.uncertainty[2]),
            format!("{:.4}", r.uncertainty[3]),
            format!("{:.3}", r.calibration[0]),
        ]);
    }
    let xs: Vec<f64> = rows.iter().map(|r| r.snr).collect();
    let series: Vec<(&str, Vec<f64>)> = Param::ALL
        .iter()
        .map(|&p| {
            (
                p.name(),
                rows.iter().map(|r| r.uncertainty[p.index()]).collect(),
            )
        })
        .collect();
    format!(
        "{}\n{}",
        t.to_text(),
        ascii_plot(
            "Fig. 7 — relative uncertainty (std/mean) vs evaluation SNR",
            &xs,
            &series,
            10
        )
    )
}

/// CSV export of the sweep (both figures in one file).
pub fn to_csv(rows: &[SnrRow]) -> String {
    use crate::metrics::report::Table;
    let mut t = Table::new(&[
        "snr", "rmse_d", "rmse_dstar", "rmse_f", "rmse_s0", "unc_d", "unc_dstar", "unc_f",
        "unc_s0", "calib_d", "calib_dstar", "calib_f", "calib_s0",
    ]);
    for r in rows {
        let mut cells = vec![format!("{}", r.snr)];
        cells.extend(r.rmse.iter().map(|v| format!("{v:.6}")));
        cells.extend(r.uncertainty.iter().map(|v| format!("{v:.6}")));
        cells.extend(r.calibration.iter().map(|v| format!("{v:.4}")));
        t.row(&cells);
    }
    t.to_csv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    #[test]
    fn sweep_shapes_hold_on_trained_tiny() {
        use crate::runtime::Runtime;
        let Ok(man) = load_manifest("tiny") else { return };
        let Ok(rt) = Runtime::cpu() else { return };
        // quick training so uncertainty reflects data noise not init noise
        let w = crate::experiments::resolve_weights(&man, Some(&rt), None, 150, 20.0).unwrap();
        let cfg = SweepConfig {
            n_voxels: 400,
            snrs: vec![5.0, 50.0],
            engine: "native".into(),
            seed: 3,
        };
        let rows = snr_sweep(&man, &w, &cfg).unwrap();
        assert_eq!(rows.len(), 2);
        // Fig. 6 shape: clean data fits better (reconstruction-driven
        // params D*, f dominate; use recon proxy via f RMSE)
        let noisy = &rows[0];
        let clean = &rows[1];
        let mean_rmse = |r: &SnrRow| {
            Param::ALL
                .iter()
                .map(|&p| {
                    let (lo, hi) = p.range();
                    r.rmse[p.index()] / (hi - lo)
                })
                .sum::<f64>()
        };
        assert!(
            mean_rmse(clean) < mean_rmse(noisy),
            "high SNR should fit better: {} vs {}",
            mean_rmse(clean),
            mean_rmse(noisy)
        );
        // Fig. 7 shape: clean data -> lower average relative uncertainty
        let mean_unc = |r: &SnrRow| r.uncertainty.iter().sum::<f64>();
        assert!(
            mean_unc(clean) < mean_unc(noisy),
            "high SNR should be more confident: {} vs {}",
            mean_unc(clean),
            mean_unc(noisy)
        );
    }

    /// Padding regression (ISSUE #5): the zero-filled tail batch must be
    /// invisible to RMSE, uncertainty AND calibration — the same dataset
    /// run with a batch size that divides it exactly (no padding at all)
    /// yields bit-identical metrics.  Per-voxel inference is independent
    /// of batch composition, so any difference is a padding leak.
    #[test]
    fn tail_padding_never_leaks_into_metrics() {
        use crate::testing::fixture;
        let (man, w) = fixture::tiny_fixture();
        // NOT a multiple of the engine batch -> the tail is padded
        let n = man.batch_infer * 2 + man.batch_infer / 2 + 1;
        let ds = synth_dataset(n, &man.bvalues, 20.0, 77);
        let mut padded = registry::build("native", &man, &w, &EngineOpts::default()).unwrap();
        let outs_padded = run_batches(padded.as_mut(), &ds).unwrap();
        assert!(outs_padded.len() > 2, "tail batch must exist");
        let exact_opts = EngineOpts {
            batch: Some(n),
            ..Default::default()
        };
        let mut exact = registry::build("native", &man, &w, &exact_opts).unwrap();
        let outs_exact = run_batches(exact.as_mut(), &ds).unwrap();
        assert_eq!(outs_exact.len(), 1, "exact run needs no padding");
        for p in Param::ALL {
            assert_eq!(
                metrics::rmse_by_param(&outs_padded, &ds, p),
                metrics::rmse_by_param(&outs_exact, &ds, p),
                "padding leaked into RMSE for {p:?}"
            );
            assert_eq!(
                metrics::mean_relative_uncertainty(&outs_padded, p, ds.len()),
                metrics::mean_relative_uncertainty(&outs_exact, p, ds.len()),
                "padding leaked into uncertainty for {p:?}"
            );
            assert_eq!(
                metrics::calibration(&outs_padded, &ds, p),
                metrics::calibration(&outs_exact, &ds, p),
                "padding leaked into calibration for {p:?}"
            );
        }
    }

    /// ISSUE #7 acceptance: one SNR point of the sweep, run through the
    /// streaming volume pipeline (chunked slice ingest → sharded
    /// coordinator → out-of-order map assembly), is **bit-identical**
    /// to the batch sweep at the same seed — RMSE, relative
    /// uncertainty and calibration, all four parameters, `assert_eq!`
    /// on the raw f64s.
    #[test]
    fn streamed_snr_point_matches_batch_sweep_bit_for_bit() {
        use crate::testing::fixture;
        let (man, w) = fixture::tiny_fixture();
        let dim = (4usize, 4usize, 2usize);
        let cfg = SweepConfig {
            n_voxels: dim.0 * dim.1 * dim.2,
            snrs: vec![20.0],
            engine: "native".into(),
            seed: 11,
        };
        let batch_rows = snr_sweep(&man, &w, &cfg).unwrap();
        let scfg = StreamConfig {
            slices_in_flight: 2,
            ..Default::default()
        };
        let (row, vol) = snr_point_streamed(&man, &w, &cfg, 0, dim, 2, &scfg).unwrap();
        assert_eq!(row.rmse, batch_rows[0].rmse, "RMSE diverged");
        assert_eq!(row.uncertainty, batch_rows[0].uncertainty, "uncertainty diverged");
        assert_eq!(row.calibration, batch_rows[0].calibration, "calibration diverged");
        // The streamed run really went through the coordinator.
        assert_eq!(vol.stats.voxels, cfg.n_voxels);
        assert!(vol.stats.max_inflight_slices >= 1);
        assert!(vol.stats.max_inflight_slices <= 2);
    }

    /// ISSUE #5 acceptance: the fig67 sweep runs end to end on the
    /// `accel-mc` engine (fixed-point MC sampling over the simulator's
    /// hot mask swap), padding included.
    #[test]
    fn snr_sweep_runs_on_accel_mc() {
        use crate::testing::fixture;
        let (man, w) = fixture::tiny_fixture();
        let cfg = SweepConfig {
            n_voxels: man.batch_infer + 3, // forces a padded tail batch
            snrs: vec![5.0, 50.0],
            engine: "accel-mc".into(),
            seed: 9,
        };
        let rows = snr_sweep(&man, &w, &cfg).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            for p in Param::ALL {
                assert!(r.rmse[p.index()].is_finite());
                assert!(r.uncertainty[p.index()].is_finite());
            }
        }
        // random masks per pass must induce spread somewhere
        assert!(rows.iter().any(|r| r.uncertainty.iter().any(|&u| u > 0.0)));
    }

    #[test]
    fn renders_do_not_panic() {
        let rows = vec![
            SnrRow {
                snr: 5.0,
                rmse: [0.001, 0.05, 0.1, 0.05],
                uncertainty: [0.3, 0.4, 0.35, 0.05],
                calibration: [0.5, 0.4, 0.45, 0.3],
            },
            SnrRow {
                snr: 50.0,
                rmse: [0.0005, 0.03, 0.05, 0.02],
                uncertainty: [0.1, 0.2, 0.15, 0.02],
                calibration: [0.6, 0.5, 0.55, 0.4],
            },
        ];
        assert!(render_fig6(&rows).contains("Fig. 6"));
        assert!(render_fig7(&rows).contains("Fig. 7"));
        assert!(to_csv(&rows).lines().count() == 3);
    }
}
