//! Tables I & II: energy efficiency vs prior FPGA BayesNN accelerators,
//! and latency/power/energy per batch vs CPU and GPU (paper §VI-C).
//!
//! Measurement protocol (DESIGN.md §5 substitutions):
//! * **CPU rows** are truly measured on this host (native f32 engine and
//!   the PJRT executable).
//! * **GPU row** is derived from the measured CPU latency scaled by the
//!   paper's CPU/GPU ratio (9.1 / 2.1) — no GPU exists here; the row is
//!   explicitly marked `derived`.
//! * **FPGA row** comes from the cycle simulator at 250 MHz plus the
//!   calibrated power model.
//! * Prior-work rows of Table I are constants quoted from the paper.

use crate::accel::power::{estimate, MaskSampler};
use crate::accel::resource::usage;
use crate::accel::{AccelConfig, AccelSimulator, Scheme};
use crate::bench::{bench, BenchConfig};
use crate::infer::registry::{self, EngineOpts};
use crate::infer::InferOutput;
use crate::ivim::synth::synth_dataset;
use crate::model::{Manifest, Weights};

/// Paper-reported constants used for context rows.
pub mod paper {
    /// Table II reference values.
    pub const CPU_LATENCY_MS: f64 = 9.1;
    pub const GPU_LATENCY_MS: f64 = 2.1;
    pub const FPGA_LATENCY_MS: f64 = 0.28;
    pub const CPU_POWER_W: f64 = 30.0;
    pub const GPU_POWER_W: f64 = 54.0;
    pub const FPGA_POWER_W: f64 = 11.78;
    /// Real-time requirement (§VI-C b).
    pub const REALTIME_MS_PER_BATCH: f64 = 0.8;

    /// Table I rows: (design, platform, freq MHz, power W, model, tech nm,
    /// energy efficiency GOP/s/W).
    pub const TABLE1_PRIOR: [(&str, &str, f64, f64, &str, u32, f64); 4] = [
        ("ASPLOS'18 [33]", "Altera Cyclone V", 213.0, 6.11, "Bayes-FC", 28, 9.75),
        ("DATE'20 [34]", "Xilinx Zynq XC7Z020", 200.0, 2.76, "Bayes-FC", 28, 8.77),
        ("DAC'21 [35]", "Arria 10 GX1150", 225.0, 45.0, "Bayes-VGG11", 20, 11.9),
        ("TPDS'22 [36]", "Arria 10 GX1150", 220.0, 43.6, "Bayes-VGG11", 20, 19.6),
    ];
    pub const OURS_EFFICIENCY: f64 = 20.31;
}

/// One Table II row.
#[derive(Debug, Clone)]
pub struct PlatformRow {
    pub platform: String,
    pub latency_ms: f64,
    pub power_w: f64,
    pub energy_mj: f64,
    pub derived: bool,
}

/// Table II result with the FPGA/CPU/GPU speedup factors.
#[derive(Debug, Clone)]
pub struct Table2 {
    pub rows: Vec<PlatformRow>,
    pub speedup_vs_cpu: f64,
    pub speedup_vs_gpu: f64,
    pub meets_realtime: bool,
}

/// Run Table II on a variant.  Errors when the PJRT runtime is
/// unavailable (the table's point is CPU-native vs CPU-PJRT vs FPGA).
pub fn table2(
    man: &Manifest,
    weights: &Weights,
    bench_cfg: &BenchConfig,
) -> anyhow::Result<Table2> {
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 21);

    // CPU (native f32) — measured on the zero-allocation hot path.
    let mut native = registry::build("native", man, weights, &EngineOpts::default())?;
    let mut native_out = InferOutput::new(native.n_samples(), native.batch_size());
    let r_native = bench("cpu-native", bench_cfg, || {
        native.execute_into(&ds.signals, &mut native_out).unwrap();
    });

    // CPU (PJRT/XLA) — measured.
    let mut pjrt = registry::build("pjrt", man, weights, &EngineOpts::default())?;
    let mut pjrt_out = InferOutput::new(pjrt.n_samples(), pjrt.batch_size());
    let r_pjrt = bench("cpu-pjrt", bench_cfg, || {
        pjrt.execute_into(&ds.signals, &mut pjrt_out).unwrap();
    });

    let cpu_ms = r_native.mean_ms().min(r_pjrt.mean_ms());

    // GPU — derived from the paper's CPU:GPU ratio.
    let gpu_ms = cpu_ms * (paper::GPU_LATENCY_MS / paper::CPU_LATENCY_MS);

    // FPGA — cycle simulator at 250 MHz.
    let cfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };
    let mut sim = AccelSimulator::new(man, weights, cfg, Scheme::BatchLevel)?;
    let (_, stats) = sim.infer_batch_stats(&ds.signals)?;
    let fpga_ms = stats.seconds(cfg.clock_hz) * 1e3;
    let u = usage(&cfg, man.nb, man.n_samples, &sim.weight_stores());
    let p = estimate(&cfg, &u, &stats, MaskSampler::Offline);

    let mk = |platform: &str, ms: f64, w: f64, derived: bool| PlatformRow {
        platform: platform.to_string(),
        latency_ms: ms,
        power_w: w,
        energy_mj: w * ms, // W * ms = mJ
        derived,
    };
    let rows = vec![
        mk("CPU (native f32, this host)", r_native.mean_ms(), paper::CPU_POWER_W, false),
        mk("CPU (PJRT/XLA, this host)", r_pjrt.mean_ms(), paper::CPU_POWER_W, false),
        mk("GPU (derived: paper ratio)", gpu_ms, paper::GPU_POWER_W, true),
        mk("FPGA VU13P (cycle sim @250MHz)", fpga_ms, p.watts, false),
    ];
    Ok(Table2 {
        speedup_vs_cpu: cpu_ms / fpga_ms,
        speedup_vs_gpu: gpu_ms / fpga_ms,
        meets_realtime: fpga_ms <= paper::REALTIME_MS_PER_BATCH,
        rows,
    })
}

pub fn render_table2(t: &Table2) -> String {
    use crate::metrics::report::Table;
    let mut tb = Table::new(&["platform", "latency (ms/batch)", "power (W)", "energy (mJ/batch)", "note"]);
    for r in &t.rows {
        tb.row(&[
            r.platform.clone(),
            format!("{:.3}", r.latency_ms),
            format!("{:.2}", r.power_w),
            format!("{:.2}", r.energy_mj),
            if r.derived { "derived".into() } else { "measured/simulated".into() },
        ]);
    }
    format!(
        "{}\nFPGA speedup: {:.1}x vs CPU, {:.1}x vs GPU (paper: 32.5x, 7.5x)\n\
         real-time 0.8 ms/batch requirement met: {}\n\
         paper reference: CPU {:.1} ms / GPU {:.1} ms / FPGA {:.2} ms\n",
        tb.to_text(),
        t.speedup_vs_cpu,
        t.speedup_vs_gpu,
        t.meets_realtime,
        paper::CPU_LATENCY_MS,
        paper::GPU_LATENCY_MS,
        paper::FPGA_LATENCY_MS,
    )
}

/// One Table I row.
#[derive(Debug, Clone)]
pub struct EfficiencyRow {
    pub design: String,
    pub platform: String,
    pub freq_mhz: f64,
    pub power_w: f64,
    pub model: String,
    pub tech_nm: u32,
    pub gops_per_w: f64,
    pub ours: bool,
}

/// Table I: ours computed from the simulator (GOP/s from op count and
/// simulated latency, W from the power model), prior rows quoted.
pub fn table1(man: &Manifest, weights: &Weights) -> anyhow::Result<Vec<EfficiencyRow>> {
    let ds = synth_dataset(man.batch_infer, &man.bvalues, 20.0, 22);
    let cfg = AccelConfig {
        batch: man.batch_infer,
        ..Default::default()
    };
    let mut sim = AccelSimulator::new(man, weights, cfg, Scheme::BatchLevel)?;
    let (_, stats) = sim.infer_batch_stats(&ds.signals)?;
    let u = usage(&cfg, man.nb, man.n_samples, &sim.weight_stores());
    let p = estimate(&cfg, &u, &stats, MaskSampler::Offline);
    let secs = stats.seconds(cfg.clock_hz);
    let gops = (2.0 * stats.macs as f64) / secs / 1e9; // MAC = 2 ops
    let ours_eff = gops / p.watts;

    let mut rows: Vec<EfficiencyRow> = paper::TABLE1_PRIOR
        .iter()
        .map(|&(d, pl, f, w, m, t, e)| EfficiencyRow {
            design: d.to_string(),
            platform: pl.to_string(),
            freq_mhz: f,
            power_w: w,
            model: m.to_string(),
            tech_nm: t,
            gops_per_w: e,
            ours: false,
        })
        .collect();
    rows.push(EfficiencyRow {
        design: "Ours (sim)".into(),
        platform: "Xilinx VU13P".into(),
        freq_mhz: cfg.clock_hz / 1e6,
        power_w: p.watts,
        model: "Mask-based Bayes-FC".into(),
        tech_nm: 16,
        gops_per_w: ours_eff,
        ours: true,
    });
    Ok(rows)
}

pub fn render_table1(rows: &[EfficiencyRow]) -> String {
    use crate::metrics::report::Table;
    let mut t = Table::new(&["design", "platform", "freq", "power (W)", "model", "tech", "GOP/s/W"]);
    for r in rows {
        t.row(&[
            r.design.clone(),
            r.platform.clone(),
            format!("{:.0} MHz", r.freq_mhz),
            format!("{:.2}", r.power_w),
            r.model.clone(),
            format!("{}nm", r.tech_nm),
            format!("{:.2}", r.gops_per_w),
        ]);
    }
    let ours = rows.iter().find(|r| r.ours).map(|r| r.gops_per_w).unwrap_or(0.0);
    format!(
        "{}\npaper's reported efficiency for its design: {:.2} GOP/s/W (ours simulated: {:.2})\n",
        t.to_text(),
        paper::OURS_EFFICIENCY,
        ours
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    #[test]
    fn table2_shapes_hold_paper_variant() {
        // The paper's ordering claim (FPGA < GPU < CPU) is about the
        // paper-scale model (Nb=104, batch 64) — the tiny variant is so
        // small that the derived GPU row beats the simulated FPGA.
        let Ok(man) = load_manifest("paper") else { return };
        if crate::runtime::Runtime::cpu().is_err() {
            return; // stub build: Table II needs the PJRT engine
        }
        let w = Weights::load_init(&man).unwrap();
        let cfg = BenchConfig {
            target_s: 0.05,
            warmup_s: 0.01,
            min_iters: 2,
            max_iters: 50,
        };
        let t = table2(&man, &w, &cfg).unwrap();
        assert_eq!(t.rows.len(), 4);
        // ordering claim: FPGA < GPU < CPU latency
        let fpga = t.rows[3].latency_ms;
        let gpu = t.rows[2].latency_ms;
        let cpu = t.rows[0].latency_ms.min(t.rows[1].latency_ms);
        assert!(fpga < gpu && gpu < cpu, "{fpga} {gpu} {cpu}");
        assert!(t.speedup_vs_cpu > 1.0);
        let s = render_table2(&t);
        assert!(s.contains("FPGA speedup"));
    }

    #[test]
    fn table1_has_five_rows_and_ours_wins_fc_designs() {
        // Efficiency is only meaningful at paper scale: on the tiny
        // variant the 32x128-lane array idles and GOP/s collapses.
        let Ok(man) = load_manifest("paper") else { return };
        let w = Weights::load_init(&man).unwrap();
        let rows = table1(&man, &w).unwrap();
        assert_eq!(rows.len(), 5);
        let ours = rows.iter().find(|r| r.ours).unwrap();
        // paper claim: >2x the FC-only designs [33][34]
        assert!(ours.gops_per_w > 2.0 * 9.75 * 0.5, "eff {}", ours.gops_per_w);
        assert!(render_table1(&rows).contains("GOP/s/W"));
    }
}
