//! Ablation: Masksembles vs MC-Dropout vs Deep Ensembles (paper §II-C —
//! "Masksembles … combine[s] the advantages" of both extremes) plus the
//! hardware-cost side the co-design argument rests on.
//!
//! For each method we report uncertainty quality (calibration correlation
//! and monotonicity across SNR) and the hardware-relevant costs:
//! repeatability (fixed masks are deterministic; MC-Dropout is not),
//! weight memory multiplier, and whether a runtime sampler is needed
//! (the paper's Fig. 4 hardware penalty) — plus, for the sampler
//! methods, the **per-sample sampler overhead in isolation**: what one
//! mask redraw costs as a fresh engine build (the pre-refactor
//! lifecycle) vs an in-place mask swap ([`sampler_overhead`]), which
//! the mask-lifecycle refactor finally makes measurable.

use crate::experiments::fig67::run_batches;
use crate::infer::native::NativeEngine;
use crate::infer::registry::{self, EngineOpts};
use crate::infer::Engine;
use crate::ivim::synth::synth_dataset;
use crate::ivim::Param;
use crate::masks::MaskPlan;
use crate::metrics;
use crate::model::{Manifest, Weights};
use crate::util::rng::Pcg32;
use crate::util::Timer;

/// One method's ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub method: String,
    /// Mean calibration (Pearson of |err| vs std) across params at the
    /// reference SNR.
    pub calibration: f64,
    /// Mean relative uncertainty at SNR 5 / SNR 50 — monotone methods
    /// have hi > lo.
    pub unc_noisy: f64,
    pub unc_clean: f64,
    /// Run-to-run repeatability: max |Δ prediction| between two identical
    /// calls (0 for deterministic methods).
    pub repeatability: f64,
    /// Weight-memory multiplier vs a single dense model.
    pub memory_x: f64,
    /// Needs a runtime RNG/sampler module in hardware.
    pub runtime_sampler: bool,
    /// Per-sample sampler overhead when masks are applied by rebuilding
    /// the engine (us; 0 = no runtime sampler needed).
    pub sampler_fresh_us: f64,
    /// Per-sample sampler overhead via the in-place mask swap (us).
    pub sampler_swap_us: f64,
    /// Per-sample sampler overhead when only the last layer is redrawn
    /// (the `mc-dropout-ll` head's per-pass cost; us).
    pub sampler_ll_us: f64,
}

/// Measure the runtime-sampler overhead in isolation, per mask redraw:
///
/// * **fresh-build** — clone the manifest, bake the redrawn masks in,
///   construct a new `NativeEngine` (the pre-refactor `McDropout`
///   lifecycle: transpose + BN-fold + pack + allocate, every sample);
/// * **mask-swap** — `MaskPlan::resample` + `NativeEngine::swap_masks`
///   (the current hot path: in-place redraw + union re-pack);
/// * **last-layer swap** — `MaskPlan::resample_layer_range(2, 2)` +
///   swap: the `mc-dropout-ll` head's per-pass cost, redrawing half the
///   mask bits.
///
/// All include the Bernoulli redraw itself, so the differences are
/// purely the mask-application machinery.  Returns
/// `(fresh_us, swap_us, ll_us)`.
pub fn sampler_overhead(man: &Manifest, weights: &Weights) -> anyhow::Result<(f64, f64, f64)> {
    let iters = 50usize;
    let mut rng = Pcg32::new(71);
    let mut plan = MaskPlan::bernoulli(man, 1.0 / man.scale, &mut rng);

    let t = Timer::start();
    for _ in 0..iters {
        plan.resample(&mut rng);
        let mut man2 = man.clone();
        plan.apply_to_manifest(&mut man2);
        let eng = NativeEngine::with_batch(&man2, weights, man.batch_infer)?;
        std::hint::black_box(&eng);
    }
    let fresh_us = t.elapsed_s() * 1e6 / iters as f64;

    let mut eng = NativeEngine::with_batch(man, weights, man.batch_infer)?;
    let t = Timer::start();
    for _ in 0..iters {
        plan.resample(&mut rng);
        eng.swap_masks(&plan)?;
    }
    std::hint::black_box(&eng);
    let swap_us = t.elapsed_s() * 1e6 / iters as f64;

    let t = Timer::start();
    for _ in 0..iters {
        plan.resample_layer_range(2, 2, &mut rng);
        eng.swap_masks(&plan)?;
    }
    std::hint::black_box(&eng);
    let ll_us = t.elapsed_s() * 1e6 / iters as f64;
    Ok((fresh_us, swap_us, ll_us))
}

fn eval_engine(
    engine: &mut dyn Engine,
    man: &Manifest,
    seed: u64,
) -> anyhow::Result<(f64, f64, f64, f64)> {
    let ref_ds = synth_dataset(512, &man.bvalues, 20.0, seed);
    let outs = run_batches(engine, &ref_ds)?;
    let calibration = Param::ALL
        .iter()
        .map(|&p| metrics::calibration(&outs, &ref_ds, p))
        .sum::<f64>()
        / 4.0;

    let noisy = synth_dataset(256, &man.bvalues, 5.0, seed + 1);
    let clean = synth_dataset(256, &man.bvalues, 50.0, seed + 1);
    let unc_noisy = metrics::mean_relative_uncertainty_all(&run_batches(engine, &noisy)?, noisy.len());
    let unc_clean = metrics::mean_relative_uncertainty_all(&run_batches(engine, &clean)?, clean.len());

    // repeatability: identical input twice
    let a = run_batches(engine, &ref_ds)?;
    let b = run_batches(engine, &ref_ds)?;
    let mut max_delta = 0.0f64;
    for (oa, ob) in a.iter().zip(&b) {
        for p in Param::ALL {
            let (lo, hi) = p.range();
            for v in 0..oa.batch {
                let d = (oa.mean(p, v) - ob.mean(p, v)).abs() / (hi - lo);
                max_delta = max_delta.max(d);
            }
        }
    }
    Ok((calibration, unc_noisy, unc_clean, max_delta))
}

/// Run the four-method ablation with the given weights.  All the heads
/// come from the engine registry, like every other consumer.
pub fn ablation(man: &Manifest, weights: &Weights) -> anyhow::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();

    // Masksembles (the paper's method): fixed masks from the manifest.
    let mut ours = registry::build("native", man, weights, &EngineOpts::default())?;
    let (cal, un, uc, rep) = eval_engine(ours.as_mut(), man, 61)?;
    rows.push(AblationRow {
        method: "Masksembles (ours)".into(),
        calibration: cal,
        unc_noisy: un,
        unc_clean: uc,
        repeatability: rep,
        memory_x: 1.0, // mask-zero skipping: N partial copies ≈ 1 dense set
        runtime_sampler: false,
        sampler_fresh_us: 0.0,
        sampler_swap_us: 0.0,
        sampler_ll_us: 0.0,
    });

    // MC-Dropout: random Bernoulli masks per pass.  The sampler columns
    // isolate what one redraw costs under the three mask lifecycles
    // (fresh engine build, full-plan swap, last-layer-only swap).
    let mcd_opts = EngineOpts {
        seed: 62,
        ..Default::default()
    };
    let (sampler_fresh_us, sampler_swap_us, sampler_ll_us) = sampler_overhead(man, weights)?;
    let mut mcd = registry::build("mc-dropout", man, weights, &mcd_opts)?;
    let (cal, un, uc, rep) = eval_engine(mcd.as_mut(), man, 61)?;
    rows.push(AblationRow {
        method: "MC-Dropout".into(),
        calibration: cal,
        unc_noisy: un,
        unc_clean: uc,
        repeatability: rep,
        memory_x: 1.0,
        runtime_sampler: true, // the Fig.-4 hardware penalty
        sampler_fresh_us,
        sampler_swap_us,
        sampler_ll_us,
    });

    // Last-layer-only MC-Dropout: the deterministic trunk is shared
    // across passes, only the output-layer masks are redrawn — the
    // cheap-sampler ablation the `mc-dropout-ll` head exists for.
    let mut mcd_ll = registry::build("mc-dropout-ll", man, weights, &mcd_opts)?;
    let (cal, un, uc, rep) = eval_engine(mcd_ll.as_mut(), man, 61)?;
    rows.push(AblationRow {
        method: "MC-Dropout (last layer)".into(),
        calibration: cal,
        unc_noisy: un,
        unc_clean: uc,
        repeatability: rep,
        memory_x: 1.0,
        runtime_sampler: true,
        sampler_fresh_us,
        sampler_swap_us: sampler_ll_us, // its per-pass cost IS the ll redraw
        sampler_ll_us,
    });

    // Deep Ensemble: N independent weight sets (untrained members carry
    // init-diversity; with trained members this is the gold standard).
    let ens_opts = EngineOpts {
        seed: 63,
        members: Some(man.n_samples),
        ..Default::default()
    };
    let mut de = registry::build("ensemble", man, weights, &ens_opts)?;
    let memory_x = de.n_samples() as f64;
    let (cal, un, uc, rep) = eval_engine(de.as_mut(), man, 61)?;
    rows.push(AblationRow {
        method: "Deep Ensemble".into(),
        calibration: cal,
        unc_noisy: un,
        unc_clean: uc,
        repeatability: rep,
        memory_x,
        runtime_sampler: false,
        sampler_fresh_us: 0.0,
        sampler_swap_us: 0.0,
        sampler_ll_us: 0.0,
    });

    Ok(rows)
}

/// Render the ablation table.
pub fn render(rows: &[AblationRow]) -> String {
    use crate::metrics::report::Table;
    let mut t = Table::new(&[
        "method", "calibration", "unc@SNR5", "unc@SNR50", "repeatability", "memory",
        "runtime sampler", "sampler fresh-build", "sampler mask-swap", "sampler last-layer",
    ]);
    for r in rows {
        let sampler_col = |us: f64| {
            if r.runtime_sampler {
                format!("{us:.1} us/sample")
            } else {
                "-".into()
            }
        };
        t.row(&[
            r.method.clone(),
            format!("{:.3}", r.calibration),
            format!("{:.3}", r.unc_noisy),
            format!("{:.3}", r.unc_clean),
            if r.repeatability == 0.0 {
                "exact".into()
            } else {
                format!("±{:.1e}", r.repeatability)
            },
            format!("{:.0}x", r.memory_x),
            if r.runtime_sampler { "REQUIRED" } else { "none" }.into(),
            sampler_col(r.sampler_fresh_us),
            sampler_col(r.sampler_swap_us),
            sampler_col(r.sampler_ll_us),
        ]);
    }
    t.to_text()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load_manifest;

    #[test]
    fn ablation_hardware_claims() {
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let rows = ablation(&man, &w).unwrap();
        assert_eq!(rows.len(), 4);
        let ours = &rows[0];
        let mcd = &rows[1];
        let mcd_ll = &rows[2];
        let de = &rows[3];
        // The paper's §II-C / §V claims:
        assert_eq!(ours.repeatability, 0.0, "fixed masks are deterministic");
        assert!(mcd.repeatability > 0.0, "MC-Dropout is not repeatable");
        assert!(
            mcd_ll.repeatability > 0.0,
            "last-layer MC still redraws masks"
        );
        assert!(!ours.runtime_sampler && mcd.runtime_sampler && mcd_ll.runtime_sampler);
        assert!(de.memory_x >= 2.0, "ensembles pay the memory cost");
        // Sampler overhead is reported (and only) for the sampler methods.
        assert!(mcd.sampler_fresh_us > 0.0 && mcd.sampler_swap_us > 0.0);
        assert!(mcd.sampler_ll_us > 0.0);
        assert_eq!(ours.sampler_fresh_us, 0.0);
        assert_eq!(ours.sampler_ll_us, 0.0);
        // The ll head's per-pass cost is the last-layer redraw itself.
        assert_eq!(mcd_ll.sampler_swap_us, mcd_ll.sampler_ll_us);
        // All three methods show more uncertainty on noisier data.
        for r in &rows {
            assert!(
                r.unc_noisy > r.unc_clean,
                "{}: {} !> {}",
                r.method,
                r.unc_noisy,
                r.unc_clean
            );
        }
        assert!(render(&rows).contains("Masksembles"));
        let rendered = render(&rows);
        assert!(rendered.contains("sampler fresh-build"));
        assert!(rendered.contains("sampler mask-swap"));
        assert!(rendered.contains("sampler last-layer"));
        assert!(rendered.contains("MC-Dropout (last layer)"));
    }

    /// Fixture-backed (never skips): all three sampler lifecycles are
    /// measurable.  The swap-vs-fresh *magnitude* claim lives in the
    /// `micro_hotpaths` bench, not here — wall-clock comparisons on a
    /// contended CI runner are a flaky-test class, so the unit test
    /// only asserts the measurement machinery works.
    #[test]
    fn sampler_overhead_is_measurable() {
        let (man, w) = crate::testing::fixture::tiny_fixture();
        let (fresh_us, swap_us, ll_us) = sampler_overhead(&man, &w).unwrap();
        assert!(fresh_us > 0.0 && fresh_us.is_finite());
        assert!(swap_us > 0.0 && swap_us.is_finite());
        assert!(ll_us > 0.0 && ll_us.is_finite());
    }
}
