//! Experiment drivers — one function per paper table/figure, shared by
//! the CLI (`repro fig6` …) and the `cargo bench` targets so both always
//! report the same numbers (DESIGN.md §4 experiment index).

pub mod ablation;
pub mod fig67;
pub mod fig8;
pub mod tables;

use crate::model::manifest::{artifacts_root, Manifest};
use crate::model::Weights;
use crate::runtime::Runtime;

/// Load a variant manifest from the artifacts root.
pub fn load_manifest(variant: &str) -> anyhow::Result<Manifest> {
    let dir = artifacts_root().join(variant);
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts for variant '{variant}' not found under {} — run `make artifacts`",
        artifacts_root().display()
    );
    Manifest::load(&dir)
}

/// Resolve weights: explicit stem > cached trained weights > train now >
/// artifact init (when `train_steps == 0`).  Training needs a PJRT
/// runtime; pass `None` to only allow the non-training paths.
pub fn resolve_weights(
    man: &Manifest,
    rt: Option<&Runtime>,
    weights_stem: Option<&str>,
    train_steps: usize,
    train_snr: f64,
) -> anyhow::Result<Weights> {
    if let Some(stem) = weights_stem {
        let stem = std::path::PathBuf::from(stem);
        return Weights::load_files(
            man,
            &stem.with_extension("params.bin"),
            &stem.with_extension("bn.bin"),
        );
    }
    if train_steps == 0 {
        return Weights::load_init(man);
    }
    // Cache trained weights next to the artifacts so repeated experiment
    // runs skip retraining.
    let cache = man.dir.join(format!(
        "trained_s{}_snr{}",
        train_steps, train_snr as i64
    ));
    let p = cache.with_extension("params.bin");
    let b = cache.with_extension("bn.bin");
    if p.exists() && b.exists() {
        if let Ok(w) = Weights::load_files(man, &p, &b) {
            return Ok(w);
        }
    }
    let rt = rt.ok_or_else(|| {
        anyhow::anyhow!("training {train_steps} steps needs a PJRT runtime (none available)")
    })?;
    let cfg = crate::train::TrainConfig {
        steps: train_steps,
        snr: train_snr,
        seed: 1,
        log_every: 0,
        early_stop_rel: 0.0,
    };
    let rep = crate::train::train(rt, man, &cfg, None)?;
    let _ = rep.final_weights.save(&cache);
    Ok(rep.final_weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_registry_engines_on_artifacts() {
        use crate::infer::registry::{build, EngineOpts};
        let Ok(man) = load_manifest("tiny") else { return };
        let w = Weights::load_init(&man).unwrap();
        let opts = EngineOpts::default();
        assert!(build("native", &man, &w, &opts).is_ok());
        assert!(build("accel", &man, &w, &opts).is_ok());
        if Runtime::cpu().is_ok() {
            assert!(build("pjrt", &man, &w, &opts).is_ok());
        } else {
            assert!(build("pjrt", &man, &w, &opts).is_err());
        }
    }

    #[test]
    fn resolve_weights_without_runtime() {
        // Fixture-independent behaviour: asking for training without a
        // runtime must error instead of panicking.
        let (man, _) = crate::testing::fixture::tiny_fixture();
        let r = resolve_weights(&man, None, None, 50, 20.0);
        assert!(r.is_err());
    }
}
